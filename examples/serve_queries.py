"""Serving driver: batched scan requests through the typed client frontend
— the paper's §V service shape, runnable end-to-end.  Every batch is a
``repro.api.Query`` routed by table name through a ``Database`` handle:
the shared ``QueryScheduler`` coalesces concurrent callers (here with a
2 ms micro-batch window) into bucket-padded planner invocations with
broadcast/routed selection, sentinel retry, and LSM-tier merge; the run
demos multi-table serving, paged ``ReadSession`` streaming, and ends
with an append + compact (the write path).  Pass ``--root DIR`` to
persist and re-open the tables across runs.

    PYTHONPATH=src python examples/serve_queries.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--text-len", "200000", "--queries", "5000",
                "--batch", "256", "--coalesce-window", "2.0"])

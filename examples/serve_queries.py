"""Serving driver: batched scan requests against a ``repro.api.SuffixTable``
— the paper's §V service shape, runnable end-to-end.  All scans go through
the table's merged read path on top of the scan planner (repro.core.planner):
broadcast/routed selection, sentinel retry, memtable merge, and top-k match
enumeration; the run ends with an append + compact (the write path).
Pass ``--root DIR`` to persist and re-open the table across runs.

    PYTHONPATH=src python examples/serve_queries.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--text-len", "200000", "--queries", "5000",
                "--batch", "256"])

"""Serving driver: batched scan requests against the tablet store — the
paper's §V service shape, runnable end-to-end.  All scans go through the
scan planner (repro.core.planner): broadcast/routed selection, sentinel
retry, and top-k match enumeration.

    PYTHONPATH=src python examples/serve_queries.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--text-len", "200000", "--queries", "5000",
                "--batch", "256"])

"""The paper's case study end-to-end (§IV-V): chromosome-scale DNA ingest,
single-process and 50-user scan workloads, Table III/IV/V statistics, and
the hedged-read tail fix.

    PYTHONPATH=src python examples/dna_search.py --text-len 300000
"""
import argparse
import time

import jax

from repro.core.codec import decode_dna, random_dna
from repro.core.planner import ScanPlanner
from repro.core.tablet import build_tablet_store
from repro.serving import HedgedScanService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=300_000)
    ap.add_argument("--queries", type=int, default=10_000)
    args = ap.parse_args()

    print(f"[ingest] {args.text_len} bases (paper: chr1, 17 min on 2 VMs)")
    t0 = time.perf_counter()
    codes = random_dna(args.text_len, seed=0)
    store = build_tablet_store(codes, is_dna=True)
    jax.block_until_ready(store.sa)
    dt = time.perf_counter() - t0
    print(f"[ingest] {dt:.1f}s = {args.text_len / dt / 1e6:.2f} Mbase/s")

    planner = ScanPlanner(store)
    svc = HedgedScanService(store, planner=planner)
    # Table III: single process
    # batch=10: a sequential single-stream on CPU is dispatch-bound;
    # 10-wide batches keep the "single process" semantics at tractable cost
    s = svc.run_workload(args.queries, batch=10, hedged=False, seed=3)
    print(f"[table III] n={s['n']} mean={s['mean_ms']:.2f}ms "
          f"sd={s['sd_ms']:.2f} max={s['max_ms']:.0f} hit={s['hit_rate']:.3f}"
          f"   (paper: mean 2.79ms sd 3.64 max 41 hit 0.072)")
    # Table IV: 50 users
    s = svc.run_workload(args.queries, batch=50, hedged=False, seed=4)
    print(f"[table IV ] n={s['n']} mean={s['mean_ms']:.2f}ms "
          f"max={s['max_ms']:.0f} hit={s['hit_rate']:.3f}"
          f"   (paper: mean 5.26ms max 771 hit 0.080)")
    # Table V: correlations
    print(f"[table V  ] corr(len,time)={s['corr_len_time']:.3f} "
          f"corr(len,hit)={s['corr_len_outcome']:.3f}"
          f"   (paper: 0.013 / -0.469)")
    # Beyond-paper: hedged reads kill the tail the paper measured
    h = svc.run_workload(args.queries, batch=50, hedged=True, seed=4)
    print(f"[hedged   ] max={h['max_ms']:.0f}ms p99={h['p99_ms']:.1f}ms "
          f"(single-read max was {s['max_ms']:.0f}ms)")
    # Beyond-paper: match enumeration — the paper only reports the first
    # match row; the planner's locate() gathers top-k positions per query
    probe = decode_dna(codes[1000:1008])
    out = planner.scan([probe], top_k=8)
    hits = [int(x) for x in out.positions[0] if x >= 0]
    print(f"[locate   ] {probe!r}: count={int(out.count[0])} "
          f"positions={hits} (planted at 1000)")
    assert 1000 in hits or int(out.count[0]) > 8


if __name__ == "__main__":
    main()

"""The paper's case study end-to-end (§IV-V), through the client frontend:
chromosome-scale DNA ingest into a persisted ``SuffixTable`` behind a
``repro.api.Database`` handle, single-process and 50-user scan workloads,
Table III/IV/V statistics, the hedged-read tail fix — then the
beyond-paper surface: typed locate queries, paged ``ReadSession``
streaming with a mid-stream cursor resume, append with merged-read exact
counts, compact, and re-open from disk.

    PYTHONPATH=src python examples/dna_search.py --text-len 300000
"""
import argparse
import tempfile
import time

from repro.api import Database, Query, SuffixTable
from repro.core.codec import decode_dna, random_dna
from repro.serving import HedgedScanService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=300_000)
    ap.add_argument("--queries", type=int, default=10_000)
    args = ap.parse_args()

    db = Database(tempfile.mkdtemp(prefix="repro_tables_"))
    root = db.root
    print(f"[ingest] {args.text_len} bases (paper: chr1, 17 min on 2 VMs)")
    t0 = time.perf_counter()
    codes = random_dna(args.text_len, seed=0)
    table = db.create_table("chr_demo", codes, is_dna=True)
    dt = time.perf_counter() - t0
    print(f"[ingest] {dt:.1f}s = {args.text_len / dt / 1e6:.2f} Mbase/s "
          f"-> {root}/chr_demo v{table.version}")

    svc = HedgedScanService(table, database=db)
    # paper workload lengths are 1..100; clamp to the table's pattern cap
    # (run_workload validates max_len up front)
    max_len = min(100, table.max_query_len)
    # Table III: single process
    # batch=10: a sequential single-stream on CPU is dispatch-bound;
    # 10-wide batches keep the "single process" semantics at tractable cost
    s = svc.run_workload(args.queries, batch=10, hedged=False, seed=3,
                         max_len=max_len)
    print(f"[table III] n={s['n']} mean={s['mean_ms']:.2f}ms "
          f"sd={s['sd_ms']:.2f} max={s['max_ms']:.0f} hit={s['hit_rate']:.3f}"
          f"   (paper: mean 2.79ms sd 3.64 max 41 hit 0.072)")
    # Table IV: 50 users
    s = svc.run_workload(args.queries, batch=50, hedged=False, seed=4,
                         max_len=max_len)
    print(f"[table IV ] n={s['n']} mean={s['mean_ms']:.2f}ms "
          f"max={s['max_ms']:.0f} hit={s['hit_rate']:.3f}"
          f"   (paper: mean 5.26ms max 771 hit 0.080)")
    # Table V: correlations
    print(f"[table V  ] corr(len,time)={s['corr_len_time']:.3f} "
          f"corr(len,hit)={s['corr_len_outcome']:.3f}"
          f"   (paper: 0.013 / -0.469)")
    # Beyond-paper: hedged reads kill the tail the paper measured
    h = svc.run_workload(args.queries, batch=50, hedged=True, seed=4,
                         max_len=max_len)
    print(f"[hedged   ] max={h['max_ms']:.0f}ms p99={h['p99_ms']:.1f}ms "
          f"(single-read max was {s['max_ms']:.0f}ms)")
    # Beyond-paper: match enumeration — the paper only reports the first
    # match row; a typed locate Query gathers the top-k smallest positions
    probe = decode_dna(codes[1000:1008])
    out = db.query(Query.locate("chr_demo", [probe], top_k=8))
    hits = [int(x) for x in out.value[0] if x >= 0]
    print(f"[locate   ] {probe!r}: count={int(out.count[0])} "
          f"positions={hits} (planted at 1000)")
    assert 1000 in hits or int(out.count[0]) > 8

    # Beyond-paper: paged streaming (the ReadRows analogue) — a huge
    # enumeration comes back in bounded pages; a serialized cursor resumes
    # mid-stream, even across the compaction below
    short = decode_dna(codes[1000:1003])
    sess = db.read_rows("chr_demo", short, page_size=100)
    first = sess.next_page()
    cursor = first.cursor                      # plain JSON, process-portable
    rest = sum(len(p.positions) for p in db.resume_read(cursor).pages())
    want = int(db.query(Query.count("chr_demo", [short])).value[0])
    assert len(first.positions) + rest == want
    print(f"[stream   ] {short!r}: {want} positions = "
          f"{len(first.positions)} (page 1) + {rest} (resumed from cursor)")

    # Beyond-paper: the write path.  Accumulo tables are mutable; so is
    # ours — appends land in the memtable and reads merge exact counts,
    # including matches straddling the old end-of-text.
    tail = decode_dna(codes[-4:])
    straddle = tail + "GATTACA"          # crosses the base/append boundary
    before = int(db.query(Query.count("chr_demo", [straddle])).value[0])
    table.append("GATTACA" + decode_dna(random_dna(500, seed=7)))
    after = int(db.query(Query.count("chr_demo", [straddle])).value[0])
    assert after == before + 1, (before, after)
    print(f"[append   ] {straddle!r}: count {before} -> {after} "
          f"(memtable merged read)")
    table.compact()
    reopened = SuffixTable.open("chr_demo", root=root)
    assert int(reopened.count([straddle])[0]) == after
    print(f"[compact  ] v{reopened.version}, {len(reopened)} bases; "
          f"re-opened from disk with identical counts")
    db.close()


if __name__ == "__main__":
    main()

"""Quickstart: the paper's pipeline in 30 lines.

Builds a suffix-array tablet store over a DNA string, runs pattern scans
(paper §V), and shows the paper's own MISSISSIPPI worked example (§III).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import codec, query as Q
from repro.core.tablet import build_tablet_store

# --- the paper's §III worked example ---------------------------------------
text = "MISSISSIPPI"
codes = np.frombuffer(text.encode(), dtype=np.uint8).astype(np.int32)
store = build_tablet_store(codes, is_dna=False)
sa = np.asarray(store.sa)[store.pad_count:]
print("ordered suffixes (paper §III):")
for i in sa:
    print("  ", text[i:])

# --- DNA scans (paper §IV-V) ------------------------------------------------
dna = codec.random_dna(100_000, seed=0)
store = build_tablet_store(dna, is_dna=True)

patterns = ["ACGT", "TTTTTTTTTTTTTTTT", "GATTACA"]
_, packed, lengths = Q.encode_patterns(patterns, 32)
res = Q.query(store, packed, lengths)
for p, found, count, pos in zip(patterns, res.found, res.count,
                                res.first_pos):
    print(f"pattern {p!r}: found={bool(found)} count={int(count)} "
          f"first_pos={int(pos)}")

"""Quickstart: the paper's pipeline in 30 lines, via the table API.

Builds a suffix-array table over a DNA string (``repro.api.SuffixTable``
is the single public entry point — construction, scans, appends), runs
pattern scans (paper §V), and shows the paper's own MISSISSIPPI worked
example (§III) on the low-level store.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import SuffixTable
from repro.core import codec
from repro.core.tablet import build_tablet_store

# --- the paper's §III worked example (low-level store) ----------------------
text = "MISSISSIPPI"
codes = np.frombuffer(text.encode(), dtype=np.uint8).astype(np.int32)
store = build_tablet_store(codes, is_dna=False)
sa = np.asarray(store.sa)[store.pad_count:]
print("ordered suffixes (paper §III):")
for i in sa:
    print("  ", text[i:])

# --- DNA scans (paper §IV-V) through the table facade -----------------------
dna = codec.random_dna(100_000, seed=0)
table = SuffixTable.from_codes(dna, is_dna=True)   # in-memory table

patterns = ["ACGT", "TTTTTTTTTTTTTTTT", "GATTACA"]
out = table.scan(patterns, top_k=3)
for p, found, count, pos, row in zip(patterns, out.found, out.count,
                                     out.first_pos, out.positions):
    print(f"pattern {p!r}: found={bool(found)} count={int(count)} "
          f"first_pos={int(pos)} top3={[int(x) for x in row if x >= 0]}")

# --- the write path: append, merged exact read ------------------------------
table.append("GATTACAGATTACA")
print(f"after append: count('GATTACA') = {int(table.count(['GATTACA'])[0])}")

"""Quickstart: the paper's pipeline in 40 lines, via the client frontend.

Builds a suffix-array table over a DNA string, routes typed ``Query``
requests through a ``repro.api.Database`` handle (the Bigtable-style
client: count / contains / locate / scan), streams a big enumeration in
pages (``ReadSession``), and shows the paper's own MISSISSIPPI worked
example (§III) on the low-level store.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import Database, Query, SuffixTable
from repro.core import codec
from repro.core.tablet import build_tablet_store

# --- the paper's §III worked example (low-level store) ----------------------
text = "MISSISSIPPI"
codes = np.frombuffer(text.encode(), dtype=np.uint8).astype(np.int32)
store = build_tablet_store(codes, is_dna=False)
sa = np.asarray(store.sa)[store.pad_count:]
print("ordered suffixes (paper §III):")
for i in sa:
    print("  ", text[i:])

# --- DNA scans (paper §IV-V) through the typed client ------------------------
dna = codec.random_dna(100_000, seed=0)
with Database.in_memory() as db:                     # the client handle
    table = db.attach("dna", SuffixTable.from_codes(dna, is_dna=True))

    patterns = ["ACGT", "TTTTTTTTTTTTTTTT", "GATTACA"]
    res = db.query(Query.scan("dna", patterns, top_k=3))
    for p, found, count, pos, row in zip(patterns, res.found, res.count,
                                         res.first_pos, res.positions):
        print(f"pattern {p!r}: found={bool(found)} count={int(count)} "
              f"first_pos={int(pos)} top3={[int(x) for x in row if x >= 0]}")

    # --- paged streaming (the ReadRows analogue) -----------------------------
    pages = list(db.read_rows("dna", "GATTACA", page_size=4).pages())
    total = sum(len(pg.positions) for pg in pages)
    print(f"streamed {total} 'GATTACA' positions in {len(pages)} pages of <=4"
          f" (cursor resumes across appends and compactions)")

    # --- the write path: append, merged exact read ---------------------------
    table.append("GATTACAGATTACA")
    after = int(db.query(Query.count("dna", ["GATTACA"])).value[0])
    print(f"after append: count('GATTACA') = {after}")

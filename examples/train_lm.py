"""End-to-end training driver: train a reduced-config model for a few
hundred steps on CPU with checkpoints + auto-resume, through the same
launcher a pod deployment uses.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --steps 200
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    losses = train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "20",
    ])
    assert losses[-1] < losses[0], "training must reduce loss"
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()

"""LM-pipeline integration: suffix-array dedup + contamination search over
a token corpus (DESIGN.md §3) — the paper's scan engine as training-data
infrastructure, served from a named table behind a ``repro.api.Database``
handle (DNA and token corpora share one root, like Accumulo tables share
one instance).  Contamination checks go through the table's merged read
path, so tokens appended after the build are searched too; the eval-leak
lookup at the end rides a typed raw-codes ``Query`` through the client.

    PYTHONPATH=src python examples/corpus_dedup.py
"""
import tempfile

import numpy as np

from repro.api import Database, Query
from repro.core import dedup

rng = np.random.default_rng(0)

# a document pool with planted duplication and eval contamination
docs = [rng.integers(0, 32000, 400).astype(np.int32) for _ in range(8)]
docs[5] = docs[1].copy()                     # exact duplicate document
eval_window = docs[3][100:140].copy()        # eval n-gram leaked into train

tokens = np.concatenate(docs)
doc_ids = np.repeat(np.arange(len(docs)), 400)

db = Database(tempfile.mkdtemp(prefix="repro_tables_"))
table = db.create_table("train_tokens", tokens, is_dna=False,
                        max_query_len=64)
print(f"database {db.root}: {db.list_tables()}")

scores = dedup.doc_dup_scores(table, doc_ids, min_len=48)
keep = dedup.filter_duplicate_docs(table, doc_ids, min_len=48)
print("per-document duplicated fraction:")
for i, (s, k) in enumerate(zip(scores, keep)):
    print(f"  doc {i}: dup={s:.2f} keep={bool(k)}")
assert not (keep[1] and keep[5]), "one of the duplicate pair must drop"

hits = dedup.contamination_check(table, eval_window[None, :])
print(f"eval window contaminated: {bool(hits[0])} (expected True)")
clean = dedup.contamination_check(
    table, rng.integers(32000, 64000, 40).astype(np.int32)[None, :])
print(f"random window contaminated: {bool(clean[0])} (expected False)")

# the same leak lookup as a typed raw-codes client query: token tables
# take int32 code rows padded to the table's query cap, plus row lengths
w = np.zeros((1, table.max_query_len), np.int32)
w[0, :eval_window.size] = eval_window
res = db.query(Query(table="train_tokens", kind="count", codes=w,
                     lens=np.array([eval_window.size], np.int32)))
print(f"typed Query count of the leaked window: {int(res.value[0])}")
assert int(res.value[0]) >= 1

# a late-arriving training shard: append is searched without a rebuild
late_window = rng.integers(0, 32000, 40).astype(np.int32)
assert not dedup.contamination_check(table, late_window[None, :])[0]
table.append(late_window)
assert dedup.contamination_check(table, late_window[None, :])[0]
print("appended shard visible to contamination search (merged read)")
db.close()

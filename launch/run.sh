#!/usr/bin/env bash
# Tuned production launcher for the serving workload.
#
# Applies the launch-time half of the tuning story — the knobs a Python
# process cannot apply to itself — then execs serve.py with the --tuned
# env preset (docs/observability.md documents every knob):
#
#   * LD_PRELOAD tcmalloc when present: thread-cached mallocs beat glibc
#     under the scheduler's multi-threaded dispatch fan-out;
#   * TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD: silence tcmalloc's stderr
#     report for the large build-time array allocations;
#   * TF_CPP_MIN_LOG_LEVEL=4: fully quiet TF/XLA logging;
#   * XLA_FLAGS host-device count (HOST_DEVICES=N): multi-device scan
#     paths on a CPU-only box — set BEFORE python starts, so it always
#     beats the jax import.
#
# Usage (any serve.py flag passes through):
#   launch/run.sh --root /data/sa --table dna --queries 100000
#   HOST_DEVICES=4 launch/run.sh --root /data/sa --tablets 2
set -euo pipefail

# repo root = one level above this script: run from anywhere
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# tcmalloc, when the box has it (Debian/Ubuntu package paths first,
# then whatever ldconfig knows) — skipped silently when absent
if [ -z "${LD_PRELOAD:-}" ]; then
  for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
            "$(ldconfig -p 2>/dev/null | awk '/libtcmalloc(_minimal)?\.so/ {print $NF; exit}')"; do
    if [ -n "$so" ] && [ -e "$so" ]; then
      export LD_PRELOAD="$so"
      break
    fi
  done
fi

export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# optional: force N XLA host devices (the multi-device scan paths) —
# serve.py --host-devices does the same, but env set here also covers
# any jax import that might precede flag parsing in custom entrypoints
if [ -n "${HOST_DEVICES:-}" ]; then
  export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=${HOST_DEVICES}"
fi

exec python -m repro.launch.serve --tuned "$@"

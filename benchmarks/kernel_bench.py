"""Micro-benchmarks for the scan-path compute (XLA path on CPU; the Pallas
kernels target TPU and are validated in interpret mode by tests)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec, query as Q
from repro.core.codec import random_dna
from repro.core.planner import ScanPlanner
from repro.core.tablet import build_tablet_store


def _time(fn, *args, reps=5):
    fn(*args)                                # compile+warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_pattern_compare(B=4096, W=7):
    codes = random_dna(100_000, seed=0)
    packed = codec.pack_2bit(codes)
    rng = np.random.default_rng(0)
    pos = jnp.asarray(rng.integers(0, 100_000, B), jnp.int32)
    pats = Q.random_patterns(B, 1, 100, seed=1)
    _, pp, pl = Q.encode_patterns(pats, W * 16)

    f = jax.jit(lambda p: Q.compare_packed(packed, 100_000, p, pp, pl))
    dt = _time(f, pos)
    return dt / B * 1e6, {"compares_per_s": round(B / dt), "batch": B}


def bench_binary_search(B=1024):
    store = build_tablet_store(random_dna(1_000_000, seed=2), is_dna=True)
    pats = Q.random_patterns(B, 1, 100, seed=3)
    _, pp, pl = Q.encode_patterns(pats, 112)
    f = jax.jit(lambda a, b: Q.query(store, a, b))
    dt = _time(f, pp, pl)
    return dt / B * 1e6, {"scans_per_s": round(B / dt),
                          "rows": store.n_pad}


def bench_planner_scan(B=1024):
    """Planner entry point (single-device executor, jitted) — the path the
    serving engine now takes; comparable to bench_binary_search."""
    store = build_tablet_store(random_dna(1_000_000, seed=2), is_dna=True)
    planner = ScanPlanner(store)
    pats = Q.random_patterns(B, 1, 100, seed=3)
    _, pp, pl = Q.encode_patterns(pats, 112)
    dt = _time(lambda a, b: planner.scan_encoded(a, b), pp, pl)
    return dt / B * 1e6, {"scans_per_s": round(B / dt),
                          "rows": store.n_pad,
                          "mode": planner.plan(B).mode}


def bench_pack_throughput(n=4_000_000):
    codes = random_dna(n, seed=4)
    f = jax.jit(codec.pack_2bit)
    dt = _time(f, codes)
    return dt / n * 1e6, {"mbase_per_s": round(n / dt / 1e6, 1)}

"""LSM compaction benchmark: merge-based major compaction vs full rebuild,
and sustained append throughput with minor compaction.

Measures, over a ``repro.api.SuffixTable``:

* ``major_merge`` / ``major_rebuild`` — folding a small append delta
  (default 5% of the base) into the base suffix array via the merge path
  (``repro.api.compaction``: dirty-range prefix doubling + batched
  window-compare insertion) vs the old from-scratch rebuild, same data;
* ``append_flat`` — sustained ingest (append + probe read per chunk)
  with one ever-growing memtable, the pre-run-tier behaviour;
* ``append_minor`` — the same ingest with ``memtable_limit`` sealing the
  memtable into immutable runs, which bounds the per-read index rebuild;
* ``read_with_runs`` — merged-read cost with live runs vs base-only;
* an exactness check of merged reads against the Algorithm 1 brute-force
  oracle, with live runs and after the merge compaction.

Writes ``BENCH_compaction.json`` at the repo root.  ``--smoke`` shrinks
every dimension for the weekly CI job.

    PYTHONPATH=src python benchmarks/compaction_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--delta-frac", type=float, default=0.05)
    ap.add_argument("--append-chunk", type=int, default=500)
    ap.add_argument("--memtable-limit", type=int, default=2_000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.text_len, args.append_chunk = 20_000, 250
        args.memtable_limit, args.batch, args.reps = 1_000, 32, 5
    return args


def _ingest(table, chunks, probe):
    """Append chunks, paying the per-append index rebuild via one probe
    read each (the memtable/run stores are rebuilt lazily on read)."""
    patt, plen = probe
    t0 = time.perf_counter()
    for c in chunks:
        table.append(c)
        table.scan_encoded(patt, plen)
    return time.perf_counter() - t0


def _time_compaction(make_table, *, merge: bool, reps: int) -> float:
    """Median wall time of one major compaction (state is consumed, so a
    fresh table is built per rep; construction is outside the clock)."""
    times = []
    for _ in range(reps):
        table = make_table()
        if not merge:
            # force the pre-merge behaviour: from-scratch rebuild
            import repro.api.table as T
            combined = np.concatenate(
                [table._codes, table._delta_codes()])
            t0 = time.perf_counter()
            sa_real = np.asarray(
                T.build_suffix_array(combined.astype(np.int32)))
            table._codes = combined
            table._attach(combined, sa_real)
            dt = time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            table.compact()
            dt = time.perf_counter() - t0
        times.append(dt)
    return float(np.median(times))


def run(args) -> dict:
    from repro.api import SuffixTable
    from repro.core import codec, query as Q

    n = args.text_len
    d = int(n * args.delta_frac)
    codes = codec.random_dna(n, seed=0)
    delta = codec.random_dna(d, seed=1)
    pats = Q.random_patterns(args.batch, 1, 100, seed=2)

    def fresh_with_delta():
        t = SuffixTable.from_codes(codes, is_dna=True)
        t.append(delta)
        t.minor_compact()
        return t

    # warm both paths once (jit compilation priced out of the medians)
    _time_compaction(fresh_with_delta, merge=True, reps=1)
    _time_compaction(fresh_with_delta, merge=False, reps=1)
    merge_s = _time_compaction(fresh_with_delta, merge=True, reps=args.reps)
    rebuild_s = _time_compaction(fresh_with_delta, merge=False,
                                 reps=args.reps)

    # exactness: merged reads with a live run + after merge compaction
    t = fresh_with_delta()
    combined = np.concatenate([codes, delta])
    probes = pats[:16] + [codec.decode_dna(combined[n - 4:n + 6])]
    live = t.scan(probes)
    t.compact()
    post = t.scan(probes)
    exact = True
    for i, p in enumerate(probes):
        pc = codec.encode_dna(p).astype(np.int32)
        want, _ = Q.brute_force_count(combined.astype(np.int32), pc)
        exact &= int(live.count[i]) == want == int(post.count[i])

    # sustained ingest: flat memtable vs minor-compaction run tier
    n_chunks = max(4, d // args.append_chunk)
    chunks = [codec.random_dna(args.append_chunk, seed=10 + i)
              for i in range(n_chunks)]
    appended = n_chunks * args.append_chunk

    flat = SuffixTable.from_codes(codes, is_dna=True)
    probe = flat.planner.encode(pats[:1])
    flat_s = _ingest(flat, chunks, probe)
    minor = SuffixTable.from_codes(codes, is_dna=True,
                                   memtable_limit=args.memtable_limit)
    minor_s = _ingest(minor, chunks, probe)

    # merged read overhead with the run tier live (median of per-rep
    # wall times — single-batch timings at these sizes are noisy)
    import jax

    def _read_time(table, patt, plen, reps):
        jax.block_until_ready(table.scan_encoded(patt, plen).count)  # warm
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(table.scan_encoded(patt, plen).count)
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    read_reps = max(args.reps, 10)
    patt, plen = minor.planner.encode(pats)
    runs_dt = _read_time(minor, patt, plen, read_reps)
    base_only = SuffixTable.from_codes(codes, is_dna=True)
    base_dt = _read_time(base_only, patt, plen, read_reps)

    return {
        "bench": "lsm_compaction",
        "text_len": n,
        "delta_len": d,
        "append_chunk": args.append_chunk,
        "memtable_limit": args.memtable_limit,
        "results": {
            "major_merge_s": round(merge_s, 4),
            "major_rebuild_s": round(rebuild_s, 4),
            "merge_speedup_x": round(rebuild_s / max(merge_s, 1e-9), 2),
            "merge_bases_per_s": round((n + d) / max(merge_s, 1e-9)),
            "append_flat_bases_per_s": round(appended / flat_s),
            "append_minor_bases_per_s": round(appended / minor_s),
            "append_speedup_x": round(flat_s / max(minor_s, 1e-9), 2),
            "runs_live_after_ingest": len(minor.runs),
            "read_with_runs_us_per_query":
                round(runs_dt / args.batch * 1e6, 3),
            "read_base_us_per_query":
                round(base_dt / args.batch * 1e6, 3),
            "read_with_runs_over_base_x":
                round(runs_dt / max(base_dt, 1e-9), 2),
            "exact_vs_brute_force": bool(exact),
        },
    }


def bench_compaction():
    """benchmarks/run.py entry: (major_merge_ms, derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    return (payload["results"]["major_merge_s"] * 1e3,
            payload["results"])


def main() -> None:
    args = _parse()
    payload = run(args)
    for k, v in payload["results"].items():
        print(f"{k}: {v}", flush=True)
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_compaction.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""Serving observability benchmark: the tentpole's proof-of-work.

Three questions, one scripted load run (docs/observability.md):

* **served latency from the feed** — the load runs through the real
  ``Database``/``QueryScheduler`` path with the table's metrics
  emitter streaming ``stats()`` into a ``metrics.jsonl`` feed; the
  reported p50/p95 are aggregated FROM THAT FEED (the same rows
  ``serve.py --dump-stats`` and ``check_regression.py --from-feed``
  read), so the number gated in CI is what a serving process actually
  recorded about itself, not a bench-side stopwatch;
* **tracing overhead** — the per-query tracing layer must be ~free on
  the inline fast path: per-call latency is measured with the tracers
  enabled vs disabled (min-of-alternating-reps to kill scheduler
  noise) and reported as ``trace_overhead_x`` (gated, lower-better);
  results are checked bit-identical across both arms;
* **tuned launcher effect** — a fresh subprocess imports jax and runs
  one dispatch under the default env vs the ``--tuned`` preset
  (TF_CPP_MIN_LOG_LEVEL=4 + tcmalloc report threshold; the LD_PRELOAD
  half lives in ``launch/run.sh`` and needs the .so present, so it is
  applied when available);  startup seconds and stderr log bytes are
  reported, plus ``tuned_not_noisier`` (the preset must never ADD log
  noise — gated as a flag).

Writes ``BENCH_serving.json`` at the repo root; ``--feed-out PATH``
additionally copies the load run's feed for ``--from-feed`` gating.
``--smoke`` shrinks every dimension for the weekly CI job.

    PYTHONPATH=src python benchmarks/serving_bench.py [--smoke] \\
        [--feed-out bench-out/serving_feed.jsonl]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--concurrency", type=int, default=128,
                    help="simulated concurrent callers per load wave")
    ap.add_argument("--waves", type=int, default=4,
                    help="load waves through the scheduler")
    ap.add_argument("--probe-calls", type=int, default=60,
                    help="sequential per-call probes per overhead rep")
    ap.add_argument("--reps", type=int, default=3,
                    help="alternating enabled/disabled overhead reps")
    ap.add_argument("--max-pattern", type=int, default=24)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--feed-out", default=None,
                    help="copy the load run's metrics.jsonl here "
                         "(input for check_regression --from-feed)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.text_len, args.concurrency = 20_000, 32
        args.waves, args.probe_calls = 2, 30
    if args.concurrency < 1 or args.waves < 1 or args.probe_calls < 1:
        ap.error("need positive --concurrency/--waves/--probe-calls")
    return args


def _rand_patterns(rng, n: int, max_len: int) -> list[str]:
    lens = rng.integers(3, max(4, max_len), size=n)
    return ["".join(rng.choice(list("ACGT"), size=int(L)))
            for L in lens]


def _set_tracers(db, table, enabled: bool) -> None:
    table.tracer.enabled = enabled
    db.scheduler.tracer.enabled = enabled


def _served_load(args, db, table, name: str, feed_path: str) -> dict:
    """Scripted load with the feed on; served stats come FROM the feed."""
    from repro.api import Query
    from repro.serving.metrics import aggregate_metrics

    rng = np.random.default_rng(7)

    def wave():
        pats = _rand_patterns(rng, args.concurrency, args.max_pattern)
        futs = [db.submit(Query.count(name, [p])) for p in pats]
        for f in futs:
            r = f.result(timeout=60.0)
            assert r.ok, r.error
        # plus one coalesced burst per wave (query_many inline path)
        out = db.query_many([Query.scan(name, pats[:8], top_k=4)])
        assert all(r.ok for r in out)
        return len(futs) + 8

    # unrecorded warmup first, so the feed describes steady-state
    # serving rather than one-time jit spikes: batches pad to
    # power-of-two buckets, so compile every bucket the load can hit
    # (count path up to `concurrency`, the top-k scan-burst bucket),
    # then run one throwaway wave for the scheduler's adaptive state
    b = 1
    while b <= args.concurrency:
        pats = _rand_patterns(rng, b, args.max_pattern)
        assert all(r.ok for r in db.query_many(
            [Query.count(name, [p]) for p in pats]))
        b *= 2
    assert all(r.ok for r in db.query_many(
        [Query.scan(name, _rand_patterns(rng, 8, args.max_pattern),
                    top_k=4)]))
    wave()
    table.tracer.reset()
    table.start_metrics(feed_path, interval_s=0.2, name=name)
    t0 = time.perf_counter()
    n_queries = 0
    for _ in range(args.waves):
        n_queries += wave()
    wall_s = time.perf_counter() - t0
    table.stop_metrics()               # final row carries the last word
    agg = aggregate_metrics(feed_path)["summary"]
    return {
        "queries": int(n_queries),
        "wall_s": round(wall_s, 3),
        "queries_per_s": round(n_queries / max(wall_s, 1e-9), 1),
        "feed_emitters": int(agg["emitters"]),
        "feed_queries": int(agg["queries"]),
        "p50_ms": agg["p50_ms_median"],
        "p95_ms": agg["p95_ms_max"],
    }


def _overhead(args, db, table, name: str) -> dict:
    """Per-call fast-path latency, tracers enabled vs disabled —
    min-of-alternating-reps so one GC hiccup can't fake a regression."""
    from repro.api import Query

    rng = np.random.default_rng(11)
    pats = _rand_patterns(rng, args.probe_calls, args.max_pattern)
    db.query(Query.count(name, [pats[0]]))        # warm the jit caches

    def arm(enabled: bool):
        _set_tracers(db, table, enabled)
        table.planner.invalidate_cache()          # no cache cross-talk
        lat = []
        keys = []
        for p in pats:
            t0 = time.perf_counter()
            r = db.query(Query.count(name, [p]))
            lat.append((time.perf_counter() - t0) * 1e3)
            keys.append((int(r.count[0]), int(r.first_pos[0])))
        return float(np.median(lat)), keys

    on_best, off_best = float("inf"), float("inf")
    on_keys = off_keys = None
    for _ in range(args.reps):
        m, k = arm(True)
        on_best, on_keys = min(on_best, m), k
        m, k = arm(False)
        off_best, off_keys = min(off_best, m), k
    _set_tracers(db, table, True)
    return {
        "p50_on_ms": round(on_best, 4),
        "p50_off_ms": round(off_best, 4),
        "trace_overhead_x": round(on_best / max(off_best, 1e-9), 3),
        "bit_identical": on_keys == off_keys,
    }


_STARTUP_CODE = (
    "import time,sys; t0=time.perf_counter(); "
    "import jax, jax.numpy as jnp; "
    "jnp.zeros(16).block_until_ready(); "
    "print(round(time.perf_counter()-t0, 3))"
)


def _startup(env_extra: dict) -> tuple[float, int]:
    """(import+first-dispatch seconds, stderr bytes) in a fresh child."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.update(env_extra)
    proc = subprocess.run([sys.executable, "-c", _STARTUP_CODE],
                          capture_output=True, text=True, env=env,
                          timeout=300)
    if proc.returncode != 0:
        raise RuntimeError(f"startup probe failed: {proc.stderr[-500:]}")
    return float(proc.stdout.strip().splitlines()[-1]), len(proc.stderr)


def _tuned_effect() -> dict:
    """Default env vs the --tuned preset (plus tcmalloc when the .so
    exists — the launch/run.sh half), one fresh subprocess each."""
    tuned_env = {"TF_CPP_MIN_LOG_LEVEL": "4",
                 "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000"}
    for so in ("/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
               "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4"):
        if os.path.exists(so):
            tuned_env["LD_PRELOAD"] = so
            break
    default_s, default_log = _startup({"TF_CPP_MIN_LOG_LEVEL": "2"})
    tuned_s, tuned_log = _startup(tuned_env)
    return {
        "startup_default_s": default_s,
        "startup_tuned_s": tuned_s,
        "log_bytes_default": default_log,
        "log_bytes_tuned": tuned_log,
        "tcmalloc_preloaded": "LD_PRELOAD" in tuned_env,
        "tuned_not_noisier": tuned_log <= default_log,
    }


def run(args) -> dict:
    from repro.api import Database, SuffixTable
    from repro.core.codec import random_dna

    table = SuffixTable.from_codes(random_dna(args.text_len, seed=0),
                                   is_dna=True)
    db = Database.in_memory()
    name = "serving_bench"
    db.attach(name, table)

    tmp = tempfile.mkdtemp(prefix="serving_bench_")
    feed_path = os.path.join(tmp, "metrics.jsonl")
    try:
        served = _served_load(args, db, table, name, feed_path)
        overhead = _overhead(args, db, table, name)
        if args.feed_out:
            os.makedirs(os.path.dirname(os.path.abspath(args.feed_out)),
                        exist_ok=True)
            shutil.copyfile(feed_path, args.feed_out)
            print(f"feed copied to {args.feed_out}", flush=True)
    finally:
        db.close()
        shutil.rmtree(tmp, ignore_errors=True)
    tuned = _tuned_effect()
    return {
        "bench": "serving_observability",
        "text_len": args.text_len,
        "concurrency": args.concurrency,
        "waves": args.waves,
        "probe_calls": args.probe_calls,
        "reps": args.reps,
        "results": {
            "served": served,
            **overhead,
            **tuned,
        },
    }


def bench_serving():
    """benchmarks/run.py entry: (us_per_served_query, derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    r = payload["results"]
    us = 1e6 / max(r["served"]["queries_per_s"], 1)
    return us, {"trace_overhead_x": r["trace_overhead_x"],
                "served_p50_ms": r["served"]["p50_ms"],
                "tuned_not_noisier": r["tuned_not_noisier"]}


def main() -> None:
    args = _parse()
    payload = run(args)

    def flat(d, pre=""):
        for k, v in d.items():
            if isinstance(v, dict):
                flat(v, pre + k + ".")
            else:
                print(f"{pre}{k}: {v}", flush=True)

    flat(payload["results"])
    r = payload["results"]
    if not r["bit_identical"]:
        raise SystemExit("FAIL: results diverge with tracing disabled")
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serving.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

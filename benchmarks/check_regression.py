"""Bench regression gate: compare a fresh ``--smoke`` run's JSON against
the committed ``BENCH_*.json`` baseline and fail on a real regression.

The perf surface (BENCH_planner / table / compaction / client / wal) was
write-only until now: the weekly job produced numbers nobody compared.
This gate makes it regression-checked:

* **throughput metrics** (``*_per_s``, ``*speedup*``, ``*_rate``) must
  not fall more than ``--threshold`` (default 25%) below the baseline;
* **overhead ratios** (``*overhead*``, ``*_over_*`` like
  ``read_with_runs_over_base_x``) and the low-load latency target
  (``coalesced_low_load_p50_ms``) must not rise more than the
  threshold above it;
* **boolean exactness flags** (``bit_identical``, ``exact_*``,
  ``recovered_all_acked``) that are true in the baseline must stay true
  — a correctness regression is never a matter of degree;
* **latency metrics** (``*_ms``, ``*_us_per_*``, ``*_s``) and plain
  counts are reported but not gated: on shared CI runners their noise
  swamps a 25% band, and every latency win already shows up in a gated
  throughput metric.

When the candidate's config (every top-level key except ``results``)
differs from the baseline's — e.g. a full-size committed baseline vs a
``--smoke`` candidate — absolute throughput is not comparable, so only
the scale-invariant metrics (speedups, overheads, rates, booleans) are
gated and a warning says so.  To tighten the gate, refresh the baseline
at smoke sizes (docs/ci.md).

    python benchmarks/check_regression.py \\
        --pair BENCH_table.json=artifacts/BENCH_table.json \\
        --pair BENCH_wal.json=artifacts/BENCH_wal.json [--threshold 0.25]
"""
from __future__ import annotations

import argparse
import json
import sys


def flatten(d: dict, prefix: str = "") -> dict:
    out = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, key + "."))
        else:
            out[key] = v
    return out


def is_scale_invariant(name: str) -> bool:
    """Ratios and flags keep their meaning across workload sizes."""
    n = name.lower()
    return ("speedup" in n or "overhead" in n or n.endswith("_x")
            or n.endswith("_rate") or "identical" in n or "exact" in n
            or "recovered" in n)


def classify(name: str, value) -> str:
    """'higher' / 'lower' (gated directions), 'flag', or 'info'."""
    n = name.lower()
    if isinstance(value, bool):
        return "flag"
    if not isinstance(value, (int, float)):
        return "info"
    if "overhead" in n or "_over_" in n or "low_load_p50" in n:
        return "lower"
    if (n.endswith("_per_s") or n.endswith("_per_sec")
            or "queries_per_s" in n or "speedup" in n
            or "scale_factor" in n or "gain" in n
            or n.endswith("_rate")):
        return "higher"
    return "info"


def compare(baseline: dict, candidate: dict, threshold: float,
            label: str) -> list[str]:
    """Returns failure messages (empty = pass); prints a metric table."""
    base_cfg = {k: v for k, v in baseline.items() if k != "results"}
    cand_cfg = {k: v for k, v in candidate.items() if k != "results"}
    cfg_match = base_cfg == cand_cfg
    if not cfg_match:
        diff = {k for k in set(base_cfg) | set(cand_cfg)
                if base_cfg.get(k) != cand_cfg.get(k)}
        print(f"[{label}] WARNING: config differs from baseline "
              f"({sorted(diff)}) — gating only scale-invariant metrics")
    base = flatten(baseline.get("results", {}))
    cand = flatten(candidate.get("results", {}))
    failures = []
    for name in sorted(set(base) & set(cand)):
        b, c = base[name], cand[name]
        kind = classify(name, b)
        gated = kind != "info" and (cfg_match or is_scale_invariant(name))
        if kind == "flag":
            ok = (not b) or bool(c)     # baseline-true must stay true
            verdict = "OK" if ok else "FAIL"
        elif not gated:
            verdict = "info"
            ok = True
        elif kind == "higher":
            ok = c >= b * (1.0 - threshold)
            verdict = "OK" if ok else "FAIL"
        else:                           # lower-better
            ok = c <= b * (1.0 + threshold)
            verdict = "OK" if ok else "FAIL"
        print(f"[{label}] {verdict:>4s}  {name}: baseline={b} "
              f"candidate={c}" + ("" if gated or kind == "flag"
                                  else "  (not gated)"))
        if not ok:
            failures.append(
                f"{label}: {name} regressed past {threshold:.0%} — "
                f"baseline={b}, candidate={c}")
    missing = sorted(set(base) - set(cand))
    if missing:
        failures.append(f"{label}: candidate is missing baseline "
                        f"metrics {missing}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", default=[],
                    metavar="BASELINE=CANDIDATE",
                    help="a baseline/candidate JSON pair (repeatable)")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--candidate", default=None)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max fractional regression (default 0.25)")
    args = ap.parse_args(argv)
    pairs = []
    if args.baseline or args.candidate:
        if not (args.baseline and args.candidate):
            ap.error("--baseline and --candidate go together")
        pairs.append((args.baseline, args.candidate))
    for p in args.pair:
        if "=" not in p:
            ap.error(f"--pair wants BASELINE=CANDIDATE, got {p!r}")
        pairs.append(tuple(p.split("=", 1)))
    if not pairs:
        ap.error("nothing to compare — pass --pair or "
                 "--baseline/--candidate")
    if not 0 < args.threshold < 1:
        ap.error("--threshold must be in (0, 1)")

    failures = []
    for base_path, cand_path in pairs:
        label = base_path.rsplit("/", 1)[-1]
        with open(base_path) as f:
            baseline = json.load(f)
        with open(cand_path) as f:
            candidate = json.load(f)
        if baseline.get("bench") != candidate.get("bench"):
            failures.append(f"{label}: bench id mismatch "
                            f"({baseline.get('bench')} vs "
                            f"{candidate.get('bench')})")
            continue
        failures.extend(compare(baseline, candidate, args.threshold,
                                label))
    if failures:
        print("\nREGRESSIONS DETECTED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(f"\nall gated metrics within {args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Bench regression gate: compare a fresh ``--smoke`` run's JSON against
the committed ``BENCH_*.json`` baseline and fail on a real regression.

The perf surface (BENCH_planner / table / compaction / client / wal) was
write-only until now: the weekly job produced numbers nobody compared.
This gate makes it regression-checked:

* **throughput metrics** (``*_per_s``, ``*speedup*``, ``*_rate``) must
  not fall more than ``--threshold`` (default 25%) below the baseline;
* **overhead ratios** (``*overhead*``, ``*_over_*`` like
  ``read_with_runs_over_base_x``) and the low-load latency target
  (``coalesced_low_load_p50_ms``) must not rise more than the
  threshold above it;
* **boolean exactness flags** (``bit_identical``, ``exact_*``,
  ``recovered_all_acked``) that are true in the baseline must stay true
  — a correctness regression is never a matter of degree;
* **latency metrics** (``*_ms``, ``*_us_per_*``, ``*_s``) and plain
  counts are reported but not gated: on shared CI runners their noise
  swamps a 25% band, and every latency win already shows up in a gated
  throughput metric.

When the candidate's config (every top-level key except ``results``)
differs from the baseline's — e.g. a full-size committed baseline vs a
``--smoke`` candidate — absolute throughput is not comparable, so only
the scale-invariant metrics (speedups, overheads, rates, booleans) are
gated and a warning says so.  To tighten the gate, refresh the baseline
at smoke sizes (docs/ci.md).

    python benchmarks/check_regression.py \\
        --pair BENCH_table.json=artifacts/BENCH_table.json \\
        --pair BENCH_wal.json=artifacts/BENCH_wal.json [--threshold 0.25]

``--from-feed`` gates what a serving process ACTUALLY did, not an
offline bench: it aggregates a ``metrics.jsonl`` feed left by a
scripted load run (``benchmarks/serving_bench.py --feed-out``, or any
live ``serve.py --metrics-interval`` / plane deployment) and compares
the served p50/p95 against the committed ``BENCH_serving.json``
baseline.  Feed latencies cross machines, so the bound is a sanity
ratio (``--feed-ratio``, default 3.0: fail only when served latency is
3x the baseline) — wide enough for runner-to-runner variance, tight
enough to catch a serving-path pathology (docs/ci.md):

    python benchmarks/check_regression.py \\
        --from-feed bench-out/serving_feed.jsonl \\
        --feed-baseline BENCH_serving.json [--feed-ratio 3.0]

This mode parses the feed locally (stdlib only — CI invokes this
script without ``PYTHONPATH=src``), mirroring
``repro.serving.metrics.aggregate_metrics`` semantics: latest row per
emitter; served p50 = median of per-emitter p50s, p95 = max.
"""
from __future__ import annotations

import argparse
import json
import sys


def flatten(d: dict, prefix: str = "") -> dict:
    out = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, key + "."))
        else:
            out[key] = v
    return out


def is_scale_invariant(name: str) -> bool:
    """Ratios and flags keep their meaning across workload sizes."""
    n = name.lower()
    return ("speedup" in n or "overhead" in n or n.endswith("_x")
            or n.endswith("_rate") or "identical" in n or "exact" in n
            or "recovered" in n)


def classify(name: str, value) -> str:
    """'higher' / 'lower' (gated directions), 'flag', or 'info'."""
    n = name.lower()
    if isinstance(value, bool):
        return "flag"
    if not isinstance(value, (int, float)):
        return "info"
    if "overhead" in n or "_over_" in n or "low_load_p50" in n:
        return "lower"
    if (n.endswith("_per_s") or n.endswith("_per_sec")
            or "queries_per_s" in n or "speedup" in n
            or "scale_factor" in n or "gain" in n
            or n.endswith("_rate")):
        return "higher"
    return "info"


def compare(baseline: dict, candidate: dict, threshold: float,
            label: str) -> list[str]:
    """Returns failure messages (empty = pass); prints a metric table."""
    base_cfg = {k: v for k, v in baseline.items() if k != "results"}
    cand_cfg = {k: v for k, v in candidate.items() if k != "results"}
    cfg_match = base_cfg == cand_cfg
    if not cfg_match:
        diff = {k for k in set(base_cfg) | set(cand_cfg)
                if base_cfg.get(k) != cand_cfg.get(k)}
        print(f"[{label}] WARNING: config differs from baseline "
              f"({sorted(diff)}) — gating only scale-invariant metrics")
    base = flatten(baseline.get("results", {}))
    cand = flatten(candidate.get("results", {}))
    failures = []
    for name in sorted(set(base) & set(cand)):
        b, c = base[name], cand[name]
        kind = classify(name, b)
        gated = kind != "info" and (cfg_match or is_scale_invariant(name))
        if kind == "flag":
            ok = (not b) or bool(c)     # baseline-true must stay true
            verdict = "OK" if ok else "FAIL"
        elif not gated:
            verdict = "info"
            ok = True
        elif kind == "higher":
            ok = c >= b * (1.0 - threshold)
            verdict = "OK" if ok else "FAIL"
        else:                           # lower-better
            ok = c <= b * (1.0 + threshold)
            verdict = "OK" if ok else "FAIL"
        print(f"[{label}] {verdict:>4s}  {name}: baseline={b} "
              f"candidate={c}" + ("" if gated or kind == "flag"
                                  else "  (not gated)"))
        if not ok:
            failures.append(
                f"{label}: {name} regressed past {threshold:.0%} — "
                f"baseline={b}, candidate={c}")
    missing = sorted(set(base) - set(cand))
    if missing:
        failures.append(f"{label}: candidate is missing baseline "
                        f"metrics {missing}")
    return failures


def aggregate_feed(path: str) -> dict:
    """Stdlib-only ``metrics.jsonl`` aggregation (same semantics as
    ``repro.serving.metrics.aggregate_metrics``, re-implemented here so
    this script needs no PYTHONPATH): latest line per emitter; served
    p50 = median of per-emitter p50s over the query-serving roles
    (plane workers and in-process tables), p95 = worst emitter."""
    latest: dict = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue                       # torn line: skip
            key = (rec.get("role", "worker"), rec.get("tablet"),
                   rec.get("replica"), rec.get("pid"), rec.get("table"))
            cur = latest.get(key)
            if cur is None or rec.get("ts", 0) >= cur.get("ts", 0):
                latest[key] = rec
    serving = [r for r in latest.values()
               if r.get("role", "worker") in ("worker", "table")]
    p50s = sorted(float(r.get("p50_ms") or 0.0) for r in serving)
    return {
        "emitters": len(latest),
        "serving_emitters": len(serving),
        "queries": sum(int(r.get("queries") or 0) for r in serving),
        "p50_ms": (p50s[len(p50s) // 2] if p50s else 0.0),
        "p95_ms": max((float(r.get("p95_ms") or 0.0) for r in serving),
                      default=0.0),
    }


def check_feed(feed_path: str, baseline_path: str,
               ratio: float) -> list[str]:
    """Gate the feed's served p50/p95 against the ``served.*`` block of
    the BENCH_serving baseline.  Returns failure messages."""
    agg = aggregate_feed(feed_path)
    print(f"[feed] {feed_path}: emitters={agg['emitters']} "
          f"serving={agg['serving_emitters']} queries={agg['queries']} "
          f"served p50={agg['p50_ms']}ms p95={agg['p95_ms']}ms")
    failures = []
    if agg["serving_emitters"] == 0 or agg["queries"] == 0:
        failures.append(f"feed: {feed_path} has no serving emitters / "
                        f"queries — the load run left no usable rows")
        return failures
    with open(baseline_path) as f:
        baseline = json.load(f)
    served = flatten(baseline.get("results", {}))
    gated = False
    for q in ("p50_ms", "p95_ms"):
        b = served.get(f"served.{q}")
        if not isinstance(b, (int, float)) or b <= 0:
            continue
        gated = True
        c = agg[q]
        ok = c <= b * ratio
        print(f"[feed] {'OK' if ok else 'FAIL':>4s}  served.{q}: "
              f"baseline={b} candidate={c} (bound {ratio:g}x)")
        if not ok:
            failures.append(f"feed: served {q}={c} exceeds {ratio:g}x "
                            f"the baseline {b}")
    if not gated:
        failures.append(f"feed: baseline {baseline_path} has no "
                        f"positive served.p50_ms/p95_ms to gate against")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", default=[],
                    metavar="BASELINE=CANDIDATE",
                    help="a baseline/candidate JSON pair (repeatable)")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--candidate", default=None)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max fractional regression (default 0.25)")
    ap.add_argument("--from-feed", default=None, metavar="FEED.jsonl",
                    help="gate served p50/p95 aggregated from this "
                         "metrics.jsonl feed (stdlib parsing, no "
                         "PYTHONPATH needed)")
    ap.add_argument("--feed-baseline", default="BENCH_serving.json",
                    help="baseline JSON whose results.served block the "
                         "feed is gated against")
    ap.add_argument("--feed-ratio", type=float, default=3.0,
                    help="max served-latency ratio vs baseline "
                         "(cross-machine sanity bound, default 3.0)")
    args = ap.parse_args(argv)
    pairs = []
    if args.baseline or args.candidate:
        if not (args.baseline and args.candidate):
            ap.error("--baseline and --candidate go together")
        pairs.append((args.baseline, args.candidate))
    for p in args.pair:
        if "=" not in p:
            ap.error(f"--pair wants BASELINE=CANDIDATE, got {p!r}")
        pairs.append(tuple(p.split("=", 1)))
    if not pairs and args.from_feed is None:
        ap.error("nothing to compare — pass --pair, "
                 "--baseline/--candidate, or --from-feed")
    if not 0 < args.threshold < 1:
        ap.error("--threshold must be in (0, 1)")
    if args.feed_ratio <= 1.0:
        ap.error("--feed-ratio must be > 1")

    failures = []
    if args.from_feed is not None:
        failures.extend(check_feed(args.from_feed, args.feed_baseline,
                                   args.feed_ratio))
    for base_path, cand_path in pairs:
        label = base_path.rsplit("/", 1)[-1]
        with open(base_path) as f:
            baseline = json.load(f)
        with open(cand_path) as f:
            candidate = json.load(f)
        if baseline.get("bench") != candidate.get("bench"):
            failures.append(f"{label}: bench id mismatch "
                            f"({baseline.get('bench')} vs "
                            f"{candidate.get('bench')})")
            continue
        failures.extend(compare(baseline, candidate, args.threshold,
                                label))
    if failures:
        print("\nREGRESSIONS DETECTED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(f"\nall gated metrics within {args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness: one function per paper table + engine micro-benches +
the roofline summary (read from dry-run artifacts).

Prints ``name,us_per_call,derived`` CSV as required.
"""
from __future__ import annotations

import json
import sys


def main() -> None:
    from benchmarks import build_bench, client_bench, compaction_bench, \
        fm_bench, kernel_bench, paper_tables, plane_bench, roofline, \
        serving_bench, table_bench, wal_bench

    benches = [
        ("table1_preprocess_build", paper_tables.bench_build_table1),
        ("table3_single_process_scans", paper_tables.bench_single_table3),
        ("table4_multi_user_scans", paper_tables.bench_multi_table4),
        ("table5_correlations", paper_tables.bench_correlation_table5),
        ("fig1_latency_histogram", paper_tables.bench_histogram_fig1),
        ("kernel_pattern_compare", kernel_bench.bench_pattern_compare),
        ("kernel_binary_search_1M_rows", kernel_bench.bench_binary_search),
        ("planner_scan_1M_rows", kernel_bench.bench_planner_scan),
        ("kernel_pack_2bit", kernel_bench.bench_pack_throughput),
        ("table_merged_scan", table_bench.bench_table_ops),
        ("lsm_compaction", compaction_bench.bench_compaction),
        ("fm_frozen_tier", fm_bench.bench_fm),
        ("client_coalescing", client_bench.bench_client),
        ("wal_group_commit", wal_bench.bench_wal),
        ("staged_build", build_bench.bench_build),
        ("plane_swarm", plane_bench.bench_plane),
        ("serving_observability", serving_bench.bench_serving),
    ]
    print("name,us_per_call,derived")
    for name, fn in benches:
        try:
            us, derived = fn()
            print(f"{name},{us:.3f},\"{json.dumps(derived)}\"", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,\"{type(e).__name__}: {e}\"", flush=True)

    summary = roofline.summarize()
    print(f"roofline_cells,0,\"{json.dumps(summary)}\"")


if __name__ == "__main__":
    main()

"""One benchmark per paper table (Giacomelli 2020 §IV-V).

Table I  (pre-processing): suffix-array construction throughput; the paper
          reports 17 min for chr1 on 2 VMs — we report Mbase/s and the
          chr1-extrapolated wall time.
Table III (single process, 10k scans): per-scan latency stats + hit rate.
Table IV  (50 threads): 50-wide batches — the TPU analogue of threads.
Table V   (correlations): corr(len, time), corr(len, outcome).
Figure 1  (latency histogram): bucket counts emitted as derived values.

All numbers are measured on the real engine (jit'd JAX on this host);
the simulated-latency service stats (serving.HedgedScanService) cover the
distributional claims (tail, hedging).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import query as Q
from repro.core.codec import random_dna
from repro.core.tablet import build_tablet_store
from repro.serving import HedgedScanService

TEXT_N = 200_000
_STORE = None


def _store():
    global _STORE
    if _STORE is None:
        _STORE = build_tablet_store(random_dna(TEXT_N, seed=1), is_dna=True)
    return _STORE


def bench_build_table1():
    """Returns (us_per_call, derived) — derived = extrapolated chr1 minutes."""
    rows = []
    for n in (100_000, 400_000):
        codes = random_dna(n, seed=n)
        t0 = time.perf_counter()
        store = build_tablet_store(codes, is_dna=True)
        jax.block_until_ready(store.sa)
        dt = time.perf_counter() - t0
        rows.append((n, dt))
    n, dt = rows[-1]
    mbase_s = n / dt / 1e6
    # paper: 250 Mbp chromosome 1, 17 minutes on 2 VMs.
    chr1_minutes = 250e6 / (mbase_s * 1e6) / 60
    return dt / n * 1e6, {"mbase_per_s": round(mbase_s, 3),
                          "chr1_extrapolated_min": round(chr1_minutes, 1),
                          "paper_min": 17}


def _run_scans(total: int, batch: int, seed: int):
    store = _store()
    lat, outs, lens = [], [], []
    jq = jax.jit(lambda pp, pl: Q.query(store, pp, pl))
    # warmup
    pats = Q.random_patterns(batch, 1, 100, seed=(seed, 999))
    _, pp, pl = Q.encode_patterns(pats, 112)
    jax.block_until_ready(jq(pp, pl).count)
    done = 0
    b = 0
    while done < total:
        pats = Q.random_patterns(batch, 1, 100, seed=(seed, b))
        _, pp, pl = Q.encode_patterns(pats, 112)
        t0 = time.perf_counter()
        res = jq(pp, pl)
        jax.block_until_ready(res.count)
        dt = time.perf_counter() - t0
        lat.append(dt / batch * 1e6)            # us per scan
        outs.append(np.asarray(res.found))
        lens.append(np.asarray(pl))
        done += batch
        b += 1
    return (np.asarray(lat), np.concatenate(outs)[:total],
            np.concatenate(lens)[:total])


def bench_single_table3(total=10_000, batch=100):
    lat, outs, lens = _run_scans(total, batch, seed=3)
    return float(lat.mean()), {
        "n": total, "mean_us": round(float(lat.mean()), 2),
        "sd_us": round(float(lat.std()), 2),
        "min_us": round(float(lat.min()), 2),
        "max_us": round(float(lat.max()), 2),
        "hit_rate": round(float(outs.mean()), 4),
        "paper_hit_rate": 0.072,
    }


def bench_multi_table4(total=10_000, batch=50):
    """50 concurrent scans per step == the paper's 50 threads."""
    lat, outs, lens = _run_scans(total, batch, seed=4)
    svc = HedgedScanService(_store())
    sim = svc.run_workload(20_000, batch=2000, hedged=False, seed=4)
    hedged = svc.run_workload(20_000, batch=2000, hedged=True, seed=4)
    return float(lat.mean()), {
        "measured_mean_us_per_scan": round(float(lat.mean()), 2),
        "hit_rate": round(float(outs.mean()), 4),
        "paper_hit_rate": 0.080,
        "sim_mean_ms": round(sim["mean_ms"], 2),
        "sim_max_ms": round(sim["max_ms"], 1),
        "paper_mean_ms": 5.258, "paper_max_ms": 771,
        "hedged_max_ms": round(hedged["max_ms"], 1),
        "hedged_p99_ms": round(hedged["p99_ms"], 2),
    }


def bench_correlation_table5(total=20_000):
    svc = HedgedScanService(_store())
    stats = svc.run_workload(total, batch=2000, hedged=False, seed=5)
    return 0.0, {
        "corr_len_time": round(stats["corr_len_time"], 3),
        "corr_len_outcome": round(stats["corr_len_outcome"], 3),
        "paper_corr_len_time": 0.013,
        "paper_corr_len_outcome": -0.469,
    }


def bench_histogram_fig1(total=10_000):
    svc = HedgedScanService(_store())
    stats_lat = []
    rng_stats = svc.run_workload(total, batch=2000, hedged=False, seed=6)
    # bucket the simulated reply times like Figure 1
    lat = []
    svc.seed = 60
    for b in range(5):
        pats = Q.random_patterns(2000, 1, 100, seed=(6, b))
        _, pp, pl = Q.encode_patterns(pats, 112)
        _, l = svc.scan(pp, pl, hedged=False)
        lat.append(l)
    lat = np.concatenate(lat)
    hist, edges = np.histogram(lat, bins=[0, 2, 4, 6, 8, 10, 15, 20, 50,
                                          1e9])
    return 0.0, {"buckets_ms": [0, 2, 4, 6, 8, 10, 15, 20, 50],
                 "counts": hist.tolist()}

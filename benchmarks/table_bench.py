"""Table API benchmark: ingest throughput and merged-read overhead.

Measures, over a ``repro.api.SuffixTable``:

* ``create``          — initial build throughput (bases/s);
* ``append``          — memtable ingest throughput including the first
                        post-append read (which pays the memtable rebuild);
* ``read_base``       — encoded scan throughput with an empty memtable
                        (pure planner delegation);
* ``read_merged``     — the same batch with a populated memtable (base +
                        memtable fan-out and host-side merge);
* ``compact``         — fold-into-base throughput (bases/s).

Writes ``BENCH_table.json`` at the repo root.  ``--smoke`` shrinks every
dimension for the weekly CI job.

    PYTHONPATH=src python benchmarks/table_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

ARGS = None


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--append-chunk", type=int, default=2_000)
    ap.add_argument("--appends", type=int, default=8)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.text_len, args.batch = 20_000, 64
        args.append_chunk, args.appends, args.reps = 500, 3, 2
    return args


def _time(fn, reps: int) -> float:
    fn()                                       # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    import jax
    jax.block_until_ready(getattr(out, "count", out))
    return (time.perf_counter() - t0) / reps


def run(args) -> dict:
    from repro.api import SuffixTable
    from repro.core import query as Q
    from repro.core.codec import random_dna

    codes = random_dna(args.text_len, seed=0)
    t0 = time.perf_counter()
    table = SuffixTable.from_codes(codes, is_dna=True)
    int(table.count(["ACGT"])[0])              # force build + first read
    create_s = time.perf_counter() - t0

    pats = Q.random_patterns(args.batch, 1, 100, seed=1)
    patt, plen = table.planner.encode(pats)

    base_dt = _time(lambda: table.scan_encoded(patt, plen), args.reps)

    # ingest: append chunks, paying the memtable rebuild via one probe read
    t0 = time.perf_counter()
    for a in range(args.appends):
        table.append(random_dna(args.append_chunk, seed=2 + a))
        table.scan_encoded(patt[:1], plen[:1])
    ingest_s = time.perf_counter() - t0
    appended = args.appends * args.append_chunk

    merged_dt = _time(lambda: table.scan_encoded(patt, plen), args.reps)

    t0 = time.perf_counter()
    table.compact()
    compact_s = time.perf_counter() - t0
    post_dt = _time(lambda: table.scan_encoded(patt, plen), args.reps)

    # exactness spot check: merged reads vs the compacted base
    res = table.scan_encoded(patt, plen)
    probe = SuffixTable.from_codes(
        np.asarray(table.store.text_codes[:table.store.n_real],
                   ).astype(np.uint8), is_dna=True)
    ref = probe.scan_encoded(patt, plen)
    exact = bool((np.asarray(res.count) == np.asarray(ref.count)).all())

    return {
        "bench": "suffix_table_ops",
        "text_len": args.text_len,
        "batch": args.batch,
        "appended": appended,
        "results": {
            "create_bases_per_s": round(args.text_len / create_s),
            "append_bases_per_s": round(appended / ingest_s),
            "read_base_us_per_query": round(base_dt / args.batch * 1e6, 3),
            "read_merged_us_per_query":
                round(merged_dt / args.batch * 1e6, 3),
            "merged_read_overhead_x":
                round(merged_dt / max(base_dt, 1e-12), 3),
            "read_post_compact_us_per_query":
                round(post_dt / args.batch * 1e6, 3),
            "compact_bases_per_s":
                round((args.text_len + appended) / compact_s),
            "exact_vs_rebuilt_base": exact,
        },
    }


def bench_table_ops():
    """benchmarks/run.py entry: (us_per_merged_query, derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    return (payload["results"]["read_merged_us_per_query"],
            payload["results"])


def main() -> None:
    args = _parse()
    payload = run(args)
    for k, v in payload["results"].items():
        print(f"{k}: {v}", flush=True)
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_table.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

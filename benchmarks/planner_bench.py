"""Planner throughput benchmark: broadcast vs routed vs routed+retry.

Runs the three distributed scan executions over a forced multi-device host
mesh (XLA host platform devices) and records queries/second plus retry
rates to ``BENCH_planner.json`` at the repo root — the ISSUE's acceptance
artifact.

    PYTHONPATH=src python benchmarks/planner_bench.py --devices 8
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# must be set before jax initializes its backends
_ap = argparse.ArgumentParser()
_ap.add_argument("--devices", type=int, default=8)
_ap.add_argument("--text-len", type=int, default=200_000)
_ap.add_argument("--batch", type=int, default=512)
_ap.add_argument("--reps", type=int, default=5)
_ap.add_argument("--capacity-factor", type=float, default=1.0)
_ap.add_argument("--out", default=None)
ARGS = _ap.parse_args()
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={ARGS.devices}").strip()

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402

from repro.core import query as Q                            # noqa: E402
from repro.core.codec import random_dna                      # noqa: E402
from repro.core.planner import (MODE_BROADCAST, MODE_ROUTED,  # noqa: E402
                                ScanPlanner)
from repro.core.tablet import build_tablet_store             # noqa: E402


def _time(fn, reps):
    out = fn()                                    # compile + warm
    jax.block_until_ready(getattr(out, "count", out))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(getattr(out, "count", out))
    return (time.perf_counter() - t0) / reps


def main() -> None:
    p = len(jax.devices())
    mesh = jax.make_mesh((p,), ("tablets",))
    codes = random_dna(ARGS.text_len, seed=0)
    store = build_tablet_store(codes, is_dna=True, num_tablets=p)
    pats = Q.random_patterns(ARGS.batch, 1, 100, seed=1)
    _, pp, pl = Q.encode_patterns(pats, 112)
    B = ARGS.batch

    planner = ScanPlanner(store, mesh=mesh,
                          capacity_factor=ARGS.capacity_factor)
    results = {}
    runs = [
        ("broadcast", dict(mode=MODE_BROADCAST)),
        ("routed_noretry", dict(mode=MODE_ROUTED, retry=False)),
        ("routed_retry", dict(mode=MODE_ROUTED, retry=True)),
    ]
    for name, kw in runs:
        planner.reset_stats()
        dt = _time(lambda kw=kw: planner.scan_encoded(pp, pl, **kw),
                   ARGS.reps)
        s = planner.stats
        results[name] = {
            "us_per_query": round(dt / B * 1e6, 3),
            "queries_per_s": round(B / dt),
            "retried_overflow_per_batch":
                s.retried_overflow / max(s.batches, 1),
            "retried_saturated_per_batch":
                s.retried_saturated / max(s.batches, 1),
        }
        print(f"{name}: {results[name]}", flush=True)

    # sanity: retried path must be exact vs the single-device oracle
    ref = Q.query(store, pp, pl)
    res = planner.scan_encoded(pp, pl, mode=MODE_ROUTED, retry=True)
    exact = bool((np.asarray(res.count) == np.asarray(ref.count)).all())
    results["routed_retry"]["exact_vs_oracle"] = exact
    if not exact:
        print("WARNING: routed+retry counts diverge from oracle",
              file=sys.stderr)

    payload = {
        "bench": "scan_planner_throughput",
        "devices": p,
        "text_len": ARGS.text_len,
        "batch": B,
        "capacity_factor": ARGS.capacity_factor,
        "results": results,
    }
    out = ARGS.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_planner.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""Frozen FM-index tier benchmark: serving cost and residency vs the SA.

Measures, over live/frozen twin tables (docs/storage_tiers.md):

* ``fm_count_us_per_query``   — frozen count() at 1x and 10x text size
                                (backward search is O(pattern_len): the
                                two must be ~flat);
* ``sa_count_us_per_query``   — the live twin's binary-search count();
* ``fm_over_sa_bytes_x``      — resident index bytes, frozen FM over the
                                live twin's raw SA rows (acceptance:
                                <= 0.25, target ~0.125 counting the
                                device text the freeze also drops);
* ``freeze_syms_per_s``       — freeze() throughput;
* ``locate`` µs and exactness flags (count/locate bit-identical to the
  live SA path on the same patterns).

Writes ``BENCH_fm.json`` at the repo root.  ``--smoke`` shrinks every
dimension for the weekly CI job.

    PYTHONPATH=src python benchmarks/fm_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

ARGS = None


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=100_000,
                    help="1x size; the flatness probe also runs 10x this")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--max-pattern", type=int, default=64)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--top-k", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.text_len, args.batch, args.reps = 8_000, 64, 5
    return args


def _time(fn, reps: int) -> float:
    """Best-of-reps: the gated metric here is a RATIO of two tiny
    timings, so the min (the noise floor) is the honest estimator —
    averaging lets one scheduler hiccup swing the ratio 2x run-to-run."""
    import jax
    fn()                                       # compile + warm
    best = float("inf")
    for _ in range(max(reps, 3)):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(getattr(out, "count", out))
        best = min(best, time.perf_counter() - t0)
    return best


def _twins(n: int, seed: int):
    from repro.api import SuffixTable
    from repro.core.codec import random_dna
    codes = random_dna(n, seed=seed)
    live = SuffixTable.from_codes(codes, is_dna=True)
    froz = SuffixTable.from_codes(codes, is_dna=True)
    t0 = time.perf_counter()
    froz.freeze()
    return live, froz, time.perf_counter() - t0


def run(args) -> dict:
    from repro.core import query as Q

    live, froz, freeze_s = _twins(args.text_len, seed=0)
    pats = Q.random_patterns(args.batch, 1, args.max_pattern, seed=1)
    patt, plen = live.planner.encode(pats)

    sa_dt = _time(lambda: live.scan_encoded(patt, plen), args.reps)
    fm_dt = _time(lambda: froz.scan_encoded(patt, plen), args.reps)
    loc_dt = _time(lambda: froz.scan_batch(np.asarray(patt),
                                           np.asarray(plen),
                                           top_k=args.top_k), args.reps)

    # bit-identity on the measured patterns (count AND text-order locate)
    a = live.scan_batch(np.asarray(patt), np.asarray(plen),
                        top_k=args.top_k)
    b = froz.scan_batch(np.asarray(patt), np.asarray(plen),
                        top_k=args.top_k)
    count_ok = bool(np.array_equal(a.count, b.count))
    locate_ok = bool(np.array_equal(a.positions, b.positions)
                     and np.array_equal(a.first_pos, b.first_pos))

    # residency: frozen FM vs the live twin's raw SA rows, same text
    lrb = live.stats()["tiers"]["resident_bytes"]
    frb = froz.stats()["tiers"]["resident_bytes"]

    # flatness: the same batch against a 10x text — O(plen) backward
    # search must not scale with n (the SA path's log n barely moves
    # either; the ratio is the honest probe)
    _, froz10, _ = _twins(args.text_len * 10, seed=2)
    fm10_dt = _time(lambda: froz10.scan_encoded(patt, plen), args.reps)

    return {
        "bench": "fm_frozen_tier",
        "text_len": args.text_len,
        "batch": args.batch,
        "max_pattern": args.max_pattern,
        "results": {
            "fm_count_us_per_query_1x":
                round(fm_dt / args.batch * 1e6, 3),
            "fm_count_us_per_query_10x":
                round(fm10_dt / args.batch * 1e6, 3),
            "count_flat_10x_over_1x_x":
                round(fm10_dt / max(fm_dt, 1e-12), 3),
            "sa_count_us_per_query":
                round(sa_dt / args.batch * 1e6, 3),
            "fm_locate_us_per_query":
                round(loc_dt / args.batch * 1e6, 3),
            "fm_over_sa_bytes_x":
                round(frb["fm"] / max(lrb["base_sa"], 1), 4),
            "fm_bytes_per_symbol":
                round(frb["fm"] / args.text_len, 4),
            "sa_bytes_per_symbol":
                round(lrb["base_sa"] / args.text_len, 4),
            "freeze_syms_per_s": round(args.text_len / max(freeze_s,
                                                           1e-12)),
            "count_identical": count_ok,
            "locate_identical": locate_ok,
        },
    }


def bench_fm():
    """benchmarks/run.py entry: (us_per_frozen_count_query, derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    return (payload["results"]["fm_count_us_per_query_1x"],
            payload["results"])


def main() -> None:
    args = _parse()
    payload = run(args)
    for k, v in payload["results"].items():
        print(f"{k}: {v}", flush=True)
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_fm.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""Serving-plane benchmark: N worker processes x M closed-loop clients.

Measures the three serving-plane claims end to end against a real
multi-process deployment (``repro.serving.plane``), on one table built
once per run:

* **scale** — routed queries/s with 4 tablet workers vs 1.  Every
  worker holds a per-process device lock with a per-pattern service
  floor (``--device-floor-ms``), modeling one logical accelerator per
  tablet server; on a single-core host the floors are sleeps, which
  OVERLAP across worker processes exactly like independent accelerators
  would, so the scale factor is honest about dispatch parallelism while
  staying deterministic.  The table carries no delta for this arm (the
  owner's delta fan-in would otherwise serialize the full batch through
  one process and measure the short-circuit, not the scaling);
* **hedge** — per-call p99 with hedged reads on vs off, against 2
  tablets x 2 replicas where the PRIMARY replica of every tablet
  randomly injects ``--slow-ms`` stalls (the paper's 771 ms straggler
  events, scaled down).  Injection is pinned to replica 0 — a
  designated victim, as fault-injection harnesses do — so a backup RPC
  fired at the hedge deadline always lands on a healthy process and
  the gated gain metric measures the hedge path itself instead of
  coin-flipping on rare both-replicas-slow events;
* **overload** — an abusive tenant hammering the plane through a tight
  router token-bucket quota while an in-quota tenant keeps its own
  closed loop: the abuser's shed rate and the in-quota tenant's p95
  inflation over its own unloaded baseline.

Results are checked **bit-identical** against the in-process
``SuffixTable`` the build produced (the oracle handle is kept open the
whole run, never reopened).  Writes ``BENCH_plane.json`` at the repo
root; ``--smoke`` shrinks every dimension for the weekly CI job.

    PYTHONPATH=src python benchmarks/plane_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

import numpy as np


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=120_000)
    ap.add_argument("--clients", type=int, default=6,
                    help="closed-loop client threads in the scale arm")
    ap.add_argument("--batch", type=int, default=16,
                    help="patterns per routed scan in the scale arm")
    ap.add_argument("--rounds", type=int, default=12,
                    help="batches per client thread in the scale arm")
    ap.add_argument("--device-floor-ms", type=float, default=6.0,
                    help="per-pattern service floor inside each worker's "
                         "device lock (the accelerator-per-worker model)")
    ap.add_argument("--hedge-calls", type=int, default=200,
                    help="single-pattern calls per hedging mode")
    ap.add_argument("--slow-ms", type=float, default=60.0,
                    help="injected straggler stall in the hedge arm")
    ap.add_argument("--slow-p", type=float, default=0.08,
                    help="per-RPC straggler probability in the hedge arm")
    ap.add_argument("--hedge-deadline-ms", type=float, default=15.0)
    ap.add_argument("--overload-seconds", type=float, default=6.0,
                    help="duration of the loaded phase per tenant arm")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.text_len, args.rounds = 16_000, 4
        args.hedge_calls, args.overload_seconds = 100, 2.5
    if args.clients < 1 or args.batch < 1 or args.rounds < 1:
        ap.error("need --clients/--batch/--rounds >= 1")
    return args


def _pcts(lat_ms: list[float]) -> dict:
    a = np.asarray(lat_ms)
    return {"p50_ms": round(float(np.percentile(a, 50)), 3),
            "p95_ms": round(float(np.percentile(a, 95)), 3),
            "p99_ms": round(float(np.percentile(a, 99)), 3)}


def _patterns(n: int, seed: int, lmin: int = 4, lmax: int = 16):
    """Random DNA patterns, >= lmin long: very short patterns prefix-
    match several split keys and get double-routed, which is correct
    but makes the scale arm measure routing fan-out, not workers."""
    rng = np.random.default_rng(seed)
    return ["".join("ACGT"[c] for c in rng.integers(0, 4, size=int(L)))
            for L in rng.integers(lmin, lmax + 1, size=n)]


def _closed_loop(remote, pats_per_thread, batch):
    """Each thread scans its batches back to back; returns (wall_s,
    per-call latencies ms, total patterns)."""
    lat: list[float] = []
    lock = threading.Lock()
    total = sum(len(p) for p in pats_per_thread)

    def worker(pats):
        mine = []
        for i in range(0, len(pats), batch):
            t0 = time.perf_counter()
            remote.scan(pats[i:i + batch])
            mine.append((time.perf_counter() - t0) * 1e3)
        with lock:
            lat.extend(mine)

    threads = [threading.Thread(target=worker, args=(p,))
               for p in pats_per_thread]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, lat, total


def _scale_arm(args, root, name, n_tablets, seed) -> float:
    """Deploy n_tablets x 1 plane, hammer it, return patterns/s."""
    from repro.serving.plane import ServingPlane
    with ServingPlane.deploy(
            root, name, n_tablets, replicas=1,
            device_floor_ms=args.device_floor_ms,
            max_inflight=args.clients + 2,
            metrics_interval_s=0.0) as plane:
        remote = plane.remote_table(hedge_enabled=False)
        try:
            remote.scan(_patterns(args.batch, seed=99))     # warm dials
            per_thread = [
                _patterns(args.rounds * args.batch, seed=seed + c)
                for c in range(args.clients)]
            wall, _lat, total = _closed_loop(remote, per_thread,
                                             args.batch)
            return total / wall
        finally:
            remote.close()


def run(args) -> dict:
    from repro.api import Database, Query
    from repro.core.codec import random_dna
    from repro.serving.plane import ServingPlane

    tmp = tempfile.mkdtemp(prefix="plane-bench-")
    root = os.path.join(tmp, "root")
    db = Database(root)
    # the oracle handle: kept open for the whole run — reopening a root
    # whose commit log is held would re-attach the live segment
    table = db.create_table("plane", random_dna(args.text_len, seed=0),
                            is_dna=True, max_query_len=32)
    results: dict = {}

    # -- scale: 1 worker vs 4 -----------------------------------------------
    qps1 = _scale_arm(args, root, "plane", 1, seed=10)
    qps4 = _scale_arm(args, root, "plane", 4, seed=10)
    results["routed_1w_queries_per_s"] = round(qps1, 1)
    results["routed_4w_queries_per_s"] = round(qps4, 1)
    results["scale_factor_4w_vs_1w_x"] = round(qps4 / max(qps1, 1e-9), 2)

    # -- bit-identicality + overload on a fresh 4x1 plane ---------------------
    with ServingPlane.deploy(root, "plane", 4, replicas=1,
                             device_floor_ms=args.device_floor_ms / 2,
                             metrics_interval_s=0.0):
        remote = db.connect_plane("plane", attach_as="plane@bench")
        probe = _patterns(64, seed=21, lmin=1, lmax=24) + ["ACGT", "A"]
        local = table.scan(probe, top_k=8)
        routed = remote.scan(probe, top_k=8)
        results["bit_identical"] = bool(
            np.array_equal(np.asarray(local.count), routed.count)
            and np.array_equal(np.asarray(local.first_pos),
                               routed.first_pos)
            and np.array_equal(np.asarray(local.positions),
                               routed.positions))

        # unloaded baseline: the in-quota tenant alone
        inq = _patterns(400, seed=31)

        def inquota_loop(seconds: float) -> list[float]:
            lat, i, t_end = [], 0, time.perf_counter() + seconds
            while time.perf_counter() < t_end:
                pats = [inq[(i + j) % len(inq)] for j in range(4)]
                i += 4
                t0 = time.perf_counter()
                r = db.query(Query.scan("plane@bench", pats,
                                        tenant="tenant-a"))
                if r.ok:
                    lat.append((time.perf_counter() - t0) * 1e3)
            return lat

        unloaded = _pcts(inquota_loop(args.overload_seconds))

        # loaded: two abuser threads behind a tight token bucket
        remote.router.set_quota("abuser", rate_per_s=20.0, burst=32.0)
        abuse_sent = [0]
        abuse_shed = [0]
        stop = threading.Event()

        def abuser():
            pats = _patterns(16, seed=41)
            while not stop.is_set():
                r = db.query(Query.scan("plane@bench", pats,
                                        tenant="abuser"))
                abuse_sent[0] += 1
                if r.overloaded:
                    abuse_shed[0] += 1
                # remote abusers are paced by their own network RTT and
                # don't share the serving host's interpreter; without
                # this the spin loop measures GIL contention between
                # bench threads on a 1-core host, not plane behavior
                time.sleep(0.002)

        threads = [threading.Thread(target=abuser) for _ in range(2)]
        for t in threads:
            t.start()
        loaded = _pcts(inquota_loop(args.overload_seconds))
        stop.set()
        for t in threads:
            t.join()
        results["inquota_unloaded_p95_ms"] = unloaded["p95_ms"]
        results["inquota_loaded_p95_ms"] = loaded["p95_ms"]
        results["inquota_p95_over_unloaded_x"] = round(
            loaded["p95_ms"] / max(unloaded["p95_ms"], 1e-9), 2)
        results["abuser_shed_rate"] = round(
            abuse_shed[0] / max(abuse_sent[0], 1), 3)
        results["abuser_batches_sent"] = abuse_sent[0]
        results["router_quota_shed"] = remote.router.quota_shed

    # -- hedge: stragglers with and without the backup RPC --------------------
    with ServingPlane.deploy(root, "plane", 2, replicas=2,
                             device_floor_ms=1.0,
                             inject_slow_ms=args.slow_ms,
                             inject_slow_p=args.slow_p,
                             inject_slow_replica=0,
                             metrics_interval_s=0.0) as plane:
        pats = _patterns(args.hedge_calls, seed=51)
        hstats = {}
        for hedged in (False, True):
            rt = plane.remote_table(
                hedge_enabled=hedged,
                hedge_deadline_ms=args.hedge_deadline_ms)
            try:
                rt.scan(pats[:1])                           # warm dials
                lat = []
                for p in pats:
                    t0 = time.perf_counter()
                    rt.scan([p])
                    lat.append((time.perf_counter() - t0) * 1e3)
                mode = "hedged" if hedged else "unhedged"
                hstats[mode] = _pcts(lat)
                if hedged:
                    results["hedge_fired"] = rt.router.hedge_fired
                    results["hedge_wins"] = rt.router.hedge_wins
            finally:
                rt.close()
        results["unhedged_p99_ms"] = hstats["unhedged"]["p99_ms"]
        results["hedged_p99_ms"] = hstats["hedged"]["p99_ms"]
        results["hedged_p99_gain_x"] = round(
            hstats["unhedged"]["p99_ms"]
            / max(hstats["hedged"]["p99_ms"], 1e-9), 2)

    db.close()
    return {
        "bench": "plane_swarm",
        "text_len": args.text_len,
        "clients": args.clients,
        "batch": args.batch,
        "rounds": args.rounds,
        "device_floor_ms": args.device_floor_ms,
        "hedge_calls": args.hedge_calls,
        "slow_ms": args.slow_ms,
        "slow_p": args.slow_p,
        "hedge_deadline_ms": args.hedge_deadline_ms,
        "overload_seconds": args.overload_seconds,
        "results": results,
    }


def bench_plane():
    """benchmarks/run.py entry: (us per routed pattern at 4 workers,
    derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    r = payload["results"]
    us = 1e6 / max(r["routed_4w_queries_per_s"], 1e-9)
    return us, r


def main() -> None:
    args = _parse()
    payload = run(args)
    for k, v in payload["results"].items():
        print(f"{k}: {v}", flush=True)
    if not payload["results"]["bit_identical"]:
        raise SystemExit("FAIL: routed results diverge from the "
                         "single-process oracle")
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_plane.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

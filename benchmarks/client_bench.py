"""Client frontend benchmark: coalesced vs per-call dispatch.

Simulates ``--concurrency`` callers each holding ONE single-pattern
typed ``Query`` (the paper's Table IV shape: many users, one lookup
each) and measures queries/sec plus per-query latency p50/p95 through
three dispatch paths over the same ``repro.api.Database``:

* ``per_call``   — every caller's query is its own planner invocation
                   (``db.query``, batch of 1): the pre-redesign cost
                   model, one jitted dispatch per caller;
* ``coalesced``  — the wave is grouped inline into one bucket-padded
                   planner invocation (``db.query_many``);
* ``scheduler``  — callers submit into the shared ``QueryScheduler``
                   window and the worker drains them as one batch —
                   the real cross-caller path, window wait included.

Per-query results are checked BIT-IDENTICAL across all three paths
(counts, first positions, and top-k position rows), and the table's
string cache is cleared between arms so nothing is served from memory.

Writes ``BENCH_client.json`` at the repo root.  ``--smoke`` shrinks
every dimension for the weekly CI job.

    PYTHONPATH=src python benchmarks/client_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--concurrency", type=int, default=128,
                    help="simulated concurrent single-query callers "
                         "per wave")
    ap.add_argument("--waves", type=int, default=4,
                    help="timed waves per dispatch path")
    ap.add_argument("--top-k", type=int, default=4)
    ap.add_argument("--max-pattern", type=int, default=24)
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.text_len, args.concurrency, args.waves = 20_000, 64, 2
    if args.concurrency < 1 or args.waves < 1:
        ap.error("need --concurrency >= 1 and --waves >= 1")
    return args


def _percentiles(lat_ms: list[float]) -> dict:
    a = np.asarray(lat_ms)
    return {"p50_ms": round(float(np.percentile(a, 50)), 3),
            "p95_ms": round(float(np.percentile(a, 95)), 3)}


def _key(res) -> tuple:
    """Comparable identity of one QueryResult (bit-identity check)."""
    pos = (tuple() if res.positions is None
           else tuple(int(x) for x in np.asarray(res.positions).ravel()))
    return (tuple(int(c) for c in res.count),
            tuple(int(p) for p in res.first_pos),
            tuple(bool(f) for f in res.found), pos)


def run(args) -> dict:
    from repro.api import Database, Query, SuffixTable
    from repro.core import query as Q
    from repro.core.codec import random_dna

    table = SuffixTable.from_codes(random_dna(args.text_len, seed=0),
                                   is_dna=True)
    db = Database.in_memory(coalesce_window_ms=args.window_ms)
    db.attach("dna", table)

    # distinct patterns per wave slot so the result set is non-trivial;
    # the cache is cleared between arms anyway
    pats = Q.random_patterns(args.concurrency, 2, args.max_pattern, seed=1)
    queries = [Query.scan("dna", [p], top_k=args.top_k) for p in pats]

    # warm both jit shapes (B=1 bucket and the coalesced bucket)
    db.query(queries[0])
    db.query_many(queries)

    results: dict[str, list] = {}
    timings: dict[str, dict] = {}

    def record(name: str, qps: float, lat_ms: list[float]):
        timings[name] = {"queries_per_s": round(qps),
                         **_percentiles(lat_ms)}

    # -- per-call: one dispatch per caller ----------------------------------
    lat, t_total = [], 0.0
    for _ in range(args.waves):
        table.clear_cache()
        got = []
        t0 = time.perf_counter()
        for q in queries:
            tq = time.perf_counter()
            got.append(db.query(q))
            lat.append((time.perf_counter() - tq) * 1e3)
        t_total += time.perf_counter() - t0
        results.setdefault("per_call", got)
    record("per_call", args.waves * args.concurrency / t_total, lat)

    # -- coalesced inline: one bucket-padded dispatch per wave --------------
    lat, t_total = [], 0.0
    for _ in range(args.waves):
        table.clear_cache()
        t0 = time.perf_counter()
        got = db.query_many(queries)
        dt = time.perf_counter() - t0
        t_total += dt
        lat.extend([dt * 1e3] * len(queries))   # every caller waits the wave
        results.setdefault("coalesced", got)
    record("coalesced", args.waves * args.concurrency / t_total, lat)

    # -- scheduler: cross-caller window, worker-thread drain ----------------
    lat, t_total = [], 0.0
    for _ in range(args.waves):
        table.clear_cache()
        t0 = time.perf_counter()
        futs = [db.submit(q) for q in queries]
        got = [f.result(timeout=60.0) for f in futs]
        dt = time.perf_counter() - t0
        t_total += dt
        lat.extend([dt * 1e3] * len(queries))
        results.setdefault("scheduler", got)
    record("scheduler", args.waves * args.concurrency / t_total, lat)

    # -- low load: few callers with think time (adaptive fast path) ---------
    # 4 callers, ~10 ms apart, distinct patterns: arrivals are sparser
    # than the window, so the adaptive scheduler should dispatch inline
    # instead of sleeping out the coalesce window per query.
    import threading

    n_low = max(2, min(4, args.concurrency))
    per_caller = 6 if args.smoke else 12
    low_pats = Q.random_patterns(n_low * per_caller, 2, args.max_pattern,
                                 seed=3)
    low_qs = [Query.scan("dna", [p], top_k=args.top_k) for p in low_pats]
    think_s = 0.010
    low_lat: list[float] = []
    low_res: dict[int, object] = {}
    lock = threading.Lock()

    def low_caller(c: int):
        for r in range(per_caller):
            time.sleep(think_s)
            idx = c * per_caller + r
            tq = time.perf_counter()
            res = db.submit(low_qs[idx]).result(timeout=60.0)
            dt = (time.perf_counter() - tq) * 1e3
            with lock:
                low_res[idx] = res
                if r > 0:               # first round warms the EWMA/jit
                    low_lat.append(dt)

    table.clear_cache()
    fast0 = db.scheduler.stats.fast_path_queries
    threads = [threading.Thread(target=low_caller, args=(c,))
               for c in range(n_low)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120.0)
    low = _percentiles(low_lat)
    low_fast = db.scheduler.stats.fast_path_queries - fast0
    low_identical = all(
        _key(low_res[i]) == _key(db.query(low_qs[i]))
        for i in range(n_low * per_caller))
    db.close()

    identical = all(
        _key(a) == _key(b) == _key(c)
        for a, b, c in zip(results["per_call"], results["coalesced"],
                           results["scheduler"])) and low_identical
    speedup = (timings["coalesced"]["queries_per_s"]
               / max(timings["per_call"]["queries_per_s"], 1))
    sched_speedup = (timings["scheduler"]["queries_per_s"]
                     / max(timings["per_call"]["queries_per_s"], 1))
    return {
        "bench": "client_coalescing",
        "text_len": args.text_len,
        "concurrency": args.concurrency,
        "waves": args.waves,
        "top_k": args.top_k,
        "window_ms": args.window_ms,
        "results": {
            **{f"{name}_{k}": v for name, t in timings.items()
               for k, v in t.items()},
            "coalesced_speedup_x": round(speedup, 2),
            "scheduler_speedup_x": round(sched_speedup, 2),
            "coalesced_low_load_p50_ms": low["p50_ms"],
            "coalesced_low_load_p95_ms": low["p95_ms"],
            # intentionally NOT named *_x: the low-load p50 has a fixed
            # floor (worker wakeup + one dispatch), so this ratio is NOT
            # scale-invariant — the gate compares it same-config only
            "low_load_p50_over_per_call": round(
                low["p50_ms"] / max(timings["per_call"]["p50_ms"], 1e-9), 2),
            "low_load_fast_path_queries": int(low_fast),
            "bit_identical": identical,
        },
    }


def bench_client():
    """benchmarks/run.py entry: (us_per_coalesced_query, derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    r = payload["results"]
    us = 1e6 / max(r["coalesced_queries_per_s"], 1)
    return us, r


def main() -> None:
    args = _parse()
    payload = run(args)
    for k, v in payload["results"].items():
        print(f"{k}: {v}", flush=True)
    r = payload["results"]
    if not r["bit_identical"]:
        raise SystemExit("FAIL: coalesced results diverge from per-call")
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_client.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

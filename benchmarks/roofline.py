"""Roofline report: reads the dry-run artifacts (experiments/dryrun/*.json)
and renders the per-(arch x shape x mesh) table for EXPERIMENTS.md.

No compilation happens here — launch/dryrun.py produces the artifacts."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(tag: str | None = None):
    cells = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        if tag is None and len(parts) > 3:
            continue
        if tag is not None and (len(parts) < 4 or parts[3] != tag):
            continue
        with open(path) as f:
            cells[tuple(parts[:3])] = json.load(f)
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def render_markdown(cells) -> str:
    lines = [
        "| arch | shape | mesh | compute | memory(HLO) | memory(floor) | "
        "collective | dominant | useful FLOPs | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r.get("skipped"):
            lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | — | "
                         f"skipped | — | — |")
            continue
        if r.get("error"):
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR: "
                         f"{r['error'][:60]} | | | | | | |")
            continue
        rf = r["roofline"]
        floor = r.get("memory_floor_s")
        ur = r.get("useful_ratio")
        peak = r["memory"]["peak_bytes_estimate"] / 1e9
        lines.append(
            f"| {arch} | {shape} | {mesh} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(floor)} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{'' if ur is None else f'{ur:.2f}'} | {peak:.1f} |")
    return "\n".join(lines)


def summarize() -> dict:
    cells = load_cells()
    n_ok = sum(1 for c in cells.values()
               if not c.get("error") and not c.get("skipped"))
    n_skip = sum(1 for c in cells.values() if c.get("skipped"))
    n_err = sum(1 for c in cells.values() if c.get("error"))
    return {"cells": len(cells), "compiled": n_ok, "skipped": n_skip,
            "errors": n_err}


if __name__ == "__main__":
    cells = load_cells()
    print(render_markdown(cells))
    print()
    print(summarize())

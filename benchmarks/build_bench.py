"""Staged-build benchmark: bases/s vs corpus size, plus the out-of-core
proof (docs/build_pipeline.md).

Two arms:

* **sweep** — for each corpus size, build the suffix array with the
  in-memory builder (``core.suffix_array.build_suffix_array``) and the
  staged pipeline (``core.build_pipeline.staged_suffix_array``) and
  report bases/s for both plus the staged/in-memory overhead ratio.
  Results must be bit-identical (``sweep_bit_identical``).
* **out-of-core** — a subprocess warms the jit caches at the target
  chunk shape, reads its own ``VmPeak`` from ``/proc/self/status``,
  then hard-caps its address space with
  ``resource.setrlimit(RLIMIT_AS, VmPeak + headroom)`` and builds a
  corpus ``>= 8x`` the per-chunk device budget with ``spill_dir`` set,
  streaming SA shards straight to a file.  The parent verifies the
  streamed SA bit-identical against an UNCAPPED in-memory build.  At
  full size the headroom is smaller than the in-memory builder's
  ``n * 24 B`` working set, so the cap is one the one-shot build could
  not have met — the staged pipeline's memory bound is real, not
  nominal.  (Spill I/O uses ``np.save``/``tofile`` block reads, never
  mmap — mapped files would count against ``RLIMIT_AS`` and void the
  proof.)

Writes ``BENCH_build.json`` at the repo root; the committed baseline is
refreshed from ``--smoke`` so the weekly CI gate compares like against
like (benchmarks/check_regression.py).

    PYTHONPATH=src python benchmarks/build_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

# Runs with its address space capped; prints one "OOB_RESULT {json}" line.
_OOB_CHILD = r"""
import json, os, resource, sys, time
import numpy as np

n, chunk_rows, headroom_mb = (int(a) for a in sys.argv[1:4])
spill_dir, out_path = sys.argv[4], sys.argv[5]
seed = int(sys.argv[6])

from repro.core.build_pipeline import staged_suffix_array

codes = np.random.default_rng(seed).integers(0, 4, size=n, dtype=np.int32)

# Warm every jit cache at the REAL chunk shape (the sort pads each
# super-chunk to chunk_rows, so any warm corpus compiles the same
# kernels) and touch the spill/merge/emit paths once.
warm_dir = os.path.join(spill_dir, "warm")
staged_suffix_array(codes[:max(2, 3 * chunk_rows // 2)],
                    chunk_rows=chunk_rows, spill_dir=warm_dir,
                    emit_shard=lambda i, blk: None)


def _vm_kb(field):
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith(field):
                return int(line.split()[1])
    return 0


vm_peak_kb = _vm_kb("VmPeak:")
cap_bytes = vm_peak_kb * 1024 + headroom_mb * (1 << 20)
resource.setrlimit(resource.RLIMIT_AS, (cap_bytes, cap_bytes))

t0 = time.perf_counter()
with open(out_path, "wb") as out:
    _, stats = staged_suffix_array(
        codes, chunk_rows=chunk_rows, spill_dir=spill_dir,
        emit_shard=lambda i, blk: out.write(
            np.ascontiguousarray(blk, dtype=np.int32).tobytes()))
wall_s = time.perf_counter() - t0

print("OOB_RESULT " + json.dumps({
    "built_under_cap": True,
    "cap_mb": round(cap_bytes / 2**20, 1),
    "vm_peak_before_cap_mb": round(vm_peak_kb / 1024, 1),
    "peak_rss_mb": round(_vm_kb("VmHWM:") / 1024, 1),
    "spill_bytes": int(stats.spill_bytes),
    "rounds": stats.rounds,
    "n_chunks": stats.n_chunks,
    "wall_s": round(wall_s, 3),
}))
"""


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep-sizes", type=int, nargs="+",
                    default=[100_000, 400_000])
    ap.add_argument("--chunk-rows", type=int, default=1 << 13,
                    help="device chunk for the staged sweep arm")
    ap.add_argument("--oob-n", type=int, default=1 << 21,
                    help="out-of-core corpus size (bases)")
    ap.add_argument("--oob-chunk-rows", type=int, default=1 << 13)
    ap.add_argument("--headroom-mb", type=int, default=32,
                    help="RLIMIT_AS slack above post-warmup VmPeak")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.sweep_sizes = [20_000, 60_000]
        args.chunk_rows = 1 << 12
        args.oob_n = 1 << 18
        args.oob_chunk_rows = 1 << 12
    if args.oob_n < 8 * args.oob_chunk_rows:
        ap.error("--oob-n must be >= 8x --oob-chunk-rows "
                 "(the out-of-core claim needs a multi-chunk corpus)")
    return args


def _sweep_one(n: int, chunk_rows: int, seed: int) -> dict:
    from repro.core.build_pipeline import staged_suffix_array
    from repro.core.suffix_array import build_suffix_array

    codes = np.random.default_rng(seed).integers(0, 4, size=n,
                                                 dtype=np.int32)
    ref = np.asarray(build_suffix_array(codes))        # compile pass
    t0 = time.perf_counter()
    ref = np.asarray(build_suffix_array(codes))
    t_mem = time.perf_counter() - t0

    sa, _ = staged_suffix_array(codes, chunk_rows=chunk_rows)  # compile
    t0 = time.perf_counter()
    sa, _ = staged_suffix_array(codes, chunk_rows=chunk_rows)
    t_staged = time.perf_counter() - t0

    return {
        "in_memory_bases_per_s": round(n / max(t_mem, 1e-9), 1),
        "staged_bases_per_s": round(n / max(t_staged, 1e-9), 1),
        "bit_identical": bool(np.array_equal(ref, sa)),
    }


def _run_oob(n: int, chunk_rows: int, headroom_mb: int,
             seed: int = 7) -> dict:
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    tmp = tempfile.mkdtemp(prefix="build_bench_oob_")
    try:
        spill = os.path.join(tmp, "spill")
        out_path = os.path.join(tmp, "sa.bin")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(repo_root, "src") + os.pathsep \
            + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-c", _OOB_CHILD, str(n), str(chunk_rows),
             str(headroom_mb), spill, out_path, str(seed)],
            capture_output=True, text=True, env=env, timeout=1800)
        lines = [ln for ln in proc.stdout.splitlines()
                 if ln.startswith("OOB_RESULT ")]
        if proc.returncode != 0 or not lines:
            tail = (proc.stderr or proc.stdout).strip()[-500:]
            return {"oob_built_under_cap": False,
                    "oob_bit_identical": False,
                    "oob_error": tail or "child died without output"}
        info = json.loads(lines[-1][len("OOB_RESULT "):])

        # bit-identity vs the one-shot builder, run HERE with no cap
        from repro.core.suffix_array import build_suffix_array
        codes = np.random.default_rng(seed).integers(0, 4, size=n,
                                                     dtype=np.int32)
        ref = np.asarray(build_suffix_array(codes), dtype=np.int32)
        got = np.fromfile(out_path, dtype=np.int32)
        return {
            "oob_built_under_cap": bool(info["built_under_cap"]),
            "oob_bit_identical": bool(np.array_equal(ref, got)),
            "oob_budget_multiple_x": round(n / chunk_rows, 1),
            "oob_cap_mb": info["cap_mb"],
            "oob_peak_rss_mb": info["peak_rss_mb"],
            "oob_spill_mb": round(info["spill_bytes"] / 2**20, 1),
            "oob_rounds": info["rounds"],
            "oob_wall_s": info["wall_s"],
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(args) -> dict:
    sweep = {}
    all_identical = True
    for n in args.sweep_sizes:
        one = _sweep_one(n, args.chunk_rows, seed=n)
        all_identical &= one.pop("bit_identical")
        sweep[f"n{n}"] = one

    oob = _run_oob(args.oob_n, args.oob_chunk_rows, args.headroom_mb)

    largest = sweep[f"n{args.sweep_sizes[-1]}"]
    overhead = (largest["in_memory_bases_per_s"]
                / max(largest["staged_bases_per_s"], 1e-9))
    results = {
        "staged_bases_per_s": largest["staged_bases_per_s"],
        "in_memory_bases_per_s": largest["in_memory_bases_per_s"],
        "staged_overhead_over_in_memory_x": round(overhead, 2),
        "sweep_bit_identical": all_identical,
        "sweep": sweep,
    }
    results.update(oob)
    return {
        "bench": "staged_build",
        "sweep_sizes": args.sweep_sizes,
        "chunk_rows": args.chunk_rows,
        "oob_n": args.oob_n,
        "oob_chunk_rows": args.oob_chunk_rows,
        "headroom_mb": args.headroom_mb,
        "results": results,
    }


def bench_build():
    """benchmarks/run.py entry: (us per staged build at smoke size,
    derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    res = payload["results"]
    n = args.sweep_sizes[-1]
    us = 1e6 * n / max(res["staged_bases_per_s"], 1e-9)
    return (us, {k: v for k, v in res.items() if k != "sweep"})


def main() -> None:
    args = _parse()
    payload = run(args)
    for k, v in payload["results"].items():
        print(f"{k}: {v}", flush=True)
    res = payload["results"]
    if not res["sweep_bit_identical"]:
        raise SystemExit("staged sweep is NOT bit-identical to the "
                         "in-memory builder")
    if not (res["oob_built_under_cap"] and res["oob_bit_identical"]):
        raise SystemExit("out-of-core build failed under the RLIMIT_AS "
                         f"cap: {res.get('oob_error', 'not identical')}")
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_build.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""Commit-log benchmark: acked appends/sec, fsync-per-append vs group
commit.

``--concurrency`` client threads each push ``--appends`` chunks into
one table.  Two arms over identical workloads:

* ``fsync_per_append`` — the pre-group-commit discipline: each append
  logs, fsyncs, and acks while still HOLDING the table's write lock
  (``SuffixTable.append`` under ``run_exclusive``), so every ack pays
  its own fsync and writers queue behind each other's disk waits;
* ``group_commit``     — ``Database.append``: the mutation is applied
  under the lock but the fsync is awaited OUTSIDE it, and a small
  window lets concurrent writers batch into ONE fsync per wave before
  acking — the write-side mirror of the ``QueryScheduler``'s read-side
  coalescing.

After the group-commit arm the root is copied (a simulated crash — the
live handle is abandoned) and reopened to verify every acked append was
recovered: ``recovered_all_acked`` must be true.

Writes ``BENCH_wal.json`` at the repo root.  ``--smoke`` shrinks every
dimension for the weekly CI job.

    PYTHONPATH=src python benchmarks/wal_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import threading
import time


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=20_000)
    ap.add_argument("--concurrency", type=int, default=8,
                    help="concurrent client writer threads")
    ap.add_argument("--appends", type=int, default=40,
                    help="chunks appended per thread per arm")
    ap.add_argument("--chunk", type=int, default=32,
                    help="bases per appended chunk")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="group-commit window for the batched arm")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.text_len, args.appends = 5_000, 15
    if args.concurrency < 1 or args.appends < 1:
        ap.error("need --concurrency >= 1 and --appends >= 1")
    return args


def _run_arm(db, table: str, *, concurrency: int, appends: int,
             chunk: int, serial_ack: bool) -> dict:
    from repro.core.codec import random_dna
    errs: list[Exception] = []
    barrier = threading.Barrier(concurrency + 1)
    t_obj = db.table(table)

    def push(c) -> None:
        if serial_ack:
            # fsync-per-append: ack (fsync wait) INSIDE the table lock —
            # the pre-group-commit write path
            db.scheduler.run_exclusive(t_obj, lambda: t_obj.append(c))
        else:
            db.append(table, c)      # fsync awaited outside the lock

    def writer(tid: int) -> None:
        try:
            chunks = [random_dna(chunk, seed=1000 * tid + j)
                      for j in range(appends)]
            barrier.wait()
            for c in chunks:
                push(c)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(concurrency)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errs:
        raise errs[0]
    total = concurrency * appends
    log = db.table(table).stats()["wal"]["log"]
    return {"acked_per_s": total / dt, "total_acked": total,
            "wall_s": dt, "fsyncs": log["fsyncs"],
            "appends_per_fsync": total / max(log["fsyncs"], 1)}


def run(args) -> dict:
    from repro.api import Database, SuffixTable
    from repro.core.codec import random_dna

    root = tempfile.mkdtemp(prefix="wal_bench_")
    try:
        base = random_dna(args.text_len, seed=0)
        arms = {}
        for name, window, serial in (("fsync_per_append", 0.0, True),
                                     ("group_commit", args.window_ms,
                                      False)):
            db = Database(root, group_commit_ms=window)
            db.create_table(name, base, is_dna=True,
                            group_commit_ms=window)
            arms[name] = _run_arm(db, name,
                                  concurrency=args.concurrency,
                                  appends=args.appends, chunk=args.chunk,
                                  serial_ack=serial)
            db.close()

        # crash + reopen the group-commit table: every ack must survive
        crash = root + "_crash"
        shutil.copytree(root, crash)
        t = SuffixTable.open("group_commit", root=crash)
        want = args.text_len + (args.concurrency * args.appends
                                * args.chunk)
        recovered = bool(len(t) == want)
        shutil.rmtree(crash, ignore_errors=True)

        speedup = (arms["group_commit"]["acked_per_s"]
                   / max(arms["fsync_per_append"]["acked_per_s"], 1e-9))
        return {
            "bench": "wal_group_commit",
            "text_len": args.text_len,
            "concurrency": args.concurrency,
            "appends_per_thread": args.appends,
            "chunk": args.chunk,
            "window_ms": args.window_ms,
            "results": {
                "fsync_per_append_acked_per_s":
                    round(arms["fsync_per_append"]["acked_per_s"], 1),
                "fsync_per_append_appends_per_fsync":
                    round(arms["fsync_per_append"]["appends_per_fsync"],
                          2),
                "group_commit_acked_per_s":
                    round(arms["group_commit"]["acked_per_s"], 1),
                "group_commit_appends_per_fsync":
                    round(arms["group_commit"]["appends_per_fsync"], 2),
                "group_commit_speedup_x": round(speedup, 2),
                "recovered_all_acked": recovered,
            },
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


def bench_wal():
    """benchmarks/run.py entry: (us_per_acked_append, derived)."""
    args = _parse(["--smoke"])
    payload = run(args)
    res = payload["results"]
    return (1e6 / max(res["group_commit_acked_per_s"], 1e-9), res)


def main() -> None:
    args = _parse()
    payload = run(args)
    for k, v in payload["results"].items():
        print(f"{k}: {v}", flush=True)
    if not payload["results"]["recovered_all_acked"]:
        raise SystemExit("acked appends were LOST across crash+reopen")
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_wal.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""The LSM run tier: minor/major compaction and write-path regressions.

Load-bearing properties:

* after ANY schedule of appends, minor compactions (memtable sealed into
  immutable runs), and major compactions (runs merge-folded into the
  base), merged reads — counts, smallest position, top-k positions —
  exactly match the paper's Algorithm 1 brute force over the concatenated
  text, including occurrences straddling every tier boundary;
* major compaction MERGES (``repro.api.compaction``): for texts with no
  depth-``max_query_len`` window collisions the merged suffix array is
  bit-identical to a from-scratch build, and for adversarial repetitive
  text (where tie order inside equal-window blocks is free) counts and
  position sets stay exact;
* a persistence round trip with live runs restores the same table.

Plus regression tests for the write-path bugfixes shipped alongside
(negative-code appends, merged ``first_pos``, uint8-only DNA inference,
``run_workload`` length validation, crash-safe ``create`` registration).
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import Catalog, SuffixTable
from repro.api.compaction import merge_delta_sa
from repro.core import codec, query as Q
from repro.core.suffix_array import build_suffix_array
from repro.serving import HedgedScanService


def _oracle(codes: np.ndarray, pattern: str):
    """(count, first_pos=smallest position, all positions) by Algorithm 1."""
    cc = np.asarray(codes).astype(np.int32)
    pc = codec.encode_dna(pattern).astype(np.int32)
    k = len(pc)
    pos = [i for i in range(len(cc) - k + 1)
           if (cc[i:i + k] == pc).all()]
    return len(pos), (pos[0] if pos else -1), pos


def _check_vs_oracle(table, combined, patterns, top_k=16):
    out = table.scan(patterns, top_k=top_k)
    for i, p in enumerate(patterns):
        want, first, pos = _oracle(combined, p)
        assert int(out.count[i]) == want, (p, int(out.count[i]), want)
        assert int(out.first_pos[i]) == first, (p, "first_pos")
        got = [int(x) for x in out.positions[i] if x >= 0]
        assert got == pos[:top_k], p


def _boundary_patterns(combined, boundaries, maxlen=12):
    """Patterns planted to straddle each tier boundary."""
    pats = []
    for b in boundaries:
        for off in (1, 3, 7):
            lo, hi = b - off, b - off + min(off + 5, maxlen)
            if 0 <= lo and hi <= len(combined) and hi > lo:
                pats.append(codec.decode_dna(combined[lo:hi]))
    return pats


# ---------------------------------------------------------------------------
# the run tier: seal / fan-out reads / merge-fold
# ---------------------------------------------------------------------------
def test_minor_compaction_reads_stay_exact_across_runs():
    base = codec.random_dna(2500, seed=0)
    t = SuffixTable.from_codes(base, is_dna=True)
    combined = base
    boundaries = [len(base)]
    for step in range(4):
        app = codec.random_dna(130 + 40 * step, seed=50 + step)
        t.append(app)
        combined = np.concatenate([combined, app])
        if step < 3:                       # leave the last append unsealed
            t.minor_compact()
            boundaries.append(len(combined))
    assert len(t.runs) == 3 and t.memtable.size > 0
    assert len(t) == len(combined) and t.n_base == 2500
    pats = (Q.random_patterns(10, 1, 8, seed=60)
            + _boundary_patterns(combined, boundaries))
    _check_vs_oracle(t, combined, pats)
    # encoded reads merge the same way, min-position first_pos included
    patt, plen = t.planner.encode(pats)
    res = t.scan_encoded(patt, plen)
    for i, p in enumerate(pats):
        want, _, _ = _oracle(combined, p)
        assert int(res.count[i]) == want, p
    # sealing the live memtable changes nothing about the answers
    t.minor_compact()
    assert t.memtable.size == 0 and len(t.runs) == 4
    _check_vs_oracle(t, combined, pats)


def test_major_compaction_merge_equals_full_rebuild():
    """For random DNA at depth 128 no two windows collide, so the merged
    SA must be BIT-IDENTICAL to a from-scratch build."""
    base = codec.random_dna(3000, seed=1)
    t = SuffixTable.from_codes(base, is_dna=True)
    combined = base
    for s in range(3):
        app = codec.random_dna(100 + 30 * s, seed=70 + s)
        t.append(app)
        combined = np.concatenate([combined, app])
        t.minor_compact()
    assert t.compact() == 1 and not t.runs and t.memtable.size == 0
    ref = np.asarray(build_suffix_array(combined.astype(np.int32)))
    got = np.asarray(t.store.sa)[t.store.pad_count:]
    assert (got == ref).all()
    _check_vs_oracle(t, combined,
                     Q.random_patterns(8, 1, 9, seed=80)
                     + _boundary_patterns(combined, [3000, 3100, 3230]))


def test_merge_delta_sa_token_path_equals_rebuild():
    rng = np.random.default_rng(2)
    base = rng.integers(0, 500, 1500).astype(np.int32)
    delta = rng.integers(0, 500, 120).astype(np.int32)
    combined = np.concatenate([base, delta])
    base_sa = np.asarray(build_suffix_array(base))
    got = merge_delta_sa(combined, 1500, base_sa, is_dna=False,
                         max_query_len=32)
    ref = np.asarray(build_suffix_array(combined))
    assert (got == ref).all()


def test_merge_compaction_repetitive_text_counts_exact():
    """Adversarial repeats: every suffix of 'AAA...' shares windows, so
    the depth-capped merge may order tie blocks differently from a full
    build — counts and position SETS must stay exact regardless."""
    aa = np.zeros(300, np.uint8)                    # 'A' * 300
    t = SuffixTable.from_codes(aa, is_dna=True, max_query_len=16)
    t.append(np.zeros(50, np.uint8))
    t.minor_compact()
    t.append(codec.encode_dna("ACGTACGTAAAC"))
    combined = np.concatenate([aa, np.zeros(50, np.uint8),
                               codec.encode_dna("ACGTACGTAAAC")])
    pats = ["A", "AA", "AAAA", "A" * 15, "ACGT", "AAC", "CGTA", "TACG"]
    _check_vs_oracle(t, combined, pats, top_k=8)
    t.compact()
    _check_vs_oracle(t, combined, pats, top_k=8)


def test_compact_with_memtable_only_still_merges():
    """No runs sealed: major compaction merges the bare memtable too."""
    base = codec.random_dna(2000, seed=3)
    t = SuffixTable.from_codes(base, is_dna=True)
    app = codec.random_dna(90, seed=4)
    t.append(app)
    combined = np.concatenate([base, app])
    assert t.compact() == 1
    ref = np.asarray(build_suffix_array(combined.astype(np.int32)))
    got = np.asarray(t.store.sa)[t.store.pad_count:]
    assert (got == ref).all()


@given(st.integers(0, 10_000), st.integers(1, 4))
@settings(max_examples=4, deadline=None)
def test_property_lsm_schedule_matches_from_scratch(seed, n_steps):
    """Property: ANY append/seal/major schedule == a from-scratch store."""
    rng = np.random.default_rng(seed)
    base = codec.random_dna(int(rng.integers(300, 800)), seed=seed)
    t = SuffixTable.from_codes(base, is_dna=True)
    combined = base
    boundaries = [len(base)]
    for s in range(n_steps):
        app = codec.random_dna(int(rng.integers(40, 160)),
                               seed=seed * 13 + s)
        t.append(app)
        combined = np.concatenate([combined, app])
        op = rng.integers(0, 3)
        if op == 1:
            t.minor_compact()
            boundaries.append(len(combined))
        elif op == 2:
            t.compact()
            boundaries = [len(combined)]
    pats = (Q.random_patterns(6, 1, 9, seed=seed + 1)
            + _boundary_patterns(combined, boundaries))
    fresh = SuffixTable.from_codes(combined, is_dna=True)
    out, ref = t.scan(pats, top_k=8), fresh.scan(pats, top_k=8)
    assert (out.count == ref.count).all()
    assert (out.first_pos == ref.first_pos).all()
    assert (out.positions == ref.positions).all()


def test_persistence_round_trip_with_live_runs(tmp_path):
    base = codec.random_dna(1200, seed=5)
    t = SuffixTable.create("lsm", base, root=str(tmp_path))
    combined = base
    for s in range(2):
        app = codec.random_dna(100, seed=90 + s)
        t.append(app)
        combined = np.concatenate([combined, app])
        t.minor_compact()                  # persists the sealed run
    tail = codec.random_dna(60, seed=99)
    t.append(tail)
    combined = np.concatenate([combined, tail])
    t.flush()
    t2 = SuffixTable.open("lsm", root=str(tmp_path))
    assert len(t2.runs) == 2 and t2.memtable.size == 60
    assert t2.version == 1 and len(t2) == len(combined)
    pats = (Q.random_patterns(10, 1, 9, seed=100)
            + _boundary_patterns(combined, [1200, 1300, 1400]))
    a, b = t.scan(pats, top_k=8), t2.scan(pats, top_k=8)
    assert (a.count == b.count).all()
    assert (a.first_pos == b.first_pos).all()
    assert (a.positions == b.positions).all()
    _check_vs_oracle(t2, combined, pats)
    # major compaction on the REOPENED table (runs restored frozen)
    v = t2.compact()
    assert v == 2 and not t2.runs
    _check_vs_oracle(t2, combined, pats)
    t3 = SuffixTable.open("lsm", root=str(tmp_path))
    assert t3.version == 2 and t3.n_base == len(combined) and not t3.runs


# ---------------------------------------------------------------------------
# write-path bugfix regressions
# ---------------------------------------------------------------------------
def test_append_rejects_negative_codes():
    """Regression: negative codes passed the DNA range check (only max
    was validated) and silently wrapped on the uint8 astype."""
    t = SuffixTable.from_codes(codec.random_dna(200, seed=0), is_dna=True)
    with pytest.raises(ValueError, match="non-negative"):
        t.append(np.array([-1, 2, 3]))
    assert t.memtable.size == 0            # nothing landed
    tok = SuffixTable.from_codes(
        np.arange(100, dtype=np.int32) % 50, is_dna=False)
    with pytest.raises(ValueError, match="non-negative"):
        tok.append(np.array([3, -7]))


def test_scan_encoded_first_pos_is_min_across_tiers():
    """Merged ``first_pos`` is the smallest of the base's reported
    position and every run/memtable occurrence (the documented min rule);
    on a base miss it must be the first DELTA-tier occurrence, with
    ``first_rank`` staying −1."""
    base = codec.random_dna(600, seed=6)
    t = SuffixTable.from_codes(base, is_dna=True)
    probe = "GATTACAGG"
    # run 0: occurrence late in its appended region
    app0 = codec.decode_dna(codec.random_dna(40, seed=7)) + probe
    t.append(app0)
    t.minor_compact()
    # memtable: a second occurrence right after the run boundary
    t.append(probe + codec.decode_dna(codec.random_dna(30, seed=8)))
    combined = np.concatenate([base, codec.encode_dna(app0),
                               codec.encode_dna(probe),
                               codec.random_dna(30, seed=8)])
    want, first, _ = _oracle(combined, probe)
    patt, plen = t.planner.encode([probe])
    res = t.scan_encoded(patt, plen)
    assert int(res.count[0]) == want == 2
    assert int(res.first_pos[0]) == first  # smallest across both tiers
    assert int(res.first_rank[0]) == -1    # base missed entirely


def test_as_codes_infers_dna_for_uint8_only():
    """Regression: ANY small-vocab integer corpus used to silently take
    the packed DNA codec; now only uint8 arrays are inferred as DNA."""
    small_vocab = np.array([0, 1, 2, 3, 0, 1, 2, 0, 3, 1] * 30,
                           dtype=np.int64)
    t = SuffixTable.from_codes(small_vocab)
    assert t.is_dna is False               # token path
    import jax.numpy as jnp
    w = small_vocab[5:13].astype(np.int32)
    res = t.scan_encoded(jnp.asarray(w[None]), jnp.asarray([8]))
    assert int(res.count[0]) >= 1
    assert SuffixTable.from_codes(codec.random_dna(64, seed=0)).is_dna
    # the explicit flag still opts non-uint8 arrays into the DNA codec
    assert SuffixTable.from_codes(small_vocab.astype(np.int32)[:64],
                                  is_dna=True).is_dna


def test_run_workload_validates_max_len_up_front():
    """Regression: an over-cap max_len used to crash mid-workload (after
    partial batches) inside the planner's length validation."""
    t = SuffixTable.from_codes(codec.random_dna(500, seed=9), is_dna=True,
                               max_query_len=32)
    svc = HedgedScanService(t, seed=1)
    with pytest.raises(ValueError, match="max_len=100 exceeds"):
        svc.run_workload(200, batch=50)    # default max_len=100 > cap 32
    with pytest.raises(ValueError, match="min_len"):
        svc.run_workload(200, batch=50, min_len=0, max_len=8)
    stats = svc.run_workload(100, batch=50, max_len=32)   # at cap: fine
    assert stats["n"] == 100


def test_create_registration_is_crash_safe(tmp_path):
    """Regression: create() registered the table only AFTER persisting,
    so a crash in between left an orphan directory that blocked
    re-create but was invisible to catalog.list_tables()."""
    codes = codec.random_dna(300, seed=10)
    # simulate the old failure mode: a table dir with no published snapshot
    os.makedirs(tmp_path / "crashed" / "step_0000000001.tmp")
    t = SuffixTable.create("crashed", codes, root=str(tmp_path))
    assert t.version == 1
    assert int(SuffixTable.open("crashed", root=str(tmp_path))
               .count(["ACGT"])[0]) >= 0
    # crash BETWEEN register and persist: the remnant is now visible in
    # the catalog (register-then-persist) and a re-create reconciles it
    class _Boom(RuntimeError):
        pass

    orig = SuffixTable._persist
    try:
        def boom(self):
            raise _Boom()
        SuffixTable._persist = boom
        with pytest.raises(_Boom):
            SuffixTable.create("half", codes, root=str(tmp_path))
    finally:
        SuffixTable._persist = orig
    cat = Catalog(str(tmp_path), reconcile=False)
    assert "half" in cat.list_tables()     # visible, not an orphan
    # the next catalog open garbage-collects the snapshot-less remnant
    assert "half" in Catalog(str(tmp_path)).reconcile() or \
        "half" not in Catalog(str(tmp_path)).list_tables()
    t2 = SuffixTable.create("half", codes, root=str(tmp_path))
    assert t2.version == 1
    # a COMPLETE table still refuses duplicate creation
    with pytest.raises(FileExistsError):
        SuffixTable.create("half", codes, root=str(tmp_path))

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600):
    """Run ``code`` in a subprocess with n host devices (smoke tests and
    benches must see 1 device, so multi-device tests are subprocesses)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice

"""Training substrate: optimizers, microbatching, checkpoint/resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.training import OptConfig, make_train_step, train_state_init
from repro.training import optimizer as opt


@pytest.mark.slow
def test_loss_decreases():
    cfg = get_config("qwen3-0.6b").reduced()
    ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    state = train_state_init(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg, remat=False))
    data = DataConfig(global_batch=4, seq_len=32)
    batch = synthetic_batch(cfg, data, 0)
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[:3] + losses[-3:]


@pytest.mark.slow
def test_microbatch_equals_full_batch_grads():
    """Accumulated grads over microbatches == single big batch (same data)."""
    cfg = get_config("qwen3-0.6b").reduced()
    ocfg = OptConfig(lr=0.0, warmup_steps=0, total_steps=10,
                     weight_decay=0.0)
    state = train_state_init(cfg, ocfg, jax.random.PRNGKey(0))
    data = DataConfig(global_batch=8, seq_len=16)
    batch = synthetic_batch(cfg, data, 0)
    s1 = make_train_step(cfg, ocfg, microbatches=1, remat=False)
    s4 = make_train_step(cfg, ocfg, microbatches=4, remat=False)
    _, m1 = s1(state, batch)
    _, m4 = s4(state, batch)
    # with lr=0 params don't move; compare losses (mean over micro == full)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m4["grad_norm"]), rtol=1e-4)


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    ocfg = OptConfig(kind=kind, lr=0.1, warmup_steps=0, total_steps=100,
                     weight_decay=0.0, b1=0.9 if kind == "adamw" else 0.0)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                               jnp.float32)}
    state = opt.init(ocfg, params)
    target = jnp.ones((8, 8))

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for step in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = opt.apply(ocfg, g, state, params, jnp.int32(step))
    assert float(loss(params)) < l0 * 0.1


def test_adafactor_state_is_factored():
    ocfg = OptConfig(kind="adafactor", b1=0.0)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = opt.init(ocfg, params)
    assert st["w"]["vr"].shape == (64,)
    assert st["w"]["vc"].shape == (32,)
    assert "m" not in st["w"]
    assert st["b"]["v"].shape == (64,)


@pytest.mark.slow
def test_checkpoint_roundtrip_and_resume(tmp_path):
    from repro.checkpoint import CheckpointManager
    cfg = get_config("mamba2-780m").reduced()
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=20)
    state = train_state_init(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg, remat=False))
    data = DataConfig(global_batch=2, seq_len=32)

    # run 6 steps, checkpointing at 3
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    s = state
    for i in range(6):
        s, _ = step(s, synthetic_batch(cfg, data, i))
        if i == 2:
            mgr.save(3, s, extra={"data_step": 3})
    final_direct = s

    # resume from step 3 and replay
    got = mgr.restore_latest(state)
    assert got is not None
    start, s2, extra = got
    assert start == 3 and extra["data_step"] == 3
    for i in range(3, 6):
        s2, _ = step(s2, synthetic_batch(cfg, data, i))
    for a, b in zip(jax.tree.leaves(final_direct.params),
                    jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_checkpoint_gc_and_atomicity(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"x": jnp.arange(5)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    # a stale .tmp dir must not be listed as a checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_0000000099.tmp"))
    assert mgr.latest_step() == 4


def test_lr_schedule():
    ocfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(opt.lr_schedule(ocfg, 0)) == 0.0
    assert abs(float(opt.lr_schedule(ocfg, 10)) - 1.0) < 1e-6
    assert float(opt.lr_schedule(ocfg, 100)) < 0.2

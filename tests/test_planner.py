"""Scan planner: mode selection, sentinel retry, match enumeration, LRU.

The retry contract (-1 overflow / -2 saturated always re-executed through
an exact path) is tested here single-device with an injected faulty routed
executor, and again on a real 8-device mesh in test_distributed.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec, query as Q
from repro.core.planner import (MODE_BROADCAST, MODE_ROUTED, MODE_SINGLE,
                                ScanPlanner)
from repro.core.query import MatchResult
from repro.core.tablet import build_tablet_store

TEXT_N = 20_000


@pytest.fixture(scope="module")
def store():
    return build_tablet_store(codec.random_dna(TEXT_N, seed=0), is_dna=True)


@pytest.fixture(scope="module")
def text_codes():
    return codec.random_dna(TEXT_N, seed=0).astype(np.int32)


def test_plan_single_device(store):
    planner = ScanPlanner(store)
    plan = planner.plan(4096)
    assert plan.mode == MODE_SINGLE
    assert planner.num_tablets == 1


def test_exact_counts_and_first_pos(store, text_codes):
    planner = ScanPlanner(store)
    pats = Q.random_patterns(48, 1, 12, seed=3)
    out = planner.scan(pats)
    for i, p in enumerate(pats):
        want, first = Q.brute_force_count(text_codes, codec.encode_dna(p))
        assert int(out.count[i]) == want, p
        assert bool(out.found[i]) == (want > 0)
        if want:
            fp = int(out.first_pos[i])
            assert (text_codes[fp:fp + len(p)]
                    == codec.encode_dna(p)).all()


def test_locate_round_trips_through_oracle(store, text_codes):
    """Every position returned by locate() is a genuine occurrence; when
    count <= top_k the returned set IS the brute-force set."""
    planner = ScanPlanner(store)
    pats = Q.random_patterns(32, 2, 10, seed=5)
    k = 16
    out = planner.scan(pats, top_k=k)
    for i, p in enumerate(pats):
        pc = codec.encode_dna(p).astype(np.int32)
        oracle = {j for j in range(TEXT_N - len(p) + 1)
                  if (text_codes[j:j + len(p)] == pc).all()}
        got = {int(x) for x in out.positions[i] if x >= 0}
        assert got <= oracle, p
        assert len(got) == min(len(oracle), k), p
        if len(oracle) <= k:
            assert got == oracle, p


def test_retry_restores_exact_counts(store, text_codes):
    """Inject a faulty routed executor that stamps -1/-2 sentinels; the
    planner must transparently re-execute those through the exact path."""
    planner = ScanPlanner(store)
    real = planner._executor(MODE_SINGLE)

    def faulty_routed(patt, plen):
        res = real(patt, plen)
        count = np.asarray(res.count).copy()
        rank = np.asarray(res.first_rank).copy()
        count[0::3] = -1          # dispatch overflow
        count[1::3] = -2          # saturated run
        rank[2::3] = -1           # exact count but unusable rank
        return MatchResult(found=jnp.asarray(count > 0),
                           count=jnp.asarray(count),
                           first_rank=jnp.asarray(rank),
                           first_pos=res.first_pos)

    planner._executors[MODE_ROUTED] = faulty_routed
    pats = Q.random_patterns(30, 1, 10, seed=9)
    _, pp, pl = Q.encode_patterns(pats, 112)
    ref = planner._executor(MODE_SINGLE)(pp, pl)
    res = planner.scan_encoded(pp, pl, mode=MODE_ROUTED)
    for i, p in enumerate(pats):
        want, _ = Q.brute_force_count(text_codes, codec.encode_dna(p))
        assert int(res.count[i]) == want, p
        assert int(res.first_rank[i]) == int(ref.first_rank[i]), p
    assert planner.stats.retried_overflow == 10
    assert planner.stats.retried_saturated == 10
    n_rank_bad = sum(1 for i in range(2, 30, 3)
                     if int(ref.count[i]) > 0)
    assert planner.stats.retried_inexact_rank == n_rank_bad
    # without retry the sentinels must survive untouched (bench contract)
    raw = planner.scan_encoded(pp, pl, mode=MODE_ROUTED, retry=False)
    assert (np.asarray(raw.count)[0::3] == -1).all()
    assert (np.asarray(raw.count)[1::3] == -2).all()


def test_lru_cache_hits_and_eviction(store):
    planner = ScanPlanner(store, cache_size=2)
    a, b, c = "ACGT", "GGT", "TTA"
    planner.scan([a]); planner.scan([b])
    assert planner.stats.cache_misses == 2
    planner.scan([a])                      # hit, refreshes a
    assert planner.stats.cache_hits == 1
    planner.scan([c])                      # evicts b (LRU)
    planner.scan([b])                      # miss again
    assert planner.stats.cache_misses == 4
    # cached result equals fresh result
    fresh = ScanPlanner(store, cache_size=0).scan([a])
    again = planner.scan([a])
    assert int(again.count[0]) == int(fresh.count[0])


def test_cache_is_topk_aware(store, text_codes):
    """One cache entry per pattern: a (pattern, top_k=8) entry serves any
    request with top_k <= 8 by slicing, and any top_k at all once the
    position set is complete (count <= k_stored) — no duplicate entries
    per (pattern, top_k) key."""
    planner = ScanPlanner(store)
    # a pattern with a healthy occurrence count
    p = "".join("ACGT"[c] for c in text_codes[100:103])
    full = planner.scan([p], top_k=8)
    n = int(full.count[0])
    assert n > 8, "fixture text too small for this test"
    assert planner.stats.cache_misses == 1
    # smaller top_k: served by slicing the k=8 entry
    out4 = planner.scan([p], top_k=4)
    assert planner.stats.cache_hits == 1
    assert (out4.positions[0] == full.positions[0][:4]).all()
    # count-only: also a hit
    out0 = planner.scan([p])
    assert planner.stats.cache_hits == 2
    assert int(out0.count[0]) == n
    # larger top_k than stored (and count > stored): honest miss,
    # entry upgraded in place
    out16 = planner.scan([p], top_k=16)
    assert planner.stats.cache_misses == 2
    assert (out16.positions[0][:8] == full.positions[0]).all()
    assert len(planner._cache) == 1
    # re-request smaller k after the upgrade: still a hit
    planner.scan([p], top_k=8)
    assert planner.stats.cache_hits == 3
    # a zero-count pattern is complete at any k: top_k request hits
    miss_pat = "ACGT" * 8                     # long pattern, almost surely 0
    if int(planner.scan([miss_pat]).count[0]) == 0:
        planner.scan([miss_pat], top_k=8)
        assert planner.stats.cache_hits == 4


def test_cached_batch_and_empty_batch(store):
    """A fully cache-served batch triggers the empty-encode path."""
    planner = ScanPlanner(store)
    pats = ["ACGTAC", "TGCA"]
    first = planner.scan(pats, top_k=4)
    second = planner.scan(pats, top_k=4)
    assert planner.stats.cache_hits == 2
    assert (first.count == second.count).all()
    assert (first.positions == second.positions).all()
    empty = planner.scan([])
    assert empty.count.shape == (0,)


def test_token_corpus_goes_through_planner():
    """Non-DNA stores use the generic code path (and must never route)."""
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, 50_000, 3000).astype(np.int32)
    corpus[1000:1010] = corpus[2000:2010]
    store = build_tablet_store(corpus, is_dna=False)
    planner = ScanPlanner(store)
    w = jnp.asarray(corpus[2000:2010][None, :])
    res = planner.scan_encoded(w, jnp.asarray([10]))
    assert int(res.count[0]) == 2
    pos = planner.positions_from_result(res, top_k=4)
    assert sorted(int(x) for x in pos[0] if x >= 0) == [1000, 2000]

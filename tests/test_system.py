"""End-to-end behaviour of the whole system (paper pipeline + LM pipeline).

1. Ingest DNA -> tablet store -> serve the paper's workload -> stats sane.
2. Token corpus -> SA dedup filter -> train a reduced LM on the deduped
   stream -> loss decreases -> checkpoint -> resume bitwise-identical.
3. LM serving: greedy generation runs and is deterministic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import query as Q
from repro.core.codec import random_dna
from repro.core.tablet import build_tablet_store
from repro.data import DataConfig, synthetic_batch
from repro.data.pipeline import dedup_token_pool
from repro.serving import HedgedScanService, greedy_generate
from repro.training import OptConfig, make_train_step, train_state_init


def test_paper_pipeline_end_to_end():
    codes = random_dna(50_000, seed=3)
    store = build_tablet_store(codes, is_dna=True)
    svc = HedgedScanService(store)
    stats = svc.run_workload(2000, batch=500, seed=5)
    assert stats["n"] == 2000
    assert 0.0 < stats["hit_rate"] < 0.3
    assert stats["corr_len_outcome"] < -0.2
    # spot exactness
    pats = Q.random_patterns(20, 1, 8, seed=11)
    _, pp, pl = Q.encode_patterns(pats, 112)
    res = Q.query(store, pp, pl)
    for i, p in enumerate(pats):
        from repro.core import codec
        want, _ = Q.brute_force_count(codes, codec.encode_dna(p))
        assert int(res.count[i]) == want


@pytest.mark.filterwarnings("error::RuntimeWarning")
def test_workload_stats_zero_variance_outcome_no_nan():
    """Regression: np.corrcoef on a constant outcome column (hit rate 0.0)
    emitted NaN + RuntimeWarning; stats must stay finite and warning-free."""
    from repro.serving.engine import _safe_corr
    assert _safe_corr(np.array([1.0, 2.0, 3.0]), np.ones(3)) == 0.0
    assert _safe_corr(np.ones(3), np.array([1.0, 2.0, 3.0])) == 0.0
    # all-C text + length >= 12 random patterns: zero hits, outcome constant
    store = build_tablet_store(np.full(2048, 1, np.uint8), is_dna=True)
    svc = HedgedScanService(store)
    stats = svc.run_workload(100, batch=50, min_len=12, max_len=20, seed=0)
    assert stats["hit_rate"] == 0.0
    assert stats["corr_len_outcome"] == 0.0
    assert np.isfinite(stats["corr_len_time"])
    # empty workload must not crash (np.concatenate([]) used to raise)
    empty = svc.run_workload(0)
    assert empty["n"] == 0 and empty["mean_ms"] == 0.0


@pytest.mark.slow
def test_lm_pipeline_with_dedup_and_resume(tmp_path):
    from repro.checkpoint import CheckpointManager
    rng = np.random.default_rng(0)
    # document pool with a planted duplicate
    docs = [rng.integers(0, 512, 100).astype(np.int32) for _ in range(5)]
    docs.append(docs[0].copy())
    tokens = np.concatenate(docs)
    doc_ids = np.repeat(np.arange(6), 100)
    keep = dedup_token_pool(tokens, doc_ids, min_len=32)
    # exact-duplicate pairs are flagged on BOTH members (span symmetry);
    # unique docs survive
    assert not keep[0] and not keep[5]
    assert keep[1:5].all()

    cfg = get_config("qwen3-0.6b").reduced()
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=12)
    state = train_state_init(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg, remat=False))
    data = DataConfig(global_batch=2, seq_len=32)
    mgr = CheckpointManager(str(tmp_path))
    losses = []
    for i in range(8):
        state, m = step(state, synthetic_batch(cfg, data, i))
        losses.append(float(m["loss"]))
        if i == 3:
            mgr.save(4, state, extra={"data_step": 4})
    # every step sees a DIFFERENT synthetic batch, so a strict decrease is
    # a coin flip on noise (it deterministically failed at the seed); the
    # same-batch convergence property lives in test_training.py.  Here we
    # need the pipeline to run sanely and resume bitwise-identically.
    assert all(np.isfinite(l) for l in losses)
    assert abs(losses[-1] - losses[0]) < 1.0      # no divergence

    start, s2, _ = mgr.restore_latest(state)
    for i in range(start, 8):
        s2, m2 = step(s2, synthetic_batch(cfg, data, i))
    np.testing.assert_allclose(float(m["loss"]), float(m2["loss"]),
                               rtol=1e-6)


@pytest.mark.slow
def test_greedy_generation_deterministic():
    cfg = get_config("qwen3-0.6b").reduced()
    params = jax.device_put(
        __import__("repro.models", fromlist=["init_params"]).init_params(
            cfg, jax.random.PRNGKey(0)))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)),
        jnp.int32)}
    out1 = np.asarray(greedy_generate(cfg, params, batch, 6))
    out2 = np.asarray(greedy_generate(cfg, params, batch, 6))
    assert out1.shape == (2, 6)
    assert (out1 == out2).all()
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()

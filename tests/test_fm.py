"""repro.api.fm: the frozen FM-index tier vs its live SA twin.

The load-bearing property: a frozen table is **bit-identical** to a live
twin built over the same text on every read — count / found /
first_rank / first_pos / positions — over random DNA and small-vocab
token corpora, through freeze -> append -> minor_compact -> compact
schedules (frozen is sticky across major compaction), and across a
save/open round trip on a different device count.
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import Catalog, Database, Query, SuffixTable
from repro.api.fm import FMIndex, MAX_VOCAB, sa_is_fully_sorted
from repro.core import codec, query as Q


PATS = ["A", "ACGT", "GATTACA", "TTTT", "CCGG", "A" * 24, "ACGT" * 6]


def _twins(codes, **kw):
    """(live, frozen) tables over the same text."""
    live = SuffixTable.from_codes(codes, is_dna=True, **kw)
    froz = SuffixTable.from_codes(codes, is_dna=True, **kw)
    froz.freeze()
    return live, froz


def _assert_reads_identical(live, froz, pats, top_k=5):
    a, b = live.scan(pats, top_k=top_k), froz.scan(pats, top_k=top_k)
    assert np.array_equal(a.found, b.found)
    assert np.array_equal(a.count, b.count)
    assert np.array_equal(a.first_pos, b.first_pos)
    assert np.array_equal(a.positions, b.positions)
    assert np.array_equal(live.locate(pats, top_k=top_k),
                          froz.locate(pats, top_k=top_k))


# ---------------------------------------------------------------------------
# bit-identity: frozen vs live, random DNA (property test)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.sampled_from([3, 33, 256, 701]), st.integers(0, 2**16))
def test_fm_dna_bit_identical_to_sa_path(n, seed):
    codes = codec.random_dna(n, seed=seed)
    live, froz = _twins(codes)
    assert froz.is_frozen and not live.is_frozen
    # planted substrings guarantee hits; PATS mixes hits and misses
    text = codec.decode_dna(codes)
    rng = np.random.default_rng(seed)
    pats = [p for p in PATS if len(p) <= n]
    for _ in range(3):
        lo = int(rng.integers(0, n))
        pats.append(text[lo:lo + int(rng.integers(1, 12))])
    _assert_reads_identical(live, froz, pats)
    # base-path identity below the merged layer too: found / count /
    # first_rank (the planner's suffix-rank contract) must agree exactly
    patt, plen = live.planner.encode(pats)
    ra = live.planner.scan_encoded(patt, plen)
    rb = froz.planner.scan_encoded(patt, plen)
    for f in ("found", "count", "first_rank", "first_pos"):
        assert np.array_equal(np.asarray(getattr(ra, f)),
                              np.asarray(getattr(rb, f))), f


# ---------------------------------------------------------------------------
# bit-identity: small-vocab token corpora (encoded-batch API — the string
# encoder is DNA-only)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("vocab", [2, 5, 40])
def test_fm_token_corpus_bit_identical(vocab):
    rng = np.random.default_rng(vocab)
    tokens = rng.integers(0, vocab, 1200).astype(np.int32)
    live = SuffixTable.from_codes(tokens, is_dna=False, max_query_len=32)
    froz = SuffixTable.from_codes(tokens, is_dna=False, max_query_len=32)
    froz.freeze()
    # windows of the text (hits) + random junk (mostly misses) + one
    # pattern with an out-of-vocab symbol (must report zero, not garbage)
    W = 8
    patt = np.zeros((10, W), np.int32)
    plen = np.zeros((10,), np.int32)
    for i in range(8):
        lo = int(rng.integers(0, 1200 - W))
        k = int(rng.integers(1, W + 1))
        patt[i, :k] = tokens[lo:lo + k]
        plen[i] = k
    patt[8, :4] = rng.integers(0, vocab, 4)
    plen[8] = 4
    patt[9, :2] = [vocab + 7, 0]
    plen[9] = 2
    a = live.scan_batch(patt, plen, top_k=4)
    b = froz.scan_batch(patt, plen, top_k=4)
    assert np.array_equal(a.count, b.count)
    assert np.array_equal(a.first_pos, b.first_pos)
    assert np.array_equal(a.positions, b.positions)
    assert int(b.count[9]) == 0                 # out-of-vocab symbol


# ---------------------------------------------------------------------------
# lifecycle: freeze -> append -> minor_compact -> compact stays identical,
# and frozen is sticky across major compaction
# ---------------------------------------------------------------------------
def test_freeze_append_compact_schedule():
    codes = codec.random_dna(2000, seed=4)
    live, froz = _twins(codes)
    extra = "GATTACA" * 2 + codec.decode_dna(codec.random_dna(400, seed=5))
    live.append(extra)
    froz.append(extra)                          # boundary-straddling reads
    _assert_reads_identical(live, froz, PATS)
    live.minor_compact()
    froz.minor_compact()                        # sealed-run tier
    _assert_reads_identical(live, froz, PATS)
    v = froz.compact()
    live.compact()
    assert v == froz.version and froz.is_frozen, \
        "frozen is a sticky tier state across major compaction"
    assert froz.stats()["tiers"]["resident_bytes"]["base_sa"] == 0
    _assert_reads_identical(live, froz, PATS)


def test_freeze_adversarial_repeats_counts_exact():
    """Repetitive text exercises the deepest backward-search intervals
    AND the full-order validity check: after a merge-fold compaction the
    stored SA order is only exact to the compare depth, so freeze() must
    detect that and re-derive a true suffix array before taking the BWT.
    """
    codes = codec.encode_dna("ACGT" * 120 + "A" * 160 + "ACGT" * 40)
    t = SuffixTable.from_codes(codes, is_dna=True, max_query_len=64)
    t.append("A" * 90 + "ACGTACGT")
    t.compact()                                 # merge-fold (depth-capped)
    t.freeze()
    cc = np.concatenate([codes, codec.encode_dna("A" * 90 + "ACGTACGT")])
    for p in ["A" * 40, "ACGT" * 10, "AAACGT", "T", "CA"]:
        want, _ = Q.brute_force_count(cc.astype(np.int32),
                                      codec.encode_dna(p).astype(np.int32))
        assert int(t.count([p])[0]) == want, p


def test_sa_is_fully_sorted_detects_depth_capped_order():
    codes = codec.encode_dna("A" * 64)
    n = codes.size
    true_sa = np.arange(n - 1, -1, -1).astype(np.int64)  # shortest-first
    assert sa_is_fully_sorted(codes, true_sa)
    assert not sa_is_fully_sorted(codes, true_sa[::-1].copy())
    assert not sa_is_fully_sorted(codes, np.zeros(n, np.int64))  # not a perm


# ---------------------------------------------------------------------------
# memory + policy
# ---------------------------------------------------------------------------
def test_frozen_resident_bytes_under_quarter_of_sa():
    codes = codec.random_dna(20_000, seed=6)
    live, froz = _twins(codes)
    la = live.stats()["tiers"]
    fa = froz.stats()["tiers"]
    assert la["frozen"] is False and fa["frozen"] is True
    assert fa["resident_bytes"]["base_sa"] == 0
    assert 0 < fa["resident_bytes"]["fm"] <= la["resident_bytes"]["base_sa"] / 4
    for k in ("base_sa", "fm", "text_device", "runs", "memtable",
              "text_host"):
        assert k in fa["resident_bytes"]


def test_fm_threshold_policy_and_vocab_cap():
    # below threshold: stays live; crossing it via compact(): freezes
    t = SuffixTable.from_codes(codec.random_dna(500, seed=7), is_dna=True,
                               fm_threshold=600)
    assert not t.is_frozen
    t.append(codec.decode_dna(codec.random_dna(200, seed=8)))
    assert not t.is_frozen                      # memtable doesn't count
    t.compact()
    assert t.is_frozen                          # base grew past threshold
    # the policy is a no-op on a big-vocab token table...
    big = np.random.default_rng(0).integers(0, 50_000, 300).astype(np.int32)
    tb = SuffixTable.from_codes(big, is_dna=False, max_query_len=16,
                                fm_threshold=10)
    assert not tb.is_frozen
    # ...but an explicit freeze() states why it can't
    with pytest.raises(ValueError, match="vocab"):
        tb.freeze()
    with pytest.raises(ValueError, match="vocab"):
        FMIndex.build(np.arange(MAX_VOCAB + 1, dtype=np.int32), None,
                      is_dna=False)


def test_database_freeze_passthrough():
    db = Database(None)
    db.attach("x", SuffixTable.from_codes(codec.random_dna(1500, seed=9),
                                          is_dna=True))
    tiers = db.freeze("x")
    assert tiers["frozen"] and tiers["resident_bytes"]["fm"] > 0
    ref = SuffixTable.from_codes(codec.random_dna(1500, seed=9), is_dna=True)
    out = db.query(Query.scan("x", PATS, top_k=3))
    want = ref.scan(PATS, top_k=3)
    assert np.array_equal(np.asarray(out.count), want.count)
    assert np.array_equal(np.asarray(out.positions), want.positions)
    db.close()


# ---------------------------------------------------------------------------
# persistence: auto-freeze at create, reopen, artifact lifecycle
# ---------------------------------------------------------------------------
def test_persistent_freeze_reopen_and_drop(tmp_path):
    cat = Catalog(str(tmp_path))
    codes = codec.random_dna(3000, seed=10)
    t = cat.create_table("frz", codes, fm_threshold=1000)
    assert t.is_frozen and os.path.isdir(cat.fm_dir("frz"))
    t.append("GATTACA" * 3)
    t.flush()
    want = t.scan(PATS, top_k=4)
    t.close()
    t2 = cat.open_table("frz")                  # artifact reload, no rebuild
    assert t2.is_frozen
    got = t2.scan(PATS, top_k=4)
    assert np.array_equal(got.count, want.count)
    assert np.array_equal(got.positions, want.positions)
    t2.close()
    # drop removes the per-table auxiliary dirs (fm/, wal/) with the table
    cat.drop_table("frz")
    assert not os.path.isdir(os.path.join(str(tmp_path), "frz"))
    # orphan-dir reconcile: an unregistered name whose dir survived a
    # crashed create/drop (holding a frozen artifact) is removed too
    orphan_fm = cat.fm_dir("ghost")
    os.makedirs(orphan_fm)
    with open(os.path.join(orphan_fm, "junk.bin"), "wb") as f:
        f.write(b"x")
    assert "ghost" not in cat
    cat.drop_table("ghost")
    assert not os.path.isdir(os.path.join(str(tmp_path), "ghost"))
    with pytest.raises(KeyError):
        cat.drop_table("ghost")                 # now truly absent


def test_corrupt_fm_artifact_falls_back_to_rebuild(tmp_path):
    cat = Catalog(str(tmp_path))
    t = cat.create_table("rb", codec.random_dna(1200, seed=11),
                         fm_threshold=100)
    want = t.count(PATS)
    t.close()
    import shutil
    shutil.rmtree(cat.fm_dir("rb"))             # artifact lost, not the table
    t2 = cat.open_table("rb")
    assert t2.is_frozen                         # rebuilt from saved codes
    assert np.array_equal(t2.count(PATS), want)
    t2.close()


# ---------------------------------------------------------------------------
# elastic open: frozen artifact round-trips onto a different device count
# (subprocess, weekly tier)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_frozen_open_across_device_counts(multidevice, tmp_path):
    common = f"""
import json, numpy as np
from repro.api import SuffixTable
from repro.core import codec
ROOT = r'{tmp_path}'
pats = ['A', 'ACGT', 'GATTACA', 'TTTT', 'ACGT' * 6]
"""
    multidevice(common + """
t = SuffixTable.create('fmx', codec.random_dna(4096, seed=12), root=ROOT,
                       fm_threshold=1000)
assert t.is_frozen
out = t.scan(pats, top_k=6)
json.dump({'count': out.count.tolist(),
           'pos': out.positions.tolist()}, open(ROOT + '/want.json', 'w'))
print('OK')
""", n_devices=1)
    multidevice(common + """
t = SuffixTable.open('fmx', root=ROOT)
assert t.is_frozen and t.mesh is None        # frozen serves single-replica
want = json.load(open(ROOT + '/want.json'))
out = t.scan(pats, top_k=6)
assert out.count.tolist() == want['count']
assert out.positions.tolist() == want['pos']
print('OK')
""", n_devices=8)

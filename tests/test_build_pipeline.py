"""Staged out-of-core builds (repro.core.build_pipeline + the streamed
persist protocol): bit-identity with the in-memory builder, spill modes,
shard streaming, crash reconcile, and the build stats schema.

docs/build_pipeline.md documents the pipeline; the contract tested here
is that every configuration — chunk size, spill mode, device count,
corpus kind — produces the SAME array the single-sort builder does.
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # pragma: no cover
    from _hypothesis_compat import given, settings, strategies as st

from repro.api.catalog import Catalog
from repro.api.table import SuffixTable
from repro.checkpoint.manager import CheckpointManager, ShardedSave
from repro.core import codec
from repro.core.build_pipeline import (BYTES_PER_ROW, DEFAULT_CHUNK_ROWS,
                                       MIN_CHUNK_ROWS, BuildStats,
                                       chunk_rows_for_budget,
                                       staged_suffix_array)
from repro.core.dsort import merge_sorted_runs
from repro.core.suffix_array import build_suffix_array, \
    build_suffix_array_staged


def _ref(codes):
    return np.asarray(build_suffix_array(np.asarray(codes, np.int32)))


# --------------------------------------------------------------------------
# merge_sorted_runs
# --------------------------------------------------------------------------
class _ArrRun:
    def __init__(self, key, idx):
        self.n = len(key)
        self._k, self._i = key, idx

    def read_block(self, lo, hi):
        return self._k[lo:hi], self._i[lo:hi]


def test_merge_sorted_runs_matches_lexsort():
    rng = np.random.default_rng(0)
    n, k = 5000, 7
    key = rng.integers(0, 50, size=n).astype(np.int64)   # heavy key ties
    idx = rng.permutation(n).astype(np.int32)            # unique tiebreak
    order = np.lexsort((idx, key))
    cuts = np.sort(rng.choice(np.arange(1, n), size=k - 1, replace=False))
    runs = []
    for lo, hi in zip(np.r_[0, cuts], np.r_[cuts, n]):
        seg = np.lexsort((idx[lo:hi], key[lo:hi]))
        runs.append(_ArrRun(key[lo:hi][seg], idx[lo:hi][seg]))
    got_k, got_i = [], []
    for kb, ib in merge_sorted_runs(runs, block_rows=64):
        assert len(kb) == len(ib)
        got_k.append(kb)
        got_i.append(ib)
    assert np.array_equal(np.concatenate(got_k), key[order])
    assert np.array_equal(np.concatenate(got_i), idx[order])


def test_merge_single_and_empty_runs():
    key = np.arange(100, dtype=np.int64)
    idx = np.arange(100, dtype=np.int32)
    blocks = list(merge_sorted_runs(
        [_ArrRun(key, idx), _ArrRun(key[:0], idx[:0])], block_rows=17))
    assert np.array_equal(np.concatenate([b for b, _ in blocks]), key)
    assert list(merge_sorted_runs([_ArrRun(key[:0], idx[:0])])) == []


# --------------------------------------------------------------------------
# bit-identity property: chunk sizes x spill x corpus kind
# --------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.integers(2, 4000), st.integers(MIN_CHUNK_ROWS, 2048),
       st.booleans(), st.integers(0, 2 ** 31 - 1))
def test_staged_bit_identical_property(n, chunk_rows, dna, seed):
    rng = np.random.default_rng(seed)
    if dna:
        codes = codec.random_dna(n, seed=seed)
    else:
        codes = rng.integers(0, 1 + int(rng.integers(1, 5000)),
                             size=n).astype(np.int32)
    sa, stats = staged_suffix_array(codes, chunk_rows=chunk_rows)
    assert np.array_equal(sa, _ref(codes))
    assert stats.n_chunks == -(-n // max(chunk_rows, MIN_CHUNK_ROWS))
    assert stats.rounds >= 1 and stats.spill_bytes == 0


def test_staged_spill_to_disk_identical_and_cleaned(tmp_path):
    codes = codec.random_dna(20_000, seed=1)
    spill = tmp_path / "spill"
    sa, stats = staged_suffix_array(codes, chunk_rows=777,
                                    spill_dir=str(spill))
    assert np.array_equal(sa, _ref(codes))
    assert stats.spill_bytes > 0
    # every run/rank/sa/scat spill artifact is deleted on completion
    assert [f for f in os.listdir(spill)] == []


def test_staged_emit_shard_streaming():
    codes = codec.random_dna(5000, seed=2)
    shards = []
    sa, stats = staged_suffix_array(
        codes, chunk_rows=512, shard_rows=900,
        emit_shard=lambda i, blk: shards.append((i, blk.copy())))
    assert sa is None
    assert [i for i, _ in shards] == list(range(len(shards)))
    sizes = [len(b) for _, b in shards]
    assert all(s == 900 for s in sizes[:-1]) and sizes[-1] == 5000 % 900
    assert np.array_equal(np.concatenate([b for _, b in shards]),
                          _ref(codes))


def test_staged_edge_sizes():
    for n in (0, 1, 2, 3, MIN_CHUNK_ROWS, MIN_CHUNK_ROWS + 1):
        codes = codec.random_dna(n, seed=n)
        sa, _ = staged_suffix_array(codes, chunk_rows=MIN_CHUNK_ROWS)
        assert np.array_equal(sa, _ref(codes)), n
    # constant text: maximal ties, saturation only at the last round
    const = np.zeros(1000, np.uint8)
    sa, stats = staged_suffix_array(const, chunk_rows=MIN_CHUNK_ROWS)
    assert np.array_equal(sa, _ref(const))
    # wrapper spelling
    assert np.array_equal(
        build_suffix_array_staged(const, chunk_rows=MIN_CHUNK_ROWS), sa)


def test_budget_math():
    assert chunk_rows_for_budget(None) == DEFAULT_CHUNK_ROWS
    assert chunk_rows_for_budget(10 * BYTES_PER_ROW) == MIN_CHUNK_ROWS
    assert chunk_rows_for_budget(100_000) == 100_000 // BYTES_PER_ROW
    _, stats = staged_suffix_array(codec.random_dna(4000, seed=3),
                                   max_device_bytes=MIN_CHUNK_ROWS
                                   * BYTES_PER_ROW)
    assert stats.chunk_rows == MIN_CHUNK_ROWS
    assert stats.peak_device_bytes == MIN_CHUNK_ROWS * BYTES_PER_ROW


# --------------------------------------------------------------------------
# staged create -> open -> stats
# --------------------------------------------------------------------------
def test_staged_create_bit_identical_and_stats(tmp_path):
    codes = codec.random_dna(12_000, seed=4)
    t = SuffixTable.create("g", codes, root=str(tmp_path),
                           build_chunk_rows=1024,
                           spill_dir=str(tmp_path / "spill"))
    ref = _ref(codes)
    assert np.array_equal(
        np.asarray(t.store.sa)[t.store.pad_count:], ref)
    b = t.stats()["build"]
    assert b["mode"] == "staged" and b["spill_bytes"] > 0
    assert set(b) == {"mode", "n_bases", "rounds", "n_chunks", "chunk_rows",
                      "peak_device_bytes", "spill_bytes", "elapsed_s",
                      "bases_per_s"}
    assert b["bases_per_s"] > 0
    # the snapshot on disk is the streamed-shard kind
    mgr = CheckpointManager(str(tmp_path / "g"))
    step = mgr.latest_step()
    step_dir = os.path.join(str(tmp_path / "g"), f"step_{step:010d}")
    assert any(f.startswith("shard_sa_real_")
               for f in os.listdir(step_dir))
    # reads + writes behave like a normal table
    assert int(t.count(["ACGT"])[0]) == int(
        SuffixTable.from_codes(codes, is_dna=True).count(["ACGT"])[0])
    t.append("GATTACA")
    assert int(t.count(["GATTACA"])[0]) >= 1
    t.close()
    # reopen restores the identical SA and the persisted build stats
    t2 = SuffixTable.open("g", root=str(tmp_path))
    assert np.array_equal(
        np.asarray(t2.store.sa)[t2.store.pad_count:], ref)
    b2 = t2.stats()["build"]
    assert b2["mode"] == "staged" and b2["rounds"] == b["rounds"]
    assert BuildStats.from_dict(b2).n_bases == 12_000
    t2.close()


def test_staged_create_token_corpus(tmp_path):
    rng = np.random.default_rng(5)
    codes = rng.integers(0, 30_000, size=6000).astype(np.int32)
    t = SuffixTable.create("tok", codes, root=str(tmp_path),
                           max_device_bytes=512 * BYTES_PER_ROW)
    assert not t.is_dna
    assert np.array_equal(np.asarray(t.store.sa)[t.store.pad_count:],
                          _ref(codes))
    assert t.stats()["build"]["chunk_rows"] == 512
    t.close()


# --------------------------------------------------------------------------
# crash at every shard boundary + reconcile
# --------------------------------------------------------------------------
def test_kill_at_every_shard_boundary(tmp_path, monkeypatch):
    """A create killed after ANY number of streamed shards (abort never
    runs — a hard kill) leaves no published snapshot; the next catalog
    open garbage-collects the remnant and a re-create succeeds and is
    bit-identical."""
    codes = codec.random_dna(4000, seed=6)
    ref = _ref(codes)
    n_shards = -(-4000 // 512)

    class _Kill(BaseException):
        pass

    orig_add = ShardedSave.add_shard
    orig_commit = ShardedSave.commit
    monkeypatch.setattr(ShardedSave, "abort", lambda self: None)
    for die_at in range(n_shards + 1):        # +1: die at commit instead
        root = tmp_path / f"r{die_at}"
        seen = {"n": 0}

        def add(self, name, i, arr, _die=die_at, _seen=seen):
            if _seen["n"] == _die:
                raise _Kill()
            _seen["n"] += 1
            return orig_add(self, name, i, arr)

        monkeypatch.setattr(ShardedSave, "add_shard", add)
        if die_at == n_shards:
            monkeypatch.setattr(
                ShardedSave, "commit",
                lambda self, state, extra=None: (_ for _ in ()).throw(
                    _Kill()))
        with pytest.raises(_Kill):
            SuffixTable.create("t", codes, root=str(root),
                               build_chunk_rows=512, shard_rows=512)
        monkeypatch.setattr(ShardedSave, "add_shard", orig_add)
        monkeypatch.setattr(ShardedSave, "commit", orig_commit)
        # the kill left a registered entry + partial stream, no snapshot
        cat = Catalog(str(root), reconcile=False)
        assert "t" in cat
        with pytest.raises(FileNotFoundError):
            SuffixTable.open("t", root=str(root))
        Catalog(str(root))                    # open-time auto-reconcile
        assert "t" not in Catalog(str(root)).list_tables()
        assert not os.path.isdir(root / "t")
        t = SuffixTable.create("t", codes, root=str(root),
                               build_chunk_rows=512, shard_rows=512)
        assert np.array_equal(
            np.asarray(t.store.sa)[t.store.pad_count:], ref)
        t.close()


def test_reconcile_cases(tmp_path):
    codes = codec.random_dna(600, seed=7)
    t = SuffixTable.create("keep", codes, root=str(tmp_path))
    t.close()
    # 1. stale .tmp stage inside a healthy table (crashed re-publish)
    os.makedirs(tmp_path / "keep" / "step_0000000099.tmp")
    # 2. unregistered remnant: only table machinery inside
    os.makedirs(tmp_path / "ghost" / "step_0000000001.tmp")
    os.makedirs(tmp_path / "ghost" / "wal")
    # 3. unregistered dir holding USER data: must never be touched
    os.makedirs(tmp_path / "userdata")
    (tmp_path / "userdata" / "notes.txt").write_text("keep me")
    removed = Catalog(str(tmp_path), reconcile=False).reconcile()
    assert removed == ["ghost"]
    assert not (tmp_path / "keep" / "step_0000000099.tmp").exists()
    assert (tmp_path / "userdata" / "notes.txt").exists()
    # the healthy table still opens with its data intact
    t2 = SuffixTable.open("keep", root=str(tmp_path))
    assert np.array_equal(np.asarray(t2.store.sa)[t2.store.pad_count:],
                          _ref(codes))
    t2.close()
    # 4. a data-bearing orphan (crashed drop: unregistered, HAS snapshot)
    #    is preserved for drop_table, not GC'd
    cat = Catalog(str(tmp_path), reconcile=False)
    data = cat.load()
    del data["tables"]["keep"]
    cat._write(data)
    assert cat.reconcile() == []
    assert (tmp_path / "keep").is_dir()
    cat.drop_table("keep")                    # finishes the drop
    assert not (tmp_path / "keep").exists()


def test_sharded_save_protocol(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    stage = mgr.stage_sharded(1)
    stage.add_shard("sa_real", 0, np.arange(5, dtype=np.int32))
    with pytest.raises(ValueError, match="out of order"):
        stage.add_shard("sa_real", 2, np.arange(3, dtype=np.int32))
    stage.add_shard("sa_real", 1, np.arange(5, 8, dtype=np.int32))
    assert mgr.latest_step() is None          # nothing visible pre-commit
    stage.commit({"codes": np.zeros(8, np.uint8)}, {"v": 1})
    arrays, extra = mgr.restore_arrays(1)
    got = {k.strip("[']"): v for k, v in arrays.items()}
    assert np.array_equal(got["sa_real"], np.arange(8))
    assert got["sa_real"].dtype == np.int32 and extra == {"v": 1}
    with pytest.raises(RuntimeError, match="already"):
        stage.add_shard("sa_real", 2, np.zeros(1, np.int32))
    # abort leaves nothing behind
    stage2 = mgr.stage_sharded(2)
    stage2.add_shard("x", 0, np.ones(4))
    stage2.abort()
    assert mgr.latest_step() == 1
    assert not os.path.exists(stage2.tmp)


# --------------------------------------------------------------------------
# device-count portability: 1 -> 8 -> 1
# --------------------------------------------------------------------------
@pytest.mark.multidevice
def test_staged_build_8dev_bit_identical(multidevice):
    """The mesh super-chunk path (8 devices) produces the same SA and the
    same persisted table as the single-device staged build; reopening on
    1 device serves it unchanged."""
    out = multidevice("""
import numpy as np, tempfile
import jax
from repro.api.table import SuffixTable
from repro.core import codec
from repro.core.build_pipeline import staged_suffix_array
from repro.core.suffix_array import build_suffix_array
from repro.launch.mesh import make_tablet_mesh

assert len(jax.devices()) == 8
codes = codec.random_dna(15_000, seed=11)
ref = np.asarray(build_suffix_array(codes.astype(np.int32)))
mesh = make_tablet_mesh(8)
sa, stats = staged_suffix_array(codes, chunk_rows=256, mesh=mesh,
                                axis_name="tablets")
assert np.array_equal(sa, ref)
assert stats.peak_device_bytes == 256 * 24
with tempfile.TemporaryDirectory() as root:
    t = SuffixTable.create("g8", codes, root=root, build_chunk_rows=256)
    assert np.array_equal(np.asarray(t.store.sa)[t.store.pad_count:], ref)
    assert t.stats()["build"]["mode"] == "staged"
    t.close()
print("SA8_OK")
""")
    assert "SA8_OK" in out
    # and a table persisted under 8 devices reopens identically under 1
    out = multidevice("""
import numpy as np, tempfile, subprocess, sys, os
from repro.api.table import SuffixTable
from repro.core import codec
root = tempfile.mkdtemp()
codes = codec.random_dna(8000, seed=12)
t = SuffixTable.create("port", codes, root=root, build_chunk_rows=512)
sa = np.asarray(t.store.sa)[t.store.pad_count:]
np.save(os.path.join(root, "ref.npy"), sa)
t.close()
print(root)
""")
    root = out.strip().splitlines()[-1]
    import subprocess
    import sys

    from conftest import SRC
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    code = f"""
import numpy as np, os
from repro.api.table import SuffixTable
root = {root!r}
t = SuffixTable.open("port", root=root)
ref = np.load(os.path.join(root, "ref.npy"))
assert np.array_equal(np.asarray(t.store.sa)[t.store.pad_count:], ref)
assert t.stats()["build"]["mode"] == "staged"
print("REOPEN1_OK")
"""
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "REOPEN1_OK" in proc.stdout

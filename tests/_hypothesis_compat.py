"""Seeded fallback for the tiny slice of the `hypothesis` API these tests
use, so the suite collects and runs when hypothesis is not installed.

Real hypothesis (shrinking, example database, coverage-guided generation)
is strictly better — install it via requirements-dev.txt when possible.
The fallback keeps the *property-test shape* of the suite: each `@given`
test still runs `max_examples` randomized cases, drawn from a PRNG seeded
by the test name so failures are reproducible run-to-run.

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import os
import random
import zlib

# the fallback has no shrinking/coverage guidance, so very high example
# counts buy little — cap them to keep tier-1 fast (override via env)
_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_FALLBACK_MAX_EXAMPLES", "15"))


class _Strategy:
    """A strategy is just a draw function over a ``random.Random``."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def text(alphabet: str = "abcdefghij", min_size: int = 0,
             max_size: int = 20) -> _Strategy:
        alphabet = list(alphabet)

        def draw(rng):
            n = rng.randint(min_size, max_size)
            return "".join(rng.choice(alphabet) for _ in range(n))

        return _Strategy(draw)

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)


strategies = _Strategies()
st = strategies


def settings(max_examples: int = 20, deadline=None, **_kw):
    """Records max_examples on the test function; consumed by @given."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    """Runs the test ``max_examples`` times with freshly drawn arguments.
    The PRNG seed derives from the test name, so runs are deterministic
    and a falsifying draw reproduces on re-run."""

    def deco(fn):
        n_examples = min(getattr(fn, "_max_examples", 20), _MAX_EXAMPLES_CAP)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(n_examples):
                drawn = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *drawn, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: "
                        f"{drawn!r}") from e

        # functools.wraps sets __wrapped__, which would make pytest see the
        # original signature and demand fixtures for the drawn arguments
        del wrapper.__wrapped__
        return wrapper

    return deco

"""repro.api: SuffixTable lifecycle, the memtable write path, the catalog.

The load-bearing property: after any sequence of appends, merged reads
(count / first_pos / positions) exactly match a from-scratch
``build_tablet_store`` oracle over the concatenated text — including
patterns straddling the base/append boundary — before AND after
``compact()``, and again after ``open()`` in a fresh runtime.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import Catalog, SuffixTable
from repro.core import codec, query as Q
from repro.core.tablet import build_tablet_store
from repro.serving import HedgedScanService


def _oracle(codes: np.ndarray, pattern: str):
    """(count, first_pos=smallest position, all positions) by Algorithm 1."""
    cc = np.asarray(codes).astype(np.int32)
    pc = codec.encode_dna(pattern).astype(np.int32)
    k = len(pc)
    pos = [i for i in range(len(cc) - k + 1)
           if (cc[i:i + k] == pc).all()]
    return len(pos), (pos[0] if pos else -1), pos


def _check_vs_oracle(table, combined, patterns, top_k=16):
    out = table.scan(patterns, top_k=top_k)
    for i, p in enumerate(patterns):
        want, first, pos = _oracle(combined, p)
        assert int(out.count[i]) == want, (p, int(out.count[i]), want)
        assert bool(out.found[i]) == (want > 0)
        assert int(out.first_pos[i]) == first, (p, "first_pos")
        got = [int(x) for x in out.positions[i] if x >= 0]
        # text-order semantics: the top_k smallest positions, ascending —
        # the complete occurrence set whenever count <= top_k
        assert got == pos[:top_k], p


# ---------------------------------------------------------------------------
# persistence round trip + catalog
# ---------------------------------------------------------------------------
def test_create_open_round_trip(tmp_path):
    codes = codec.random_dna(4000, seed=0)
    pats = Q.random_patterns(24, 1, 10, seed=1)
    t = SuffixTable.create("dna", codes, root=str(tmp_path))
    assert t.version == 1 and t.is_persistent
    before = t.scan(pats, top_k=8)
    t2 = SuffixTable.open("dna", root=str(tmp_path))
    after = t2.scan(pats, top_k=8)
    assert (before.count == after.count).all()
    assert (before.first_pos == after.first_pos).all()
    assert (before.positions == after.positions).all()
    _check_vs_oracle(t2, codes, pats[:8])


def test_create_refuses_duplicates(tmp_path):
    codes = codec.random_dna(200, seed=0)
    SuffixTable.create("t", codes, root=str(tmp_path))
    with pytest.raises(FileExistsError):
        SuffixTable.create("t", codes, root=str(tmp_path))
    t = SuffixTable.create("t", codes[:100], root=str(tmp_path),
                           overwrite=True)
    assert t.n_base == 100
    with pytest.raises(FileNotFoundError):
        SuffixTable.open("nope", root=str(tmp_path))
    # a failed open must not litter the root with empty table dirs
    assert not (tmp_path / "nope").exists()
    for bad in ("bad/name", ".", "..", ".hidden", "catalog.json", ""):
        with pytest.raises(ValueError):
            SuffixTable.create(bad, codes, root=str(tmp_path))


def test_overwrite_drops_stale_snapshots(tmp_path):
    """Regression: overwrite=True used to leave the old table's higher-
    numbered snapshots in place, so open() restored the OLD data (or the
    keep_n GC deleted the fresh version-1 save)."""
    old = codec.random_dna(300, seed=1)
    t = SuffixTable.create("t", old, root=str(tmp_path))
    for i in range(4):                         # versions 2..5 (keep_n=3)
        t.append(codec.random_dna(50, seed=2 + i))
        t.compact()
    assert t.version == 5
    new = codec.random_dna(120, seed=9)
    SuffixTable.create("t", new, root=str(tmp_path), overwrite=True)
    t2 = SuffixTable.open("t", root=str(tmp_path))
    assert t2.version == 1 and t2.n_base == 120
    assert (np.asarray(t2.store.text_codes[:120])
            == new.astype(np.int32)).all()


def test_flush_raises_on_in_memory_table():
    t = SuffixTable.from_codes(codec.random_dna(100, seed=0))
    t.append("ACGT")
    with pytest.raises(RuntimeError, match="non-persistent"):
        t.flush()


def test_catalog_manages_mixed_tables(tmp_path):
    """DNA + token corpora as named tables in one root (METADATA analogue)."""
    cat = Catalog(str(tmp_path))
    cat.create_table("dna", codec.random_dna(500, seed=1), is_dna=True)
    tokens = np.random.default_rng(0).integers(0, 50_000, 600).astype(np.int32)
    cat.create_table("tokens", tokens, is_dna=False, max_query_len=32)
    assert cat.list_tables() == ["dna", "tokens"]
    assert "dna" in cat and "missing" not in cat
    assert cat.table_meta("tokens")["is_dna"] is False
    tok = cat.open_table("tokens")
    assert not tok.is_dna and tok.max_query_len == 32
    import jax.numpy as jnp
    res = tok.scan_encoded(jnp.asarray(tokens[100:108][None]),
                           jnp.asarray([8]))
    assert int(res.count[0]) >= 1
    cat.drop_table("dna")
    assert cat.list_tables() == ["tokens"]
    with pytest.raises(KeyError):
        cat.drop_table("dna")
    cat.drop_table("dna", missing_ok=True)


# ---------------------------------------------------------------------------
# the write path: append / merged reads / compact
# ---------------------------------------------------------------------------
def test_append_merged_reads_match_oracle_through_compact():
    base = codec.random_dna(3000, seed=2)
    t = SuffixTable.from_codes(base, is_dna=True)
    combined = base
    rng = np.random.default_rng(3)
    for step in range(3):                      # several appends stack up
        app = codec.random_dna(200 + 50 * step, seed=10 + step)
        t.append(app)
        n_before = len(combined)
        combined = np.concatenate([combined, app])
        # patterns: random, planted-in-append, straddling the boundary
        pats = Q.random_patterns(12, 1, 8, seed=20 + step)
        pats.append(codec.decode_dna(combined[n_before + 3:n_before + 11]))
        for off in (1, 4, 7):                  # straddle old end-of-text
            lo = n_before - off
            pats.append(codec.decode_dna(combined[lo:lo + off + 5]))
        short = int(rng.integers(1, 3))        # high-count short patterns
        pats.append(codec.decode_dna(combined[:short]))
        _check_vs_oracle(t, combined, pats)
    assert t.memtable.size == len(combined) - 3000
    # merged counts == a from-scratch store built over the same text
    patt, plen = t.planner.encode(pats)
    fresh = build_tablet_store(combined, is_dna=True)
    ref = Q.query(fresh, patt, plen)
    res = t.scan_encoded(patt, plen)
    assert (np.asarray(res.count) == np.asarray(ref.count)).all()
    v = t.compact()
    assert v == 1 and t.memtable.size == 0 and t.n_base == len(combined)
    _check_vs_oracle(t, combined, pats)
    res2 = t.scan_encoded(patt, plen)       # post-compact: base-only path
    assert (np.asarray(res2.count) == np.asarray(ref.count)).all()
    assert (np.asarray(res2.first_pos) == np.asarray(ref.first_pos)).all()


def test_append_beyond_paper_boundary_window_is_exact():
    """A pattern of exactly max_query_len straddling by one symbol is the
    overlap window's worst case; counts must stay exact."""
    base = codec.random_dna(600, seed=4)
    t = SuffixTable.from_codes(base, is_dna=True, max_query_len=32)
    app = codec.random_dna(100, seed=5)
    t.append(app)
    combined = np.concatenate([base, app])
    edge = [codec.decode_dna(combined[600 - 31:600 - 31 + 32]),   # 1 in new
            codec.decode_dna(combined[600 - 1:600 - 1 + 32]),     # 31 in new
            codec.decode_dna(combined[600 - 16:600 - 16 + 32])]
    _check_vs_oracle(t, combined, edge, top_k=4)


@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(10, 200))
@settings(max_examples=4, deadline=None)
def test_append_property_counts_and_positions(seed, n_appends, chunk):
    """Property: append+query == brute-force oracle, any seed/shape."""
    rng = np.random.default_rng(seed)
    base = codec.random_dna(int(rng.integers(300, 900)), seed=seed)
    t = SuffixTable.from_codes(base, is_dna=True)
    combined = base
    for a in range(n_appends):
        app = codec.random_dna(chunk, seed=seed * 7 + a)
        t.append(app)
        combined = np.concatenate([combined, app])
    n_base = len(base)
    pats = Q.random_patterns(8, 1, 9, seed=seed + 1)
    pats.append(codec.decode_dna(combined[n_base - 2:n_base + 4]))
    out = t.scan(pats, top_k=8)
    for i, p in enumerate(pats):
        want, first, _pos = _oracle(combined, p)
        assert int(out.count[i]) == want, (p, int(out.count[i]), want)
        assert int(out.first_pos[i]) == first, p
        for q in out.positions[i]:
            if q >= 0:
                got = codec.decode_dna(combined[int(q):int(q) + len(p)])
                assert got == p


def test_flush_persists_memtable(tmp_path):
    base = codec.random_dna(800, seed=6)
    t = SuffixTable.create("t", base, root=str(tmp_path))
    t.append("GATTACAGATTACA")
    t.flush()                                  # durable without compaction
    t2 = SuffixTable.open("t", root=str(tmp_path))
    assert t2.version == 1 and t2.memtable.size == 14
    assert int(t2.count(["GATTACAGATTACA"])[0]) >= 1
    combined = np.concatenate([base, codec.encode_dna("GATTACAGATTACA")])
    _check_vs_oracle(t2, combined, ["GATTACA", "ACGT"])


def test_compact_bumps_version_and_reopens(tmp_path):
    base = codec.random_dna(700, seed=7)
    t = SuffixTable.create("t", base, root=str(tmp_path))
    t.append(codec.random_dna(300, seed=8))
    assert t.compact() == 2
    assert t.compact() == 2                    # empty memtable: no-op
    t2 = SuffixTable.open("t", root=str(tmp_path))
    assert t2.version == 2 and t2.n_base == 1000 and t2.memtable.size == 0


def test_memtable_limit_seals_runs_and_max_runs_majors():
    """``memtable_limit`` now triggers MINOR compaction (seal to an
    immutable run, base untouched); ``max_runs`` triggers the major fold."""
    t = SuffixTable.from_codes(codec.random_dna(500, seed=9), is_dna=True,
                               memtable_limit=100, max_runs=2)
    t.append(codec.random_dna(60, seed=1))
    assert t.memtable.size == 60 and t.version == 0 and not t.runs
    t.append(codec.random_dna(60, seed=2))     # crosses the limit: seal
    assert t.memtable.size == 0 and len(t.runs) == 1
    assert t.version == 0 and t.n_base == 500  # minor: base untouched
    assert len(t) == 620
    t.append(codec.random_dna(120, seed=3))    # second seal hits max_runs
    assert t.memtable.size == 0 and not t.runs
    assert t.version == 1 and t.n_base == 740  # major: folded into base


def test_token_table_append_and_encoded_reads():
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 50_000, 2000).astype(np.int32)
    t = SuffixTable.from_codes(tokens, is_dna=False, max_query_len=64)
    extra = rng.integers(0, 50_000, 300).astype(np.int32)
    t.append(extra)
    combined = np.concatenate([tokens, extra])
    import jax.numpy as jnp
    # window straddling the boundary + window inside the append
    for lo in (1995, 2100):
        w = combined[lo:lo + 10]
        res = t.scan_encoded(jnp.asarray(w[None]), jnp.asarray([10]))
        assert int(res.count[0]) >= 1, lo
        assert int(res.first_pos[0]) == lo
    with pytest.raises(TypeError):
        t.append("ACGT")                       # strings are DNA-only


def test_pattern_longer_than_cap_raises():
    t = SuffixTable.from_codes(codec.random_dna(400, seed=0), is_dna=True,
                               max_query_len=16)
    with pytest.raises(ValueError, match="max_pattern_len"):
        t.scan(["A" * 17])
    with pytest.raises(ValueError, match="max_pattern_len"):
        t.planner.scan(["A" * 17])
    # encoded path validates too (would otherwise silently truncate)
    import jax.numpy as jnp
    _, pp, pl = Q.encode_patterns(["A" * 17], 32)
    with pytest.raises(ValueError, match="max_pattern_len"):
        t.planner.scan_encoded(pp, pl)
    assert int(t.count(["A" * 16])[0]) >= 0    # at the cap: fine


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------
def test_hedged_service_accepts_table_and_store_shim():
    codes = codec.random_dna(5000, seed=1)
    table = SuffixTable.from_codes(codes, is_dna=True)
    svc_t = HedgedScanService(table, seed=3)
    store = build_tablet_store(codes, is_dna=True)
    svc_s = HedgedScanService(store, seed=3)   # deprecation shim
    assert svc_s.store is store and svc_t.store is table.store
    a = svc_t.run_workload(200, batch=100, seed=1)
    b = svc_s.run_workload(200, batch=100, seed=1)
    assert a["hit_rate"] == b["hit_rate"]
    assert a["mean_ms"] == b["mean_ms"]        # same rng stream, same seed


def test_hedged_service_rng_is_reproducible_not_mutating():
    """Regression: scan() used to mutate self.seed per call, so equal-value
    services diverged and the dataclass compared unequal to itself."""
    store = build_tablet_store(codec.random_dna(2000, seed=0), is_dna=True)
    s1 = HedgedScanService(store, seed=7)
    s2 = HedgedScanService(store, seed=7)
    r1 = s1.run_workload(300, batch=100, seed=2)
    r2 = s2.run_workload(300, batch=100, seed=2)
    assert r1 == r2                            # identical latency stream
    assert s1.seed == 7 and s2.seed == 7       # field never mutated
    # a service also sees appends through the table (merged serving reads)
    table = SuffixTable.from_codes(codec.random_dna(2000, seed=0))
    svc = HedgedScanService(table)
    probe = "GATTACA" * 3
    _, pp, pl = Q.encode_patterns([probe], 32)
    base_count = int(svc.scan(pp, pl, hedged=False)[0].count[0])
    table.append(probe)
    assert int(svc.scan(pp, pl, hedged=False)[0].count[0]) == base_count + 1


# ---------------------------------------------------------------------------
# elastic persistence: 1 <-> 8 device meshes (subprocess, weekly tier)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_save_open_round_trip_across_device_counts(multidevice, tmp_path):
    """create on 1 device -> open/append/compact on an 8-tablet mesh ->
    open on 1 device again; counts stay oracle-exact throughout."""
    common = f"""
import json, numpy as np
from repro.api import SuffixTable
from repro.core import codec, query as Q
ROOT = r'{tmp_path}'
pats = Q.random_patterns(48, 1, 10, seed=3) + ['A', 'ACGT']
"""
    multidevice(common + """
codes = codec.random_dna(4096, seed=5)
t = SuffixTable.create('elastic', codes, root=ROOT)
out = t.scan(pats, top_k=8)
json.dump({'count': out.count.tolist(),
           'first': out.first_pos.tolist()},
          open(ROOT + '/expect.json', 'w'))
print('OK')
""", n_devices=1)
    multidevice(common + """
t = SuffixTable.open('elastic', root=ROOT)
assert t.planner.num_tablets == 8 and t.mesh is not None
want = json.load(open(ROOT + '/expect.json'))
out = t.scan(pats, top_k=8)
assert out.count.tolist() == want['count']
assert out.first_pos.tolist() == want['first']
# big encoded batch takes the routed path on the mesh; still exact
patt, plen = t.planner.encode(pats * 4)
assert t.planner.plan(len(pats) * 4).mode == 'routed'
res = t.scan_encoded(patt, plen)
assert np.asarray(res.count).tolist() == want['count'] * 4
app = codec.random_dna(512, seed=6)
t.append(app)
t.compact()                       # distributed rebuild + persist v2
combined = np.concatenate([codec.random_dna(4096, seed=5), app])
cc = combined.astype(np.int32)
out3 = t.scan(pats)
for i, p in enumerate(pats):
    want_c, _ = Q.brute_force_count(cc, codec.encode_dna(p).astype(np.int32))
    assert int(out3.count[i]) == want_c, p
json.dump({'count': out3.count.tolist()}, open(ROOT + '/expect2.json', 'w'))
print('OK')
""", n_devices=8)
    multidevice(common + """
t = SuffixTable.open('elastic', root=ROOT)
assert t.version == 2 and t.planner.num_tablets == 1
want = json.load(open(ROOT + '/expect2.json'))
assert t.scan(pats).count.tolist() == want['count']
print('OK')
""", n_devices=1)

"""Suffix-array construction: JAX prefix doubling vs the naive oracle."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored seeded-random shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import codec
from repro.core.suffix_array import (adjacent_lcp, build_suffix_array,
                                     rank_array, suffix_array_naive)


def test_paper_mississippi_example():
    """Paper §III: the MISSISSIPPI ordered-suffix table."""
    text = "MISSISSIPPI"
    codes = np.frombuffer(text.encode(), dtype=np.uint8)
    sa = np.asarray(build_suffix_array(codes))
    suffixes = [text[i:] for i in sa]
    assert suffixes == sorted(text[i:] for i in range(len(text)))
    assert suffixes[0] == "I"
    assert suffixes[-1] == "SSISSIPPI"


@given(st.text(alphabet="ACGT", min_size=1, max_size=200))
@settings(max_examples=40, deadline=None)
def test_matches_oracle(s):
    codes = codec.encode_dna(s)
    sa = np.asarray(build_suffix_array(codes))
    assert (sa == suffix_array_naive(codes)).all()


@given(st.lists(st.integers(0, 50000), min_size=2, max_size=100))
@settings(max_examples=25, deadline=None)
def test_generic_alphabet(tokens):
    """Token corpora (large vocab) sort identically."""
    codes = np.asarray(tokens, np.int32)
    sa = np.asarray(build_suffix_array(codes))
    assert (sa == suffix_array_naive(codes)).all()


@given(st.text(alphabet="ACGT", min_size=2, max_size=120))
@settings(max_examples=25, deadline=None)
def test_sa_is_permutation_and_sorted(s):
    """Invariants: SA is a permutation; suffixes strictly increasing."""
    codes = codec.encode_dna(s)
    sa = np.asarray(build_suffix_array(codes))
    n = len(codes)
    assert sorted(sa.tolist()) == list(range(n))
    b = codes.tobytes()
    for i in range(n - 1):
        assert b[sa[i]:] < b[sa[i + 1]:]


def test_rank_is_inverse():
    codes = codec.random_dna(500, seed=1)
    sa = build_suffix_array(codes)
    rank = np.asarray(rank_array(sa))
    sa = np.asarray(sa)
    assert (rank[sa] == np.arange(500)).all()


@given(st.text(alphabet="ACG", min_size=2, max_size=80),
       st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_adjacent_lcp(s, cap):
    codes = codec.encode_dna(s)
    sa = build_suffix_array(codes)
    lcp = np.asarray(adjacent_lcp(jnp.asarray(codes, jnp.int32), sa, cap))
    sa = np.asarray(sa)
    n = len(codes)
    for i in range(n - 1):
        a, b = sa[i], sa[i + 1]
        true = 0
        while (a + true < n and b + true < n
               and codes[a + true] == codes[b + true] and true < cap):
            true += 1
        assert lcp[i] == true

"""The unified read path: TierSet + fused multi-tier scan + adaptive
coalescing (docs/read_path.md).

Load-bearing properties:

* over random append/seal/compact LSM schedules the fused read path —
  ``scan_encoded`` counts, ``scan_batch`` merged counts / text-minimum
  first_pos / top-k positions, ``locate_range`` enumeration — is
  bit-identical to the per-tier fan-out oracle (base scan +
  ``Run.match_positions`` + ``Memtable.match_positions`` merge) AND to
  the paper's Algorithm 1 brute force, for DNA-packed and token tables;
* ``TierSet.delta_positions`` (host slicing of the fused less/matches
  bounds) returns exactly the per-tier ``match_positions`` sets without
  any per-tier dispatch;
* the base-only fast path skips tier fan-out entirely, and the planner's
  ``fused_batches`` / ``base_only_batches`` / ``tier_reads`` counters
  account every read (docs/client_api.md schema);
* the adaptive ``QueryScheduler``: sparse arrivals take the inline fast
  path (no coalesce-window sleep), concurrent callers still coalesce,
  ``adaptive=False`` restores the fixed window, and the stats snapshot
  exports ``window_ms_current`` / ``ewma_gap_ms`` / ``fast_path_queries``.
"""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import Database, Query, SuffixTable
from repro.api.client import QueryScheduler
from repro.core import codec, query as Q


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------
def _brute(combined, codes):
    """(count, first_pos, positions) by Algorithm 1 over raw codes."""
    cc = np.asarray(combined).astype(np.int32)
    pc = np.asarray(codes).astype(np.int32)
    k = len(pc)
    pos = [i for i in range(len(cc) - k + 1) if (cc[i:i + k] == pc).all()]
    return len(pos), (pos[0] if pos else -1), pos


def _per_tier_oracle(table, patt, plen):
    """The retired fan-out read: one base scan + one ``match_positions``
    call per live tier, merged on host.  Returns (count, first_pos,
    delta_positions) with delta_positions[i] sorted global starts."""
    import jax.numpy as jnp
    patt = jnp.asarray(patt)
    plen_j = jnp.asarray(plen)
    base = table.planner.scan_encoded(patt, plen_j)
    tiers = [r for r in table.runs if r.length]
    if table.memtable.size:
        tiers.append(table.memtable)
    per = [t.match_positions(patt, plen_j) for t in tiers]
    B = int(np.asarray(plen).shape[0])
    count = np.asarray(base.count).astype(np.int64)[:B].copy()
    # base text-minimum: min over the base SA's prefix-match run
    sa = np.asarray(table.store.sa).astype(np.int64)
    pad = table.store.pad_count
    fr = np.asarray(base.first_rank).astype(np.int64)[:B]
    first = np.full(B, np.iinfo(np.int64).max)
    for i in range(B):
        if count[i] > 0:
            lb = pad + fr[i]
            first[i] = sa[lb:lb + count[i]].min()
    delta = []
    for i in range(B):
        d = np.sort(np.concatenate(
            [np.asarray(p[i], np.int64) for p in per]
            + [np.zeros(0, np.int64)]))
        delta.append(d)
        count[i] += d.size
        if d.size:
            first[i] = min(first[i], d[0])
    first = np.where(count > 0, first, -1)
    return count, first, delta


def _encode_for(table, pats):
    """planner.encode for DNA strings; manual int32 codes otherwise
    (token patterns are raw code arrays, not text)."""
    import jax.numpy as jnp
    if table.is_dna:
        return table.planner.encode(pats)
    W = max(len(p) for p in pats)
    patt = np.zeros((len(pats), W), np.int32)
    plen = np.array([len(p) for p in pats], np.int32)
    for i, p in enumerate(pats):
        patt[i, :len(p)] = np.asarray(p, np.int32)
    return jnp.asarray(patt), jnp.asarray(plen)


def _check_table(table, combined, pats, top_k=12):
    """Fused read surfaces vs per-tier oracle vs brute force."""
    patt, plen = _encode_for(table, pats)
    ocount, ofirst, odelta = _per_tier_oracle(table, patt, plen)

    # fused delta enumeration == per-tier match_positions, bit for bit
    ts = table._tierset()
    if ts is not None:
        merged, tres = table.planner.scan_tiers(ts, patt, plen)
        delta = ts.delta_positions(tres.less, tres.matches, plen)
        for i in range(len(pats)):
            np.testing.assert_array_equal(delta[i], odelta[i], err_msg=pats[i])

    out = table.scan_batch(patt, plen, top_k=top_k)
    res = table.scan_encoded(patt, plen)
    for i, p in enumerate(pats):
        codes_p = (codec.encode_dna(p) if table.is_dna
                   else np.asarray(p, np.int32))
        want, first, pos = _brute(combined, codes_p)
        assert want == ocount[i] and first == ofirst[i], (p, "oracle split")
        assert int(out.count[i]) == want, (p, int(out.count[i]), want)
        assert int(res.count[i]) == want, (p, "scan_encoded")
        assert int(out.first_pos[i]) == first, (p, "first_pos")
        got = [int(x) for x in out.positions[i] if x >= 0]
        assert got == pos[:top_k], p
        if table.is_dna:                   # locate_range takes pattern text
            after = pos[0] if pos else -1  # resume past the first hit
            rng_pos = table.locate_range(p, after=after, limit=None)
            assert [int(x) for x in rng_pos] == [q for q in pos
                                                 if q > after], (p, "range")


def _plant_patterns(rng, combined, boundaries, is_dna, n_random=8):
    """Random patterns plus ones planted to straddle tier boundaries."""
    pats = []
    for _ in range(n_random):
        L = int(rng.integers(1, 11))
        s = int(rng.integers(0, max(1, len(combined) - L)))
        frag = combined[s:s + L]
        pats.append(codec.decode_dna(frag) if is_dna
                    else np.asarray(frag, np.int32))
    for b in boundaries:
        for off in (1, 4):
            lo, hi = b - off, b - off + off + 4
            if 0 <= lo and hi <= len(combined):
                frag = combined[lo:hi]
                pats.append(codec.decode_dna(frag) if is_dna
                            else np.asarray(frag, np.int32))
    pats.append(codec.decode_dna(np.array([3, 3, 3, 2], np.uint8))
                if is_dna else np.asarray([10 ** 6], np.int32))  # miss
    return pats


# ---------------------------------------------------------------------------
# property: random LSM schedules, DNA and token tables
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(0, 1))
@settings(max_examples=6, deadline=None)
def test_property_fused_read_equals_per_tier_fanout(seed, n_steps, is_dna):
    is_dna = bool(is_dna)
    rng = np.random.default_rng(seed)
    n0 = int(rng.integers(300, 900))
    if is_dna:
        base = codec.random_dna(n0, seed=seed)
        table = SuffixTable.from_codes(base, is_dna=True,
                                       memtable_limit=300)
    else:
        base = rng.integers(0, 40, n0).astype(np.int32)
        table = SuffixTable.from_codes(base, is_dna=False,
                                       max_query_len=32,
                                       memtable_limit=300)
    combined = base
    boundaries = [len(base)]
    for s in range(n_steps):
        ln = int(rng.integers(30, 170))
        app = (codec.random_dna(ln, seed=seed * 17 + s) if is_dna
               else rng.integers(0, 40, ln).astype(np.int32))
        table.append(app)
        combined = np.concatenate([combined, app])
        boundaries.append(len(combined))
        op = rng.random()
        if op < 0.25:
            table.minor_compact()
        elif op < 0.4:
            table.compact()
    pats = _plant_patterns(rng, combined, boundaries, is_dna)
    _check_table(table, combined, pats)


def test_fused_read_all_tier_shapes():
    """Deterministic sweep of tier configurations: memtable only, runs
    only, runs + memtable, and everything folded back to base."""
    base = codec.random_dna(1200, seed=21)
    table = SuffixTable.from_codes(base, is_dna=True)
    combined = base
    boundaries = [len(base)]

    def grow(n, seed, seal):
        nonlocal combined
        app = codec.random_dna(n, seed=seed)
        table.append(app)
        combined = np.concatenate([combined, app])
        boundaries.append(len(combined))
        if seal:
            table.minor_compact()

    rng = np.random.default_rng(22)
    grow(140, 30, seal=False)            # memtable only
    assert not table.runs and table.memtable.size
    _check_table(table, combined,
                 _plant_patterns(rng, combined, boundaries, True))
    table.minor_compact()                # runs only
    grow(90, 31, seal=True)
    assert len(table.runs) == 2 and table.memtable.size == 0
    _check_table(table, combined,
                 _plant_patterns(rng, combined, boundaries, True))
    grow(110, 32, seal=False)            # runs + memtable
    assert table.runs and table.memtable.size
    _check_table(table, combined,
                 _plant_patterns(rng, combined, boundaries, True))
    table.compact()                      # folded: base-only fast path
    assert not table.runs and table.memtable.size == 0
    _check_table(table, combined,
                 _plant_patterns(rng, combined, boundaries, True))


# ---------------------------------------------------------------------------
# planner counters + base-only fast path
# ---------------------------------------------------------------------------
def test_planner_counts_fused_and_base_only_reads():
    table = SuffixTable.from_codes(codec.random_dna(800, seed=40),
                                   is_dna=True, memtable_limit=500)
    patt, plen = table.planner.encode(["ACGT", "GATTACA"])
    s0 = table.planner.stats.as_dict()
    assert s0["fused_batches"] == 0 and s0["base_only_batches"] == 0
    assert s0["tier_reads"] == {"base": 0, "runs": 0, "memtable": 0}

    table.scan_encoded(patt, plen)       # no tiers live -> base-only
    s1 = table.planner.stats.as_dict()
    assert s1["base_only_batches"] == 1 and s1["fused_batches"] == 0
    assert s1["tier_reads"]["base"] == 1
    assert s1["tier_reads"]["runs"] == 0 and s1["tier_reads"]["memtable"] == 0

    table.append(codec.random_dna(80, seed=41))        # memtable live
    table.scan_encoded(patt, plen)
    s2 = table.planner.stats.as_dict()
    assert s2["fused_batches"] == 1 and s2["base_only_batches"] == 1
    assert s2["tier_reads"] == {"base": 2, "runs": 0, "memtable": 1}

    table.minor_compact()                # one sealed run, empty memtable
    table.append(codec.random_dna(60, seed=42))
    table.scan_encoded(patt, plen)
    s3 = table.planner.stats.as_dict()
    assert s3["fused_batches"] == 2
    assert s3["tier_reads"] == {"base": 3, "runs": 1, "memtable": 2}

    # the counters surface through the public stats schema
    ps = table.stats()["planner"]
    for key in ("fused_batches", "base_only_batches", "tier_reads"):
        assert key in ps, key
    assert set(ps["tier_reads"]) == {"base", "runs", "memtable"}


def test_base_only_fast_path_skips_tier_machinery():
    """Zero runs + empty memtable must not build a TierSet stack."""
    table = SuffixTable.from_codes(codec.random_dna(600, seed=43),
                                   is_dna=True)
    assert table._tierset() is None
    out = table.scan(["ACGT"], top_k=4)
    assert table.planner.stats.base_only_batches >= 1
    assert table.planner.stats.fused_batches == 0
    want, first, pos = _brute(codec.random_dna(600, seed=43),
                              codec.encode_dna("ACGT"))
    assert int(out.count[0]) == want and int(out.first_pos[0]) == first


# ---------------------------------------------------------------------------
# adaptive scheduler
# ---------------------------------------------------------------------------
def _db(codes, **kw):
    db = Database.in_memory(**kw)
    table = db.attach("t", SuffixTable.from_codes(codes, is_dna=True))
    return db, table


def test_sparse_submits_take_the_fast_path():
    """Arrivals slower than the window must not pay the coalesce sleep:
    the query executes inline on the caller thread."""
    db, table = _db(codec.random_dna(2000, seed=50), coalesce_window_ms=250.0)
    want = int(table.count(["ACGT"])[0])
    try:
        lat = []
        for _ in range(4):
            t0 = time.monotonic()
            res = db.submit(Query.count("t", ["ACGT"])).result(timeout=30.0)
            lat.append(time.monotonic() - t0)
            assert res.ok and int(res.count[0]) == want
            time.sleep(0.3)              # gap > window -> stay sparse
        snap = db.stats()["scheduler"]
        assert snap["fast_path_queries"] >= 3
        assert snap["ewma_gap_ms"] is None or snap["ewma_gap_ms"] > 250.0
        assert snap["window_ms_current"] == 0.0
        # no 250 ms window sleep on the fast path
        assert min(lat) < 0.2, lat
    finally:
        db.close()


def test_burst_after_idle_still_coalesces():
    """The fast path must yield to coalescing the moment load appears:
    concurrent callers are batched, results bit-identical."""
    db, table = _db(codec.random_dna(4000, seed=51), coalesce_window_ms=2.0)
    pats = Q.random_patterns(24, 1, 10, seed=52)
    want = table.scan(pats, top_k=4)
    table.clear_cache()
    results = [None] * len(pats)

    def caller(i):
        results[i] = db.submit(
            Query.scan("t", [pats[i]], top_k=4)).result(timeout=30.0)

    threads = [threading.Thread(target=caller, args=(i,))
               for i in range(len(pats))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    for i, res in enumerate(results):
        assert res is not None and res.ok
        assert int(res.count[0]) == int(want.count[i])
        assert (res.positions[0] == want.positions[i]).all()
    s = db.scheduler.stats
    assert s.executed == 24
    assert s.batches < s.submitted           # coalescing still happens
    db.close()


def test_adaptive_off_restores_fixed_window():
    db, _ = _db(codec.random_dna(900, seed=53), coalesce_window_ms=7.0,
                adaptive_window=False)
    try:
        for _ in range(3):
            res = db.submit(Query.count("t", ["ACGT"])).result(timeout=30.0)
            assert res.ok
        snap = db.stats()["scheduler"]
        assert snap["fast_path_queries"] == 0
        assert snap["window_ms_current"] == 7.0
    finally:
        db.close()


def test_scheduler_stats_snapshot_schema():
    sched = QueryScheduler(lambda name: None, window_ms=3.0)
    snap = sched.stats_snapshot()
    for key in ("submitted", "executed", "batches", "fast_path_queries",
                "window_ms_current", "ewma_gap_ms"):
        assert key in snap, key
    assert snap["window_ms_current"] == 3.0 and snap["ewma_gap_ms"] is None
    sched.close()


# ---------------------------------------------------------------------------
# mesh tables: sharded base dispatch (sentinel retries) + one fused launch
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_mesh_fused_tiers_with_sentinel_retries(multidevice):
    """On a mesh table the base scan keeps its routed dispatch — with a
    starved capacity factor forcing -1/-2 sentinel retries — while all
    delta tiers ride one fused launch; merged counts, text-minimum
    first_pos, and top-k positions stay exact vs brute force."""
    multidevice("""
import numpy as np
from repro.api import SuffixTable
from repro.core import codec, query as Q
from repro.core.planner import ScanPlanner, MODE_ROUTED

codes = codec.random_dna(4096, seed=5)
table = SuffixTable.from_codes(codes, is_dna=True)
assert table.mesh is not None
combined = codes
for s in range(3):
    app = codec.random_dna(120, seed=60 + s)
    table.append(app)
    combined = np.concatenate([combined, app])
    if s < 2:
        table.minor_compact()
assert table.runs and table.memtable.size

# starve routed capacity so the base dispatch hits both sentinel kinds
pln = ScanPlanner(table.store, mesh=table.mesh, capacity_factor=0.25,
                  routed_min_batch=8)
table.planner = pln
pats = ['A'] * 40 + Q.random_patterns(24, 1, 10, seed=11)
patt, plen = pln.encode(pats)
raw = pln.scan_encoded(patt, plen, mode=MODE_ROUTED, retry=False)
assert (np.asarray(raw.count) < 0).any(), 'expected sentinels'

out = table.scan_batch(patt, plen, top_k=6)
cc = combined.astype(np.int32)
for i, p in enumerate(pats):
    pc = codec.encode_dna(p).astype(np.int32)
    want, first = Q.brute_force_count(cc, pc)
    assert int(out.count[i]) == want, (p, int(out.count[i]), want)
    assert int(out.first_pos[i]) == first, (p, 'first_pos')
    for q in out.positions[i]:
        if q >= 0:
            assert (cc[int(q):int(q) + len(p)] == pc).all()
assert pln.stats.retried_overflow > 0
assert pln.stats.fused_batches > 0 and pln.stats.tier_reads['runs'] > 0
print('OK')
""")

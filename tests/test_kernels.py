"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps per kernel as required."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec, query as Q
from repro.core.codec import random_dna
from repro.core.tablet import build_tablet_store
from repro.kernels import ops, ref, tier_scan as TS


@pytest.mark.parametrize("n", [1, 15, 16, 17, 1000, 16384, 50001])
def test_pack2bit_shapes(n):
    c = random_dna(n, seed=n)
    got = np.asarray(ops.pack2bit(c))
    want = np.asarray(codec.pack_2bit(c))
    assert got.shape == want.shape
    assert (got == want).all()


@pytest.mark.parametrize("src_dtype", [np.uint8, np.int32, np.uint32])
def test_pack2bit_dtypes(src_dtype):
    c = random_dna(4096, seed=0).astype(src_dtype)
    got = np.asarray(ops.pack2bit(c))
    want = np.asarray(codec.pack_2bit(c.astype(np.uint8)))
    assert (got == want).all()


@pytest.mark.parametrize("B,W,text_n", [
    (1, 1, 64), (7, 2, 500), (300, 7, 3000), (512, 8, 3000), (1000, 4, 777),
])
def test_pattern_compare_sweep(B, W, text_n):
    codes = random_dna(text_n, seed=B)
    packed = codec.pack_2bit(codes)
    rng = np.random.default_rng(W)
    pos = rng.integers(0, text_n, size=B).astype(np.int32)
    pats = Q.random_patterns(B, 1, W * 16, seed=(B, W))
    _, pp, pl = Q.encode_patterns(pats, W * 16)
    win = codec.extract_window(packed, jnp.asarray(pos), W)
    lt, le, eq = ops.pattern_compare(win, pp, pl, jnp.asarray(pos),
                                     n_real=text_n)
    rlt, rle, req = ref.pattern_compare_ref(win.T, pp.T, pl,
                                            jnp.asarray(pos), n_real=text_n)
    np.testing.assert_array_equal(np.asarray(lt), np.asarray(rlt, bool))
    np.testing.assert_array_equal(np.asarray(le), np.asarray(rle, bool))
    np.testing.assert_array_equal(np.asarray(eq), np.asarray(req, bool))
    # cross-check against the core compare
    clt, ceq = Q.compare_packed(packed, text_n, jnp.asarray(pos), pp, pl)
    np.testing.assert_array_equal(np.asarray(lt), np.asarray(clt))
    np.testing.assert_array_equal(np.asarray(eq), np.asarray(ceq))


@pytest.mark.parametrize("nq,text_n", [(16, 512), (150, 2000), (260, 4096)])
def test_tablet_scan_matches_query_engine(nq, text_n):
    codes = random_dna(text_n, seed=text_n)
    store = build_tablet_store(codes)
    W = 7
    pats = Q.random_patterns(nq, 1, 12, seed=nq)
    _, pp, pl = Q.encode_patterns(pats, W * 16)
    windows = codec.extract_window(store.text_packed, store.sa, W)
    count, less, first = ops.tablet_scan(pp, pl, windows, store.sa,
                                         n_real=store.n_real)
    res = Q.query(store, pp, pl)
    np.testing.assert_array_equal(np.asarray(count), np.asarray(res.count))
    f = np.asarray(res.found)
    lb = np.asarray(res.first_rank) + store.pad_count
    np.testing.assert_array_equal(np.asarray(less)[f], lb[f])
    rc, rl, rf = ref.tablet_scan_ref(pp.T, pl, windows.T, store.sa,
                                     n_real=store.n_real)
    np.testing.assert_array_equal(np.asarray(count), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(less), np.asarray(rl))
    np.testing.assert_array_equal(np.asarray(first), np.asarray(rf))


@pytest.mark.parametrize("nq,base_n,chunks", [
    (17, 900, 3), (130, 2500, 5), (260, 1400, 4),
])
def test_tier_scan_kernel_vs_ref_vs_fused(nq, base_n, chunks):
    """The fused tier kernel (interpret), its dense oracle, and the
    pure-jnp production path agree bit-for-bit on a real TierStack."""
    from repro.api import SuffixTable
    table = SuffixTable.from_codes(random_dna(base_n, seed=base_n),
                                   is_dna=True, memtable_limit=260)
    for i in range(chunks):
        table.append(random_dna(150, seed=1000 + i))
    ts = table._tierset()
    assert ts is not None and ts.stack.num_tiers >= 2
    stack = ts.stack

    pats = Q.random_patterns(nq, 1, 12, seed=nq)
    _, pp, pl = Q.encode_patterns(pats, stack.max_query_len)

    want = TS.fused_tier_scan(stack, pp, pl)
    got = ops.tier_scan(stack, pp, pl)          # Pallas, interpret on CPU
    for name, g, w in zip(("count", "less", "matches", "first_g"),
                          got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)

    # dense ref over the same unpadded stack operands
    W = pp.shape[1]
    windows = jax.vmap(lambda pk, sa_t: codec.extract_window(pk, sa_t, W))(
        stack.text_packed, stack.sa)
    wt = jnp.transpose(windows, (0, 2, 1))
    meta = np.zeros((stack.num_tiers, 8), np.int32)
    for k, v in enumerate((stack.n_real, stack.n_rows, stack.offset,
                           stack.lo, stack.hi)):
        meta[:, k] = np.asarray(v)
    rref = ref.tier_scan_ref(pp.T.astype(jnp.uint32), pl, wt, stack.sa,
                             jnp.asarray(meta))
    for name, g, w in zip(("count", "less", "matches", "first_g"),
                          rref, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg="ref:" + name)


# ---------------------------------------------------------------------------
# pack/unpack round trips (host batch path feeds the FM-index Occ builder)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,L", [(1, 1), (3, 15), (2, 16), (5, 17), (4, 33)])
def test_unpack_2bit_batch_round_trip(B, L):
    rng = np.random.default_rng(B * 100 + L)
    codes = rng.integers(0, 4, size=(B, L)).astype(np.uint8)
    words = codec.pack_2bit_batch(codes)
    assert words.dtype == np.uint32
    got = codec.unpack_2bit_batch(words, L)
    np.testing.assert_array_equal(got, codes)
    # agrees with the jnp single-row unpack on every row
    for b in range(B):
        np.testing.assert_array_equal(
            np.asarray(codec.unpack_2bit(jnp.asarray(words[b]), L)),
            codes[b])
    # asking for more bases than the words hold is an error, not junk
    with pytest.raises(ValueError):
        codec.unpack_2bit_batch(words, words.shape[1] * 16 + 1)


# ---------------------------------------------------------------------------
# FM backward-search kernel vs the jnp oracle vs brute force
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,nq", [(130, 40), (2048, 200)])
def test_fm_scan_pallas_matches_oracle(n, nq):
    from repro.api.fm import FMIndex
    from repro.kernels import fm_scan as FM

    codes = random_dna(n, seed=n)
    fm = FMIndex.build(codes, None, is_dna=True, sample_rate=8)
    pats = Q.random_patterns(nq, 1, 12, seed=nq)
    _, pp, pl = Q.encode_patterns(pats, 16)
    syms = FM.syms_from_packed(pp, pl, pp.shape[1] * 16)
    lo_o, hi_o = FM.search_syms(fm.arrays, syms)        # jnp oracle

    padded, B = ops._pad_to(syms, FM.BLOCK_Q, 1, fill=-1)
    lo_k, hi_k = FM.fm_scan_pallas(padded, fm.arrays.bwt, fm.arrays.occ,
                                   FM.pallas_meta(fm.arrays),
                                   interpret=True)      # Pallas kernel
    np.testing.assert_array_equal(np.asarray(lo_k)[:B], np.asarray(lo_o))
    np.testing.assert_array_equal(np.asarray(hi_k)[:B], np.asarray(hi_o))

    cc = np.asarray(codes).astype(np.int32)
    count = np.asarray(hi_o) - np.asarray(lo_o)
    for i, p in enumerate(pats):
        want, _ = Q.brute_force_count(cc, codec.encode_dna(p).astype(np.int32))
        assert int(count[i]) == want, p

"""The serving plane end-to-end: tablets, replicas, hedging, failover.

The expensive fixture deploys ONE real multi-process plane per module —
a 4-tablet x 2-replica fleet over a table with every LSM tier populated
(base + sealed run + memtable snapshot + WAL tail) — and every
bit-identicality assertion compares the routed answer against the live
single-process table on the same ``Database`` handle.  Process-level
faults (kill -9 mid-serving, restart + WAL-tail replay) run against
that fleet; hedging/failover/admission *policies* are additionally
pinned by in-process RPC unit tests, which are deterministic where the
real fleet is timing-dependent.
"""
import json
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.api import Database, Query
from repro.serving import rpc
from repro.serving.metrics import aggregate_metrics
from repro.serving.plane import ServingPlane, split_table
from repro.serving.router import (OverloadedError, RemoteTable,
                                  TabletRouter, TokenBucket)
from repro.serving.tablet_server import encode_pattern_rows

N_TABLETS = 4
REPLICAS = 2
ALIAS = "dna@plane"


def _rand_pats(rng, n, lmin=1, lmax=24):
    return ["".join("ACGT"[c] for c in rng.integers(0, 4, size=int(L)))
            for L in rng.integers(lmin, lmax + 1, size=n)]


class PlaneEnv:
    def __init__(self, root, db, table, plane, remote):
        self.root = root
        self.db = db
        self.table = table        # the live single-process oracle
        self.plane = plane
        self.remote = remote


@pytest.fixture(scope="module")
def env(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("plane") / "root")
    rng = np.random.default_rng(7)
    db = Database(root)
    table = db.create_table(
        "dna", rng.integers(0, 4, size=16000, dtype=np.uint8),
        is_dna=True, max_query_len=64)
    # populate every tier: sealed run + memtable (snapshotted by flush)
    # + a WAL tail past the snapshot (replayed read-only by the owner)
    planted = "TTTTTTTTGGGGGGGG"                 # straddles tier borders
    for i in range(2):
        db.append("dna", rng.integers(0, 4, size=500, dtype=np.uint8))
    table.minor_compact()
    db.append("dna", np.concatenate(
        [np.array([3] * 8 + [2] * 8, np.uint8),
         rng.integers(0, 4, size=300, dtype=np.uint8)]))
    table.flush()                                # publish the snapshot
    db.append("dna", np.concatenate(
        [rng.integers(0, 4, size=100, dtype=np.uint8),
         np.array([3] * 8 + [2] * 8, np.uint8)]))    # WAL tail only
    assert int(table.count([planted])[0]) >= 2

    plane = ServingPlane.deploy(root, "dna", N_TABLETS, replicas=REPLICAS,
                                metrics_interval_s=0.5)
    remote = db.connect_plane("dna", attach_as=ALIAS)
    yield PlaneEnv(root, db, table, plane, remote)
    plane.stop()
    db.close()


# ---------------------------------------------------------------------------
# bit-identicality across the typed Query surface
# ---------------------------------------------------------------------------
def test_scan_bit_identical(env):
    rng = np.random.default_rng(11)
    pats = _rand_pats(rng, 150) + ["TTTTTTTTGGGGGGGG", "ACGT", "A"]
    local = env.table.scan(pats, top_k=8)
    routed = env.remote.scan(pats, top_k=8)
    assert np.array_equal(local.count, routed.count)
    assert np.array_equal(local.first_pos, routed.first_pos)
    assert np.array_equal(local.positions, routed.positions)
    assert np.array_equal(local.found, routed.found)
    assert int(local.count.sum()) > 0


@pytest.mark.parametrize("kind", ["count", "contains", "locate", "scan"])
def test_typed_queries_identical(env, kind):
    rng = np.random.default_rng(13)
    pats = _rand_pats(rng, 40) + ["TTTTTTTTGGGGGGGG"]
    ctor = getattr(Query, kind)
    a = env.db.query(ctor("dna", pats))
    b = env.db.query(ctor(ALIAS, pats))
    assert a.ok and b.ok
    assert np.array_equal(a.found, b.found)
    assert np.array_equal(a.count, b.count)
    assert np.array_equal(a.first_pos, b.first_pos)
    if a.positions is not None or b.positions is not None:
        assert np.array_equal(a.positions, b.positions)


def test_raw_codes_query_identical(env):
    """Packed-uint32 DNA batches (the planner's raw encoding) route too."""
    from repro.core import query as Q
    pats = _rand_pats(np.random.default_rng(17), 32)
    _, packed, plen = Q.encode_patterns(pats, 64)
    qa = Query(table="dna", codes=np.asarray(packed),
               lens=np.asarray(plen))
    qb = Query(table=ALIAS, codes=np.asarray(packed),
               lens=np.asarray(plen))
    a, b = env.db.query(qa), env.db.query(qb)
    assert a.ok and b.ok
    assert np.array_equal(a.count, b.count)
    assert np.array_equal(a.first_pos, b.first_pos)


def test_read_session_pages_across_tablets(env):
    """Paged streaming crosses tablet boundaries with a resumable
    cursor: pages through the plane equal pages off the local table."""
    pat = "ACG"
    local = [p.positions for p in env.db.read_rows("dna", pat,
                                                   page_size=16).pages()]
    sess = env.db.read_rows(ALIAS, pat, page_size=16)
    routed = []
    cursor = None
    for i, page in enumerate(sess.pages()):
        routed.append(page.positions)
        if i == 2:
            cursor = page.cursor          # resume mid-stream below
    assert len(local) == len(routed)
    for a, b in zip(local, routed):
        assert np.array_equal(a, b)
    resumed = env.db.resume_read(cursor)
    tail = np.concatenate(
        [p.positions for p in resumed.pages()] or [np.zeros(0, np.int64)])
    want = np.concatenate(routed[3:] or [np.zeros(0, np.int64)])
    assert np.array_equal(tail, want)


def test_locate_range_merge(env):
    pat = "ACGT"
    full_local = env.table.locate_range(pat, after=-1, limit=None)
    full_routed = env.remote.locate_range(pat, after=-1, limit=None)
    assert np.array_equal(full_local, full_routed)
    mid = int(full_local[len(full_local) // 2])
    assert np.array_equal(
        env.table.locate_range(pat, after=mid, limit=9),
        env.remote.locate_range(pat, after=mid, limit=9))


def test_encoder_parity_with_planner(env):
    """The worker's numpy-only pattern encoder matches the planner's
    jax-side encoding symbol for symbol."""
    from repro.core import query as Q
    pats = _rand_pats(np.random.default_rng(23), 20)
    rows, lens = encode_pattern_rows(pats)
    codes, _packed, plens = Q.encode_patterns(pats, 64)
    codes = np.asarray(codes)
    for i, p in enumerate(pats):
        assert int(lens[i]) == int(plens[i])
        assert np.array_equal(rows[i, :len(p)], codes[i, :len(p)])


# ---------------------------------------------------------------------------
# crash / failover / restart
# ---------------------------------------------------------------------------
def test_kill9_failover_and_bitwise_restart(env):
    rng = np.random.default_rng(29)
    pats = _rand_pats(rng, 60) + ["TTTTTTTTGGGGGGGG"]
    want = env.table.scan(pats, top_k=8)

    victim = 1
    sock = env.plane._sock_path(victim, 0)
    client = rpc.RpcClient(sock)
    crc_before = client.call({"op": "stats"})["stats"]["text_crc"]
    client.close()

    env.plane.kill(victim, 0, sig=signal.SIGKILL)
    assert not env.plane.alive(victim, 0)
    before = env.remote.router.failovers
    got = env.remote.scan(pats, top_k=8)       # replica serves, no gap
    assert np.array_equal(want.count, got.count)
    assert np.array_equal(want.positions, got.positions)
    assert env.remote.router.failovers >= before

    env.plane.restart(victim, 0)
    client = rpc.RpcClient(sock)
    stats = client.call({"op": "stats"})["stats"]
    client.close()
    # the restarted worker rebuilt the same logical text: snapshot
    # slice + WAL tail replayed bit-identically (crc covers both)
    assert stats["text_crc"] == crc_before
    got2 = env.remote.scan(pats, top_k=8)
    assert np.array_equal(want.count, got2.count)


def test_owner_replays_wal_tail(env):
    sock = env.plane._sock_path(N_TABLETS - 1, 0)
    client = rpc.RpcClient(sock)
    stats = client.call({"op": "stats"})["stats"]
    client.close()
    assert stats["serves_delta"] is True
    assert stats["wal_records_replayed"] >= 1
    assert stats["delta_len"] > 0


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_tenant_quota_sheds_typed_overloaded(env):
    env.remote.router.set_quota("abuser", rate_per_s=1.0, burst=8.0)
    pats = ["ACGT"] * 4
    shed = ok = 0
    for _ in range(8):
        r = env.db.query(Query.count(ALIAS, pats, tenant="abuser"))
        if r.overloaded:
            shed += 1
        else:
            ok += 1
            assert int(r.count[0]) == int(env.table.count(["ACGT"])[0])
    assert shed >= 1 and ok >= 1          # burst admits, then the shed
    # an unmetered tenant is untouched by the abuser's quota
    r = env.db.query(Query.count(ALIAS, pats, tenant="good"))
    assert r.ok and not r.overloaded
    assert env.db.scheduler.stats.shed >= 1


def test_metrics_feed_and_varz(env):
    path = os.path.join(env.root, "dna", "metrics.jsonl")
    deadline = time.time() + 10
    while time.time() < deadline:
        agg = aggregate_metrics(path)
        if agg["summary"]["workers"] >= N_TABLETS * REPLICAS:
            break
        time.sleep(0.25)
    s = agg["summary"]
    assert s["tablets"] == N_TABLETS
    assert s["queries"] > 0
    assert s["wal_records_replayed"] >= 1
    assert all("p95_ms" in r for r in agg["latest"]
               if r.get("role") == "worker")
    # every line is valid JSON with a timestamp (torn lines are skipped)
    with open(path) as f:
        recs = [json.loads(ln) for ln in f if ln.strip()]
    assert all("ts" in r for r in recs)


# ---------------------------------------------------------------------------
# in-process policy units: framing, buckets, hedge, failover, shed
# ---------------------------------------------------------------------------
def test_rpc_frame_roundtrip():
    msg = {"op": "scan", "top_k": 3, "note": "héllo",
           "rows": np.arange(12, dtype=np.int32).reshape(3, 4),
           "lens": np.array([4, 2, 1], np.int64)}
    out = rpc.decode_message(rpc.encode_message(msg)[4:])
    assert out["op"] == "scan" and out["top_k"] == 3
    assert out["note"] == "héllo"
    assert np.array_equal(out["rows"], msg["rows"])
    assert out["rows"].dtype == np.int32
    assert np.array_equal(out["lens"], msg["lens"])


def test_token_bucket():
    b = TokenBucket(rate_per_s=1000.0, burst=3.0)
    assert b.try_acquire(3)
    assert not b.try_acquire(1)         # drained
    time.sleep(0.01)
    assert b.try_acquire(1)             # refilled at 1000/s


def _one_tablet_manifest():
    return {"table": "t", "step": 0, "table_version": 1, "is_dna": True,
            "max_query_len": 8, "n_base": 0, "key_len": 4,
            "n_tablets": 1,
            "tablets": [{"id": 0, "rank_lo": 0, "rank_hi": 0, "key": []}]}


def _serve(path, handler, **kw):
    return rpc.RpcServer(path, handler, **kw)


def test_hedge_fires_and_backup_wins(tmp_path):
    import tempfile
    d = tempfile.mkdtemp(prefix="saplane-test-")
    slow = _serve(os.path.join(d, "a.sock"),
                  lambda m: (time.sleep(0.4), {"status": "ok", "who": 0})[1])
    fast = _serve(os.path.join(d, "b.sock"),
                  lambda m: {"status": "ok", "who": 1})
    try:
        r = TabletRouter(_one_tablet_manifest(),
                         [[slow.path, fast.path]], hedge_deadline_ms=40)
        reply = r._call_tablet(0, {"op": "x"})
        assert reply["who"] == 1            # backup won the race
        assert r.hedge_fired == 1 and r.hedge_wins == 1
        r.close()
    finally:
        slow.stop()
        fast.stop()


def test_failover_on_dead_primary(tmp_path):
    import tempfile
    d = tempfile.mkdtemp(prefix="saplane-test-")
    alive = _serve(os.path.join(d, "b.sock"),
                   lambda m: {"status": "ok", "who": 1})
    try:
        r = TabletRouter(_one_tablet_manifest(),
                         [[os.path.join(d, "dead.sock"), alive.path]],
                         hedge_enabled=False)
        reply = r._call_tablet(0, {"op": "x"})
        assert reply["who"] == 1
        assert r.failovers == 1
        r.close()
    finally:
        alive.stop()


def test_all_replicas_shedding_raises_overloaded(tmp_path):
    import tempfile
    d = tempfile.mkdtemp(prefix="saplane-test-")
    gate = threading.Event()

    def stuck(m):
        gate.wait(5.0)
        return {"status": "ok"}

    srv = _serve(os.path.join(d, "a.sock"), stuck, max_inflight=1)
    try:
        r = TabletRouter(_one_tablet_manifest(), [[srv.path]],
                         hedge_enabled=False)
        occupier = threading.Thread(
            target=lambda: r._call_tablet(0, {"op": "x"}), daemon=True)
        occupier.start()
        deadline = time.time() + 2
        while srv.queue_depth == 0 and time.time() < deadline:
            time.sleep(0.005)
        with pytest.raises(OverloadedError) as ei:
            r._call_tablet(0, {"op": "x"})   # queue full -> typed shed
        assert "OVERLOADED" in str(ei.value)
        assert srv.shed_count >= 1
        gate.set()
        occupier.join(timeout=5)
        r.close()
    finally:
        gate.set()
        srv.stop()


def test_scheduler_runs_remote_tables_concurrently():
    """supports_concurrent_scans bypasses the per-table dispatch lock —
    two callers must be able to overlap inside scan() (a barrier would
    time out if the scheduler serialized them)."""

    class FakeRemote:
        supports_concurrent_scans = True
        is_remote = True
        barrier = threading.Barrier(2, timeout=5.0)

        def scan(self, pats, top_k=0):
            self.barrier.wait()
            B = len(pats)
            z = np.zeros(B, np.int64)
            from repro.serving.router import _RemoteOutcome
            return _RemoteOutcome(z > 0, z, np.full(B, -1, np.int64), None)

    db = Database.in_memory()
    db.attach("r", FakeRemote())
    errs = []

    def call():
        try:
            r = db.query(Query.count("r", ["ACGT"]))
            if not r.ok:
                errs.append(r.error)
        except Exception as e:  # noqa: BLE001
            errs.append(repr(e))

    ts = [threading.Thread(target=call) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=10)
    assert errs == []
    db.close()


# ---------------------------------------------------------------------------
# split / catalog / lifecycle
# ---------------------------------------------------------------------------
def test_split_table_manifest_shape(env):
    path = os.path.join(env.root, "dna", "tablets", "manifest.json")
    with open(path) as f:
        m = json.load(f)
    assert m["n_tablets"] == N_TABLETS
    assert m["tablets"][0]["rank_lo"] == 0
    assert m["tablets"][-1]["rank_hi"] == m["n_base"]
    for a, b in zip(m["tablets"], m["tablets"][1:]):
        assert a["rank_hi"] == b["rank_lo"]        # contiguous cover
    assert all(len(t["key"]) <= m["key_len"] for t in m["tablets"])


def test_split_rejects_frozen(tmp_path):
    root = str(tmp_path / "root")
    db = Database(root)
    db.create_table("f", np.random.default_rng(0).integers(
        0, 4, size=2000, dtype=np.uint8), is_dna=True)
    db.freeze("f")
    with pytest.raises(RuntimeError, match="frozen"):
        split_table(root, "f", 2)
    db.close()


def test_catalog_reconcile_keeps_plane_dirs(env):
    from repro.api.catalog import Catalog
    cat = Catalog(env.root)                      # reconciles on init
    assert "dna" in cat
    assert os.path.exists(os.path.join(env.root, "dna", "tablets",
                                       "manifest.json"))
    assert os.path.exists(os.path.join(env.root, "dna", "metrics.jsonl"))
    # a crashed-create remnant that got as far as a tablets/ dir is
    # still recognized as machinery and collected
    ghost = os.path.join(env.root, "ghost")
    os.makedirs(os.path.join(ghost, "tablets"))
    open(os.path.join(ghost, "metrics.jsonl"), "w").close()
    removed = Catalog(env.root).reconcile()
    assert not os.path.exists(ghost) or "ghost" in removed


def test_database_close_is_final_and_idempotent(tmp_path):
    root = str(tmp_path / "root")
    db = Database(root)
    t = db.create_table("c", np.random.default_rng(1).integers(
        0, 4, size=1500, dtype=np.uint8), is_dna=True)
    db.append("c", np.array([0, 1, 2, 3], np.uint8))
    assert db.query(Query.count("c", ["ACGT"])).ok
    db.close()
    db.close()                                   # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        db.table("c")
    with pytest.raises(RuntimeError, match="closed"):
        db.query(Query.count("c", ["ACGT"]))
    # the scheduler worker thread is joined, not leaked
    th = db.scheduler._thread
    assert th is None or not th.is_alive()
    # the owned table's WAL fd was released: a fresh open can attach
    # the commit log immediately (an fd leak would replay-attach a
    # still-open segment)
    assert t._wal is None or t._wal._file is None
    db2 = Database(root)
    assert int(db2.query(Query.count("c", ["ACGT"])).count[0]) >= 1
    db2.close()


def test_remote_table_rejects_overlong_pattern(env):
    with pytest.raises(ValueError, match="max_query_len"):
        env.remote.scan(["A" * 65])


def test_connect_helper_from_disk(env):
    """A second client process would connect from the published
    manifest + serving.json alone — same answers."""
    from repro.serving.router import connect
    rt = connect(env.root, "dna")
    try:
        pats = ["ACGT", "TTTTTTTTGGGGGGGG"]
        local = env.table.scan(pats)
        got = rt.scan(pats)
        assert np.array_equal(local.count, got.count)
    finally:
        rt.close()
    assert isinstance(rt, RemoteTable)

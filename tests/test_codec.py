"""codec: 2-bit packing invariants (hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored seeded-random shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import codec

dna = st.text(alphabet="ACGT", min_size=1, max_size=300)


@given(dna)
@settings(max_examples=50, deadline=None)
def test_pack_roundtrip(s):
    c = codec.encode_dna(s)
    p = codec.pack_2bit(c)
    u = codec.unpack_2bit(p, len(c))
    assert (np.asarray(u) == c).all()


@given(dna)
@settings(max_examples=25, deadline=None)
def test_word_order_is_lexicographic(s):
    """Packing is big-endian: comparing the first packed word of two texts
    equals comparing their first 16 bases lexicographically."""
    c = codec.encode_dna(s)
    other = np.roll(c, 1)
    w1 = int(np.asarray(codec.pack_2bit(c))[0])
    w2 = int(np.asarray(codec.pack_2bit(other))[0])
    s1 = bytes(np.pad(c, (0, 16))[:16])
    s2 = bytes(np.pad(other, (0, 16))[:16])
    assert (w1 < w2) == (s1 < s2)
    assert (w1 == w2) == (s1 == s2)


@given(dna, st.integers(0, 400))
@settings(max_examples=50, deadline=None)
def test_extract_window(s, pos):
    c = codec.encode_dna(s)
    pos = pos % len(c)
    p = codec.pack_2bit(c)
    w = codec.extract_window(p, jnp.asarray([pos]), 2)[0]
    want = codec.pack_2bit(np.pad(c[pos:], (0, 32))[:32])[:2]
    assert (np.asarray(w) == np.asarray(want)).all()


def test_encode_rejects_non_dna():
    with pytest.raises(ValueError):
        codec.encode_dna("ACGTX")


def test_decode_inverse():
    c = codec.random_dna(97, seed=3)
    assert (codec.encode_dna(codec.decode_dna(c)) == c).all()

"""Crash-injection tests for the per-table commit log (repro.api.wal).

The durability contract under test: an append acked by a persistent
``SuffixTable`` survives a crash at ANY byte boundary of the log —
reopen recovers a logical text bit-identical to an oracle that never
crashed — while a torn (unacked) tail record is discarded whole, never
partially applied.  Crashes are injected by abandoning the live table
object and copying its directory (the disk at crash time), then
truncating or corrupting the copied ``wal.log`` at chosen offsets.
"""
import os
import shutil
import threading

import numpy as np
import pytest

from repro.api import Database, SuffixTable
from repro.api.catalog import table_wal_dir
from repro.api.wal import HEADER_SIZE, WriteAheadLog, read_segment
from repro.core import codec


def _full_text(t: SuffixTable) -> np.ndarray:
    """The table's logical text across every tier, in order."""
    parts = [np.asarray(t._codes)] + [np.asarray(r.codes) for r in t.runs]
    if t.memtable.size:
        parts.append(np.asarray(t.memtable.appended))
    return np.concatenate([p.astype(np.int64) for p in parts])


def _wal_path(root, name="t") -> str:
    return os.path.join(table_wal_dir(str(root), name), "wal.log")


def _crash_copy(root, dst) -> str:
    """Simulate a crash: the in-memory table is abandoned, the on-disk
    state (snapshots + live log) is whatever the copy captures."""
    shutil.copytree(str(root), str(dst))
    return str(dst)


def _scan_matches_oracle(table, acked: np.ndarray, patterns) -> None:
    oracle = SuffixTable.from_codes(acked.astype(np.uint8), is_dna=True)
    got = table.scan(list(patterns), top_k=8)
    want = oracle.scan(list(patterns), top_k=8)
    assert (got.count == want.count).all()
    assert (got.first_pos == want.first_pos).all()
    assert (got.positions == want.positions).all()


# ---------------------------------------------------------------------------
# acked appends survive crashes — random schedules vs an oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_crash_recovers_acked_appends_over_random_schedule(tmp_path, seed):
    rng = np.random.default_rng(seed)
    base = codec.random_dna(600, seed=seed)
    t = SuffixTable.create("t", base, root=str(tmp_path / "root"),
                           max_query_len=16)
    acked = [np.asarray(base, np.int64)]
    for _ in range(12):
        op = rng.choice(["append", "append", "append", "minor", "major"])
        if op == "append":
            chunk = codec.random_dna(int(rng.integers(1, 40)),
                                     seed=int(rng.integers(1 << 30)))
            t.append(chunk)                  # returns == acked durable
            acked.append(np.asarray(chunk, np.int64))
        elif op == "minor":
            t.minor_compact()
        else:
            t.compact()
    acked = np.concatenate(acked)
    crash = _crash_copy(tmp_path / "root", tmp_path / f"crash{seed}")
    t2 = SuffixTable.open("t", root=crash)
    assert np.array_equal(_full_text(t2), acked), "acked text lost"
    _scan_matches_oracle(t2, acked, ["ACGT", "GATTACA", "TT", "CCG"])
    rec = t2.stats()["wal"]["recovery"]
    assert rec is None or rec["reason"] == "clean"


def test_writer_killed_at_every_byte_boundary(tmp_path):
    """The tentpole property: truncate the log at EVERY byte offset (a
    writer killed mid-write leaves exactly such a prefix).  Records
    wholly on disk are acked appends and must all be recovered; a
    partial tail record must vanish whole — the recovered text is
    always ``base + appends[:k]`` for the k fully-durable records."""
    base = codec.random_dna(300, seed=7)
    root = tmp_path / "root"
    t = SuffixTable.create("t", base, root=str(root), max_query_len=16)
    chunks = [codec.random_dna(n, seed=50 + n) for n in (6, 11, 3, 17, 9)]
    for c in chunks:
        t.append(c)
    start_seq, records, summary = read_segment(_wal_path(root))
    assert summary.reason == "clean" and len(records) == len(chunks)
    boundaries = [HEADER_SIZE] + [end for _, _, end in records]
    log_len = os.path.getsize(_wal_path(root))
    assert boundaries[-1] == log_len

    prefixes = [np.asarray(base, np.int64)]
    for c in chunks:
        prefixes.append(np.concatenate(
            [prefixes[-1], np.asarray(c, np.int64)]))

    for cut in range(log_len + 1):
        crash = str(tmp_path / "cut")
        shutil.rmtree(crash, ignore_errors=True)
        _crash_copy(root, crash)
        with open(_wal_path(crash), "r+b") as f:
            f.truncate(cut)
        t2 = SuffixTable.open("t", root=crash)
        # k = records fully contained in the first `cut` bytes
        k = sum(1 for b in boundaries[1:] if b <= cut)
        got = _full_text(t2)
        assert np.array_equal(got, prefixes[k]), (
            f"cut={cut}: recovered {got.size} symbols, want the "
            f"{k}-record prefix ({prefixes[k].size}) — a torn record "
            f"must never be partially applied")
        rec = t2.stats()["wal"]["recovery"]
        if cut < HEADER_SIZE:
            assert rec["reason"] == "missing_header"
        elif cut in boundaries:
            assert rec["reason"] == "clean" and rec["torn_bytes"] == 0
        else:
            assert rec["reason"] != "clean" and rec["torn_bytes"] > 0
        assert rec["records_replayed"] == k
        if cut in boundaries:           # scan-level bit-identity per record
            _scan_matches_oracle(t2, prefixes[k], ["ACG", "TTT", "GAT"])


def test_corrupt_record_discards_it_and_everything_after(tmp_path):
    base = codec.random_dna(200, seed=3)
    root = tmp_path / "root"
    t = SuffixTable.create("t", base, root=str(root), max_query_len=16)
    chunks = [codec.random_dna(8, seed=80 + i) for i in range(4)]
    for c in chunks:
        t.append(c)
    _, records, _ = read_segment(_wal_path(root))
    crash = _crash_copy(root, tmp_path / "crash")
    # flip one payload byte inside record 2 (0-indexed): CRC must kill
    # it AND records 3+ (nothing after a corrupt record is trustworthy)
    with open(_wal_path(crash), "r+b") as f:
        f.seek(records[2][2] - 3)
        b = f.read(1)
        f.seek(records[2][2] - 3)
        f.write(bytes([b[0] ^ 0xFF]))
    t2 = SuffixTable.open("t", root=crash)
    want = np.concatenate([np.asarray(base, np.int64)]
                          + [np.asarray(c, np.int64) for c in chunks[:2]])
    assert np.array_equal(_full_text(t2), want)
    rec = t2.stats()["wal"]["recovery"]
    assert rec["reason"] == "crc_mismatch"
    assert rec["records_replayed"] == 2 and rec["torn_bytes"] > 0
    # the survivor keeps working: new appends are durable again
    t2.append("GATTACA")
    crash2 = _crash_copy(crash, tmp_path / "crash2")
    t3 = SuffixTable.open("t", root=crash2)
    assert np.array_equal(
        _full_text(t3),
        np.concatenate([want, np.asarray(codec.encode_dna("GATTACA"),
                                         np.int64)]))


def test_seal_skipped_never_double_applies(tmp_path, monkeypatch):
    """Crash window between snapshot publish and log truncation: the
    snapshot already holds the records, so replay must SKIP them by
    sequence number instead of appending them twice."""
    base = codec.random_dna(200, seed=5)
    root = tmp_path / "root"
    t = SuffixTable.create("t", base, root=str(root), max_query_len=16)
    acked = [np.asarray(base, np.int64)]
    for i in range(3):
        c = codec.random_dna(10, seed=60 + i)
        t.append(c)
        acked.append(np.asarray(c, np.int64))
    monkeypatch.setattr(WriteAheadLog, "seal",
                        lambda self, start_seq: None)   # crash-the-seal
    t.minor_compact()                  # persists the run, "fails" to seal
    for i in range(2):
        c = codec.random_dna(7, seed=70 + i)
        t.append(c)
        acked.append(np.asarray(c, np.int64))
    monkeypatch.undo()
    crash = _crash_copy(root, tmp_path / "crash")
    t2 = SuffixTable.open("t", root=crash)
    assert np.array_equal(_full_text(t2), np.concatenate(acked))
    rec = t2.stats()["wal"]["recovery"]
    assert rec["records_skipped"] == 3 and rec["records_replayed"] == 2


def test_sealing_truncates_log_after_snapshot(tmp_path):
    base = codec.random_dna(300, seed=9)
    root = tmp_path / "root"
    t = SuffixTable.create("t", base, root=str(root), max_query_len=16)
    for i in range(3):
        t.append(codec.random_dna(20, seed=90 + i))
    assert os.path.getsize(_wal_path(root)) > HEADER_SIZE
    t.minor_compact()                       # seal: run persisted first
    assert os.path.getsize(_wal_path(root)) == HEADER_SIZE
    t.append(codec.random_dna(5, seed=99))
    t.flush()                               # flush seals too
    assert os.path.getsize(_wal_path(root)) == HEADER_SIZE
    t.append(codec.random_dna(5, seed=100))
    t.compact()                             # and major compaction
    assert os.path.getsize(_wal_path(root)) == HEADER_SIZE
    t2 = SuffixTable.open("t", root=_crash_copy(root, tmp_path / "c"))
    assert len(t2) == 300 + 3 * 20 + 5 + 5


# ---------------------------------------------------------------------------
# group commit through the client
# ---------------------------------------------------------------------------
def _marker(i: int) -> str:
    """Unique 10-mer: 'AAAA' + 6 base-3 digits over {C,G,T}.  Digits
    never contain A, so the only 'AAAA' runs in a marker stream sit at
    marker starts — cross-chunk windows can never fake another marker."""
    digits = []
    for _ in range(6):
        digits.append("CGT"[i % 3])
        i //= 3
    return "AAAA" + "".join(digits)


def test_group_commit_concurrent_clients_all_acked_durable(tmp_path):
    root = str(tmp_path / "root")
    db = Database(root, group_commit_ms=2.0)
    db.create_table("t", codec.random_dna(400, seed=11), is_dna=True,
                    max_query_len=16, group_commit_ms=2.0)
    n_threads, per_thread = 6, 4
    errs = []

    def writer(tid):
        try:
            for j in range(per_thread):
                db.append("t", _marker(tid * per_thread + j))
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    log = db.table("t").stats()["wal"]["log"]
    total = n_threads * per_thread
    assert log["appends"] == total
    assert log["fsyncs"] <= log["appends"]   # group commit may batch
    db.close()
    crash = _crash_copy(root, tmp_path / "crash")
    t2 = SuffixTable.open("t", root=crash)
    assert len(t2) == 400 + total * 10
    counts = t2.count([_marker(i) for i in range(total)])
    assert (counts >= 1).all(), "an acked concurrent append was lost"


# ---------------------------------------------------------------------------
# opt-out, guards, stats
# ---------------------------------------------------------------------------
def test_wal_opt_out_restores_volatile_appends(tmp_path):
    base = codec.random_dna(300, seed=13)
    root = str(tmp_path / "root")
    t = SuffixTable.create("t", base, root=root, wal=False)
    assert not os.path.exists(_wal_path(root))
    assert t.stats()["wal"]["enabled"] is False
    t.append("GATTACA")
    t2 = SuffixTable.open("t", root=_crash_copy(root, tmp_path / "c"),
                          wal=False)
    assert len(t2) == 300                   # documented volatility
    with pytest.raises(ValueError):
        SuffixTable.from_codes(base, is_dna=True, wal=True)


def test_wal_false_interlude_never_splices_stale_records(tmp_path):
    """A wal=False open orphans the live log: appends made during the
    opt-out interlude take sequence numbers the log never saw, so a
    later wal=True open must NOT replay the stale records into the
    diverged text."""
    base = codec.random_dna(300, seed=29)
    root = str(tmp_path / "root")
    t = SuffixTable.create("t", base, root=root, max_query_len=16)
    for i in range(3):                      # logged seqs 1..3, then crash
        t.append(codec.random_dna(10, seed=40 + i))
    crash = _crash_copy(root, tmp_path / "crash")
    t2 = SuffixTable.open("t", root=crash, wal=False)
    assert not os.path.exists(_wal_path(crash))        # moved aside
    assert os.path.exists(_wal_path(crash) + ".orphaned")
    unlogged = [codec.random_dna(5, seed=45 + i) for i in range(2)]
    for c in unlogged:
        t2.append(c)
    t2.flush()                              # snapshot wal_seq now 2
    t3 = SuffixTable.open("t", root=crash)  # wal back ON
    want = np.concatenate([np.asarray(base, np.int64)]
                          + [np.asarray(c, np.int64) for c in unlogged])
    assert np.array_equal(_full_text(t3), want), \
        "stale log records spliced into a diverged table"
    t3.append("ACGT")                       # and the fresh log works
    t4 = SuffixTable.open(
        "t", root=_crash_copy(crash, tmp_path / "crash2"))
    assert len(t4) == want.size + 4


def test_oversized_append_rejected_before_logging(tmp_path, monkeypatch):
    import repro.api.wal as wal_mod
    t = SuffixTable.create("t", codec.random_dna(200, seed=31),
                           root=str(tmp_path))
    monkeypatch.setattr(wal_mod, "_MAX_PAYLOAD", 64)
    size_before = os.path.getsize(_wal_path(tmp_path))
    with pytest.raises(ValueError, match="record cap"):
        t.append(codec.random_dna(200, seed=32))
    # nothing logged, nothing applied, counter not wedged
    assert os.path.getsize(_wal_path(tmp_path)) == size_before
    assert t.memtable.size == 0
    monkeypatch.undo()
    t.append("ACGT")                        # table still writable
    assert len(t) == 204


def test_closed_table_refuses_appends_not_durability(tmp_path):
    t = SuffixTable.create("t", codec.random_dna(200, seed=17),
                           root=str(tmp_path))
    t.append("ACGT")
    t.close()
    with pytest.raises(RuntimeError):
        t.append("ACGT")
    t2 = SuffixTable.open("t", root=str(tmp_path))
    assert len(t2) == 204


def test_wal_stats_schema(tmp_path):
    t = SuffixTable.create("t", codec.random_dna(200, seed=19),
                           root=str(tmp_path))
    t.append("ACGT")
    w = t.stats()["wal"]
    assert w["enabled"] is True and w["seq"] == 1
    assert {"appends", "acked", "fsyncs", "seals",
            "group_commit_ms", "synced_seq"} <= set(w["log"])
    assert w["recovery"] is None            # clean create, nothing replayed
    t2 = SuffixTable.open("t", root=str(tmp_path))
    rec = t2.stats()["wal"]["recovery"]
    assert {"segment_start_seq", "records_scanned", "records_replayed",
            "records_skipped", "valid_bytes", "torn_bytes",
            "reason"} == set(rec)


def test_replay_respects_memtable_limit_after_recovery(tmp_path):
    """Replay defers auto-seal to the end, then honors memtable_limit —
    the recovered table persists a run and truncates the log exactly as
    a live table would have."""
    root = str(tmp_path / "root")
    t = SuffixTable.create("t", codec.random_dna(300, seed=23), root=root,
                           max_query_len=16)
    for i in range(4):
        t.append(codec.random_dna(30, seed=30 + i))
    crash = _crash_copy(root, tmp_path / "crash")
    t2 = SuffixTable.open("t", root=crash, memtable_limit=100)
    assert t2.memtable.size == 0 and len(t2.runs) == 1
    assert len(t2) == 300 + 120
    assert os.path.getsize(_wal_path(crash)) == HEADER_SIZE
    # and the post-recovery seal state itself survives another crash
    t3 = SuffixTable.open("t", root=_crash_copy(crash, tmp_path / "c2"),
                          memtable_limit=100)
    assert len(t3) == 420

"""Query engine vs the paper's Algorithm 1 brute force (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored seeded-random shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import codec, query as Q
from repro.core.tablet import build_tablet_store


def _store(text):
    return build_tablet_store(codec.encode_dna(text), is_dna=True)


@given(st.text(alphabet="ACGT", min_size=4, max_size=200),
       st.lists(st.text(alphabet="ACGT", min_size=1, max_size=12),
                min_size=1, max_size=16))
@settings(max_examples=30, deadline=None)
def test_counts_match_brute_force(text, patterns):
    store = _store(text)
    codes = codec.encode_dna(text)
    pc, pp, pl = Q.encode_patterns(patterns, 16)
    res = Q.query(store, pp, pl)
    for i, p in enumerate(patterns):
        want_count, want_first = Q.brute_force_count(
            codes, codec.encode_dna(p))
        assert int(res.count[i]) == want_count, (text, p)
        assert bool(res.found[i]) == (want_count > 0)
        if want_count:
            fp = int(res.first_pos[i])
            assert (codes[fp:fp + len(p)] == codec.encode_dna(p)).all()


@given(st.text(alphabet="ACGT", min_size=4, max_size=100))
@settings(max_examples=20, deadline=None)
def test_packed_and_codes_paths_agree(text):
    store = _store(text)
    pats = Q.random_patterns(32, 1, 10, seed=1)
    pc, pp, pl = Q.encode_patterns(pats, 16)
    r1 = Q.query(store, pp, pl)     # packed fast path
    r2 = Q.query(store, pc, pl)     # generic token path
    assert (np.asarray(r1.count) == np.asarray(r2.count)).all()
    assert (np.asarray(r1.first_pos) == np.asarray(r2.first_pos)).all()


def test_boundary_cases():
    """Suffix shorter than pattern, all-A patterns vs padding, exact end."""
    text = "GATTACA"
    store = _store(text)
    cases = {
        "A": 3, "CA": 1, "ACA": 1, "GATTACA": 1, "GATTACAA": 0,
        "AA": 0,            # would falsely match zero-padding if unguarded
        "CAA": 0, "TACA": 1, "G": 1, "TT": 1, "TTT": 0,
    }
    pc, pp, pl = Q.encode_patterns(list(cases), 16)
    res = Q.query(store, pp, pl)
    for i, (p, want) in enumerate(cases.items()):
        assert int(res.count[i]) == want, (p, int(res.count[i]), want)


def test_first_pos_is_lexicographic_rank_order():
    """first_pos is the match whose suffix is lexicographically smallest;
    first_rank indexes the real (unpadded) suffix array."""
    text = "ACGTACGTACGT"
    store = _store(text)
    pc, pp, pl = Q.encode_patterns(["ACGT"], 16)
    res = Q.query(store, pp, pl)
    assert int(res.count[0]) == 3
    # suffixes starting with ACGT: positions 0,4,8; smallest suffix = "ACGT"
    # at position 8 (shortest)
    assert int(res.first_pos[0]) == 8
    sa_real = np.asarray(store.sa)[store.pad_count:]
    assert sa_real[int(res.first_rank[0])] == 8


def test_encode_patterns_empty_batch():
    """Regression: np.stack([]) used to raise; retry passes with nothing
    to retry produce empty batches naturally."""
    pc, pp, pl = Q.encode_patterns([], 32)
    assert pc.shape == (0, 32)
    assert pp.shape == (0, codec.packed_length(32))
    assert pl.shape == (0,)
    store = _store("GATTACA")
    res = Q.query(store, pp, pl)
    assert np.asarray(res.count).shape == (0,)


def test_pad_row_canonical_order():
    """Pins build_tablet_store's pad-row layout: pad positions occupy the
    first pad_count SA rows in descending position order (n_pad-1 .. n_real),
    i.e. shortest pad run (lexicographically smallest suffix) first."""
    codes = codec.encode_dna("ACGTACGTACG")        # n_real = 11
    store = build_tablet_store(codes, is_dna=True, num_tablets=4)
    assert store.n_pad == 12 and store.pad_count == 1
    store = build_tablet_store(codes, is_dna=True, num_tablets=8)
    assert store.n_pad == 16 and store.pad_count == 5
    sa = np.asarray(store.sa)
    want_pads = np.arange(store.n_pad - 1, store.n_real - 1, -1)
    assert (sa[:store.pad_count] == want_pads).all()
    # real rows are a permutation of 0..n_real-1 and suffix-sorted
    real = sa[store.pad_count:]
    assert sorted(real.tolist()) == list(range(store.n_real))
    b = codes.tobytes()
    for i in range(len(real) - 1):
        assert b[real[i]:] < b[real[i + 1]:]


def test_token_corpus_queries():
    """Large-vocab token path (the LM dedup/contamination use)."""
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, 50000, 3000).astype(np.int32)
    corpus[1000:1010] = corpus[2000:2010]      # planted duplicate 10-gram
    store = build_tablet_store(corpus, is_dna=False)
    w = corpus[2000:2010][None, :]
    res = Q.query(store, jnp.asarray(w), jnp.asarray([10]))
    assert int(res.count[0]) == 2

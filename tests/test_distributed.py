"""Multi-device behaviour (8 host devices via subprocess; smoke tests and
benches must keep seeing 1 device, hence the isolation)."""
import pytest

pytestmark = pytest.mark.multidevice


def test_distributed_sorts(multidevice):
    multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from functools import partial
from repro.core.dsort import bitonic_sort_sharded, sort_sharded_auto

mesh = jax.make_mesh((8,), ('t',))
for m, rng_max in [(64, 20), (256, 10**6)]:   # tie-heavy and near-unique
    rng = np.random.default_rng(m)
    keys = rng.integers(0, rng_max, size=(8*m,)).astype(np.int32)
    vals = np.arange(8*m, dtype=np.int32)
    for fn in (lambda o: bitonic_sort_sharded(o, num_keys=1, axis_name='t'),
               lambda o: sort_sharded_auto(o, num_keys=1, axis_name='t')):
        @partial(shard_map, mesh=mesh, in_specs=(P('t'), P('t')),
                 out_specs=(P('t'), P('t')))
        def run(k, v):
            return fn((k, v))
        ks, vs = run(keys, vals)
        vs = np.asarray(vs)
        assert sorted(vs.tolist()) == list(range(8*m)), 'not a permutation'
        assert (np.asarray(ks) == np.sort(keys)).all()
        assert (keys[vs] == np.sort(keys)).all()
print('OK')
""")


def test_distributed_suffix_array(multidevice):
    multidevice("""
import jax, numpy as np
from repro.core.dsa import build_suffix_array_distributed
from repro.core.suffix_array import suffix_array_naive
from repro.core.codec import random_dna

mesh = jax.make_mesh((8,), ('t',))
for method in ['bitonic', 'sample']:
    for n in [100, 777, 2048]:
        codes = random_dna(n, seed=n)
        sa, pad = build_suffix_array_distributed(codes, mesh, 't', method=method)
        assert (np.asarray(sa)[pad:] == suffix_array_naive(codes)).all(), (method, n)
print('OK')
""")


def test_distributed_scan_matches_local(multidevice):
    multidevice("""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from functools import partial
from repro.core.tablet import build_tablet_store
from repro.core import query as Q
from repro.core.codec import random_dna

mesh = jax.make_mesh((8,), ('t',))
codes = random_dna(4096, seed=5)
store = build_tablet_store(codes, num_tablets=8)
pats = Q.random_patterns(64, 1, 10, seed=9)
_, pp, pl = Q.encode_patterns(pats, 16)

@partial(shard_map, mesh=mesh, in_specs=(P('t'), None, P(), P()), out_specs=P())
def dscan(sa_local, meta, patt, plen):
    return Q.query_sharded(sa_local, meta, patt, plen, 't')

res = dscan(store.sa, store, pp, pl)
ref = Q.query(store, pp, pl)
for f in ['count', 'found', 'first_pos', 'first_rank']:
    assert (np.asarray(getattr(res, f)) == np.asarray(getattr(ref, f))).all(), f
print('OK')
""")


def test_sharded_training_and_elastic_restore(multidevice, tmp_path):
    """Train sharded on (2,4) mesh, checkpoint, restore on (8,1) mesh and on
    1 device — elastic reshard-on-load."""
    multidevice(f"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.training import OptConfig, make_train_step, train_state_init
from repro.distributed import sharding as shd
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, synthetic_batch

def ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))

cfg = get_config('qwen3-0.6b').reduced()
ocfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
data = DataConfig(global_batch=8, seq_len=32)

mesh_a = jax.make_mesh((2, 4), ('data', 'model'))
state = train_state_init(cfg, ocfg, jax.random.PRNGKey(0))
pspecs = shd.param_specs(state.params, mesh_a)
sspecs = type(state)(params=pspecs,
                     opt_state=shd.opt_state_specs(ocfg, state.params, pspecs),
                     step=P())
state = jax.device_put(state, ns(mesh_a, sspecs))
step = jax.jit(make_train_step(cfg, ocfg, shard=shd.make_shard_fn(mesh_a)),
               in_shardings=(ns(mesh_a, sspecs), None),
               out_shardings=(ns(mesh_a, sspecs), None))
for i in range(3):
    state, m = step(state, synthetic_batch(cfg, data, i))
mgr = CheckpointManager(r'{tmp_path}')
mgr.save(3, state)

# elastic restore onto a DIFFERENT mesh (8 x 1)
mesh_b = jax.make_mesh((8, 1), ('data', 'model'))
pspecs_b = shd.param_specs(state.params, mesh_b)
sspecs_b = type(state)(params=pspecs_b,
                       opt_state=shd.opt_state_specs(ocfg, state.params, pspecs_b),
                       step=P())
_, state_b, _ = mgr.restore_latest(state, ns(mesh_b, sspecs_b))
step_b = jax.jit(make_train_step(cfg, ocfg, shard=shd.make_shard_fn(mesh_b)),
                 in_shardings=(ns(mesh_b, sspecs_b), None),
                 out_shardings=(ns(mesh_b, sspecs_b), None))
state_b, m = step_b(state_b, synthetic_batch(cfg, data, 3))
assert np.isfinite(float(m['loss']))

# continue on mesh A too and compare one step: same math, diff mesh
state_a2, m_a = step(state, synthetic_batch(cfg, data, 3))
np.testing.assert_allclose(float(m['loss']), float(m_a['loss']), rtol=1e-4)
print('OK')
""")


def test_pipeline_parallelism(multidevice):
    multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from functools import partial
from repro.distributed.pipeline import pipeline_apply, stage_slice

mesh = jax.make_mesh((4,), ('pp',))
L, D = 8, 16
rng = np.random.default_rng(0)
Ws = np.asarray(rng.normal(size=(L, D, D)) * 0.5, np.float32)
xm = np.asarray(rng.normal(size=(6, 4, D)), np.float32)

def stage_fn(params, h):
    out, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), h, params)
    return out

@partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=P())
def run(Ws, xm):
    return pipeline_apply(stage_fn, stage_slice(Ws, 'pp', L), xm, 'pp')

out = np.asarray(run(Ws, xm))
ref = xm
for l in range(L):
    ref = np.tanh(ref @ Ws[l])
assert np.abs(out - ref).max() < 1e-5

g_pp = jax.grad(lambda W, x: jnp.sum(run(W, x) ** 2))(jnp.asarray(Ws), jnp.asarray(xm))
def loss_ref(W, x):
    h = x
    for l in range(L):
        h = jnp.tanh(h @ W[l])
    return jnp.sum(h ** 2)
g_ref = jax.grad(loss_ref)(jnp.asarray(Ws), jnp.asarray(xm))
assert np.abs(np.asarray(g_pp) - np.asarray(g_ref)).max() < 1e-4
print('OK')
""")


def test_compressed_gradient_exchange(multidevice):
    multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from functools import partial
from repro.distributed.compression import compressed_pmean

mesh = jax.make_mesh((8,), ('pod',))
rng = np.random.default_rng(0)
vals = np.asarray(rng.normal(size=(8, 4096)), np.float32)

@partial(shard_map, mesh=mesh, in_specs=(P('pod'), P('pod')),
         out_specs=(P('pod'), P('pod')))
def cm(v, e):
    m, ne = compressed_pmean(v[0], 'pod', e[0])
    return m[None], ne[None]

true_mean = vals.mean(0)
err = np.zeros_like(vals)
m, err = cm(vals, err)
rel = np.abs(np.asarray(m)[0] - true_mean).max() / np.abs(true_mean).max()
assert rel < 0.05, rel
# error feedback: the residual carries exactly what was not transmitted
assert np.abs(np.asarray(err)).max() > 0            # non-trivial
# and across repeated steps of the SAME gradient the mean stays unbiased
total = np.zeros_like(true_mean)
err = np.zeros_like(vals)
for _ in range(16):
    m, err = cm(vals, err)
    total += np.asarray(m)[0]
rel = np.abs(total / 16 - true_mean).max() / np.abs(true_mean).max()
assert rel < 0.01, rel
print('OK')
""")


def test_int8_on_the_wire(multidevice):
    """The compressed exchange must actually put s8 on the wire (HLO)."""
    multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from functools import partial
from repro.distributed.compression import compressed_pmean

mesh = jax.make_mesh((8,), ('pod',))
@partial(shard_map, mesh=mesh, in_specs=(P('pod'), P('pod')),
         out_specs=(P('pod'), P('pod')))
def cm(v, e):
    m, ne = compressed_pmean(v[0], 'pod', e[0])
    return m[None], ne[None]
hlo = jax.jit(cm).lower(
    jax.ShapeDtypeStruct((8, 4096), jnp.float32),
    jax.ShapeDtypeStruct((8, 4096), jnp.float32)).compile().as_text()
assert 'all-gather' in hlo
import re
s8_gathers = [l for l in hlo.splitlines()
              if 'all-gather' in l and re.search(r's8\\[', l)]
assert s8_gathers, 'int8 all-gather not found in HLO'
print('OK')
""")


def test_routed_query_matches_broadcast(multidevice):
    """Beyond-paper routed scan: exact on the non-saturated set, found/
    first_pos always exact, -2 sentinel only for runs spanning >2 tablets."""
    multidevice("""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from functools import partial
from repro.core.tablet import build_tablet_store
from repro.core import query as Q
from repro.core.codec import random_dna

mesh = jax.make_mesh((8,), ('t',))
for seed in [5, 6, 9]:
    codes = random_dna(4096, seed=seed)
    store = build_tablet_store(codes, num_tablets=8)
    pats = Q.random_patterns(64, 1, 10, seed=seed + 100)
    _, pp, pl = Q.encode_patterns(pats, 16)

    @partial(shard_map, mesh=mesh,
             in_specs=(P('t'), None, P('t'), P('t')), out_specs=P('t'))
    def routed(sa_local, meta, patt, plen):
        return Q.query_routed(sa_local, meta, patt, plen, 't')

    res = routed(store.sa, store, pp, pl)
    ref = Q.query(store, pp, pl)
    cnt = np.asarray(res.count); rc = np.asarray(ref.count)
    exact = cnt >= 0; ovf = cnt == -1
    assert (cnt[exact] == rc[exact]).all()
    assert (np.asarray(res.found)[~ovf] == np.asarray(ref.found)[~ovf]).all()
    fp = np.asarray(res.first_pos); chk = exact & (cnt > 0)
    assert (fp[chk] == np.asarray(ref.first_pos)[chk]).all()
    # saturated sentinel only for genuinely huge runs
    m = store.n_pad // 8
    assert (rc[cnt == -2] >= 1).all()
print('OK')
""")


def test_planner_retry_restores_exact_counts(multidevice):
    """Regression for the routed-path sentinels: a starved capacity factor
    plus skewed/short patterns must produce both -1 (overflow) and -2
    (saturated) counts, and the planner's broadcast retry must make every
    count exact vs the brute-force oracle."""
    multidevice("""
import jax, numpy as np
from repro.core.tablet import build_tablet_store
from repro.core import query as Q
from repro.core.codec import random_dna, encode_dna
from repro.core.planner import ScanPlanner, MODE_ROUTED

mesh = jax.make_mesh((8,), ('tablets',))
codes = random_dna(4096, seed=5)
store = build_tablet_store(codes, num_tablets=8)
# 40 copies of 'A': every query owned by one tablet (forces -1 overflow)
# and its match run spans >2 tablets (forces -2 saturation); plus patterns
# prefixing each tablet's FIRST suffix (match run starts exactly at the
# boundary: the owner's local run is empty and first_rank comes entirely
# from the spill correction — regression for the frank=-1 bug)
from repro.core.codec import decode_dna
m = store.n_pad // 8
sa_np = np.asarray(store.sa)
boundary = [decode_dna(codes[int(sa_np[d*m]):int(sa_np[d*m])+6])
            for d in range(1, 8) if int(sa_np[d*m]) <= 4096 - 8]
pats = ['A'] * 40 + Q.random_patterns(24, 1, 10, seed=11) + boundary
_, pp, pl = Q.encode_patterns(pats, 16)

pln = ScanPlanner(store, mesh=mesh, capacity_factor=0.25, routed_min_batch=8)
assert pln.plan(64).mode == MODE_ROUTED
raw = pln.scan_encoded(pp, pl, mode=MODE_ROUTED, retry=False)
rc = np.asarray(raw.count)
assert (rc == -1).any(), 'expected dispatch-overflow sentinels'
assert (rc == -2).any(), 'expected saturated-run sentinels'

res = pln.scan_encoded(pp, pl)
ref = Q.query(store, pp, pl)
cc = codes.astype(np.int32)
for i, p in enumerate(pats):
    want, first = Q.brute_force_count(cc, encode_dna(p).astype(np.int32))
    assert int(res.count[i]) == want, (p, int(res.count[i]), want)
    assert bool(res.found[i]) == (want > 0)
    assert int(res.first_rank[i]) == int(ref.first_rank[i]), p
    assert int(res.first_pos[i]) == int(ref.first_pos[i]), p
assert pln.stats.retried_overflow > 0 and pln.stats.retried_saturated > 0

# locate positions round-trip through the text
posn = pln.positions_from_result(res, top_k=5)
for i, p in enumerate(pats):
    for q in posn[i]:
        if q >= 0:
            assert (cc[q:q+len(p)] == encode_dna(p)).all()

# small batches broadcast; counts equal the single-device oracle
pln2 = ScanPlanner(store, mesh=mesh, routed_min_batch=1024)
assert pln2.plan(64).mode == 'broadcast'
res2 = pln2.scan_encoded(pp, pl)
ref = Q.query(store, pp, pl)
assert (np.asarray(res2.count) == np.asarray(ref.count)).all()
print('OK')
""")


def test_expert_parallel_moe_matches_xla_path(multidevice):
    """The shard_map EP dispatch (EXPERIMENTS §Perf F3/F5) is numerically
    identical to the single-device XLA MoE."""
    multidevice("""
import jax, numpy as np, jax.numpy as jnp, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.moe import init_moe, moe_ffn, ep_sharding

mesh = jax.make_mesh((2, 4), ('data', 'model'))
cfg = get_config('deepseek-v3-671b').reduced()
cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 512, cfg.d_model)) * 0.3, jnp.float32)
out_ref, aux_ref = moe_ffn(cfg, p, x)

def f(p_, x_):
    with ep_sharding(mesh):
        return moe_ffn(cfg, p_, x_)

pspec = {'router': P(), 'wi': P('model', ('data',), None),
         'wg': P('model', ('data',), None), 'wo': P('model', None, ('data',)),
         'shared': {'wi': P(('data',), 'model'), 'wg': P(('data',), 'model'),
                    'wo': P('model', ('data',))}}
pp = jax.device_put(p, jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                                    is_leaf=lambda z: isinstance(z, P)))
xx = jax.device_put(x, NamedSharding(mesh, P(('data',), None, None)))
out_ep, aux_ep = jax.jit(f)(pp, xx)
assert float(jnp.abs(out_ep - out_ref).max()) < 5e-4
assert abs(float(aux_ep) - float(aux_ref)) < 1e-4
# gradients flow through the EP path
g = jax.grad(lambda p_, x_: jnp.sum(f(p_, x_)[0] ** 2))(pp, xx)
for leaf in jax.tree.leaves(g):
    assert np.isfinite(np.asarray(leaf)).all()
print('OK')
""")

"""Per-arch smoke tests: reduced config of the same family, one forward /
train step on CPU, output shapes + no NaNs; decode == teacher-forcing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (decode_step, forward_train, init_params, prefill)
from repro.models import transformer as T

# whole-module: every case builds and runs a model — tier-1 excludes these
pytestmark = pytest.mark.slow

ARCHS = list_archs()


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        if cfg.frontend == "vlm_stub":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(B, cfg.num_patches, cfg.d_model)),
                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    loss, metrics = forward_train(cfg, params, batch, remat=False)
    assert np.isfinite(float(loss)), arch
    assert 3.0 < float(metrics["xent"]) < 12.0      # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.training import OptConfig, make_train_step, train_state_init
    cfg = get_config(arch).reduced()
    opt = OptConfig(warmup_steps=1, total_steps=10)
    state = train_state_init(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, remat=False)
    batch = _batch(cfg, 2, 32)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    state, m3 = step(state, batch)
    assert np.isfinite(float(m3["loss"]))
    assert float(m3["loss"]) < float(m1["loss"]), arch  # learns the batch
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all(), arch


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-0.6b", "qwen1.5-110b",
                                  "deepseek-v3-671b", "mamba2-780m",
                                  "jamba-v0.1-52b", "internvl2-26b",
                                  "musicgen-medium", "phi3-mini-3.8b",
                                  "kimi-k2-1t-a32b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch).reduced()
    # disable MoE capacity dropping (batch-context dependent by design)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S, S0 = 2, 16, 8
    batch = _batch(cfg, B, S, seed=42)
    x, _ = T._embed_inputs(cfg, params, batch, T._noshard)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    h, _, _ = T._run_stack(cfg, params, x, pos, None, T._noshard, False,
                           remat=False)
    h = T.Ls.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    full_logits = T._logits(cfg, params, h)
    off = cfg.num_patches if cfg.frontend == "vlm_stub" else 0

    b0 = dict(batch)
    if "tokens" in b0:
        b0["tokens"] = batch["tokens"][:, :S0]
    if "embeds" in b0:
        b0["embeds"] = batch["embeds"][:, :S0]
    lg, caches = prefill(cfg, params, b0, max_len=x.shape[1] + 4)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, off + S0 - 1]),
                               rtol=5e-3, atol=5e-3)
    for t in range(S0, S):
        if cfg.frontend == "audio_stub":
            lg, caches = decode_step(cfg, params, None, caches,
                                     embeds=batch["embeds"][:, t:t + 1])
        else:
            lg, caches = decode_step(cfg, params, batch["tokens"][:, t:t + 1],
                                     caches)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, off + t]),
                                   rtol=5e-3, atol=5e-3)


def test_param_counts_match_published():
    """Config fidelity: derived totals land on the published sizes."""
    expect = {
        "deepseek-v3-671b": (671e9, 0.02), "kimi-k2-1t-a32b": (1.03e12, 0.03),
        "yi-6b": (6.1e9, 0.05), "qwen1.5-110b": (111e9, 0.03),
        "qwen3-0.6b": (0.6e9, 0.1), "phi3-mini-3.8b": (3.8e9, 0.05),
        "jamba-v0.1-52b": (52e9, 0.05), "mamba2-780m": (0.78e9, 0.1),
        "musicgen-medium": (1.5e9, 0.15), "internvl2-26b": (20e9, 0.05),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got, want)


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert abs(active - 37e9) / 37e9 < 0.05           # 37B activated


def test_mamba2_ssd_vs_recurrence():
    """Chunked SSD == step-by-step recurrence (the duality the paper
    [2405.21060] proves)."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 32, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, final = ssd_chunked(x, dt, A, B, C, chunk=8)
    # explicit recurrence
    st = np.zeros((b, h, p, n), np.float32)
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])  # (b,h)
        xbar = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        st = st * dA[..., None, None] + np.einsum(
            "bhp,bn->bhpn", xbar, np.asarray(B[:, t]))
        yt = np.einsum("bn,bhpn->bhp", np.asarray(C[:, t]), st)
        np.testing.assert_allclose(np.asarray(y[:, t]), yt,
                                   rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), st, rtol=2e-4, atol=2e-4)

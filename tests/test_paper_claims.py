"""Validation against the paper's own §V claims (statistical shape).

Absolute milliseconds are not comparable (Accumulo RPC vs on-chip compute);
what must reproduce (DESIGN.md §8): hit-rate ~0.07-0.08 for the random
workload, corr(len, outcome) ~ -0.47, corr(len, time) ~ 0, and the heavy
right tail (max >> mean) that hedged reads collapse.
"""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.codec import random_dna
from repro.core.tablet import build_tablet_store
from repro.serving import HedgedScanService


# the service fixture builds a 200k-base SA and the workload tests push
# tens of thousands of queries — slow-marked except the worked example
slow_service = pytest.mark.slow


@pytest.fixture(scope="module")
def service():
    store = build_tablet_store(random_dna(200_000, seed=1), is_dna=True)
    return HedgedScanService(store)


@slow_service
def test_table3_hit_rate(service):
    """Paper Table III outcome mean 0.072 (250 Mbp chr1); our smaller text
    gives the same order: most random patterns >len 9-12 never match."""
    stats = service.run_workload(4000, batch=1000, hedged=False, seed=0)
    assert 0.04 < stats["hit_rate"] < 0.14, stats["hit_rate"]


@slow_service
def test_table5_correlations(service):
    """corr(len, time) ~ 0; corr(len, outcome) strongly negative (-0.469)."""
    stats = service.run_workload(4000, batch=1000, hedged=False, seed=1)
    assert abs(stats["corr_len_time"]) < 0.1
    assert stats["corr_len_outcome"] < -0.3


@slow_service
def test_table4_heavy_tail_and_hedging(service):
    """Paper Table IV: max 771ms vs mean 5.3ms under 50 threads.  The
    simulated replica latency reproduces the tail; hedged reads kill it."""
    single = service.run_workload(20000, batch=2000, hedged=False, seed=2)
    hedged = service.run_workload(20000, batch=2000, hedged=True, seed=2)
    assert single["max_ms"] > 10 * single["mean_ms"]        # heavy tail
    assert hedged["max_ms"] < single["max_ms"]
    assert hedged["p99_ms"] <= single["p99_ms"]
    assert hedged["mean_ms"] < single["mean_ms"] * 1.2


@slow_service
def test_exactness_vs_bruteforce_on_paper_workload(service):
    """The engine is exact, not approximate: spot-check outcomes against
    Algorithm 1 on a subsample."""
    from repro.core import codec
    store = service.store
    codes = np.asarray(codec.unpack_2bit(store.text_packed, store.n_real))
    pats = Q.random_patterns(50, 1, 12, seed=7)
    _, pp, pl = Q.encode_patterns(pats, 112)
    res = Q.query(store, pp, pl)
    for i, p in enumerate(pats):
        want, _ = Q.brute_force_count(codes, codec.encode_dna(p))
        assert int(res.count[i]) == want


def test_mississippi_counts():
    """Paper §III worked example: searching PI in MISSISSIPPI needs the
    suffix array to report exactly one occurrence."""
    codes = np.frombuffer(b"MISSISSIPPI", dtype=np.uint8).astype(np.int32)
    store = build_tablet_store(codes, is_dna=False)
    import jax.numpy as jnp
    for pat, want in {b"PI": 1, b"ISS": 2, b"SSI": 2, b"MISS": 1,
                      b"IPPI": 1, b"X": 0}.items():
        q = np.frombuffer(pat, dtype=np.uint8).astype(np.int32)
        q = np.pad(q, (0, 8 - len(q)))[None]
        res = Q.query(store, jnp.asarray(q), jnp.asarray([len(pat)]))
        assert int(res.count[0]) == want, pat

"""repro.api.client: typed queries, coalescing, paged streaming, caches.

Load-bearing properties:

* coalesced dispatch (inline waves AND the scheduler thread) returns
  results bit-identical to per-call dispatch and to the table itself;
* concatenating every ``ReadSession`` page reproduces the one-shot
  ``locate`` enumeration for random append/seal schedules, and a cursor
  taken mid-stream resumes exactly — including after a minor compaction
  moves the data under it;
* no cached count/top-k from before a write is ever served (the
  generation-stamped ``TopKCache``), even through planner references
  captured before a major compaction.
"""
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import Database, Query, SuffixTable
from repro.core import codec, query as Q
from repro.core.planner import TopKCache
from repro.serving import HedgedScanService


def _db_over(codes, name="dna", **kw):
    db = Database.in_memory()
    table = db.attach(name, SuffixTable.from_codes(codes, is_dna=True, **kw))
    return db, table


def _oracle_positions(codes, pattern):
    cc = np.asarray(codes).astype(np.int32)
    pc = codec.encode_dna(pattern).astype(np.int32)
    k = len(pc)
    return [i for i in range(len(cc) - k + 1) if (cc[i:i + k] == pc).all()]


# ---------------------------------------------------------------------------
# typed request validation + routing
# ---------------------------------------------------------------------------
def test_query_validation():
    with pytest.raises(ValueError, match="kind"):
        Query(table="t", kind="explode", patterns=("A",))
    with pytest.raises(ValueError, match="exactly one"):
        Query(table="t", patterns=("A",), codes=np.zeros((1, 4)),
              lens=np.array([1]))
    with pytest.raises(ValueError, match="exactly one"):
        Query(table="t")
    with pytest.raises(ValueError, match="lens"):
        Query(table="t", codes=np.zeros((1, 4), np.int32))
    with pytest.raises(ValueError, match="max_len"):
        Query.count("t", ["ACGTACGT"], max_len=4)
    with pytest.raises(TypeError):
        Query(table="t", patterns=(b"ACGT",))
    q = Query.locate("t", ["ACGT"])            # locate defaults top_k to 8
    assert q.top_k == 8 and q.num_patterns == 1
    with pytest.raises(ValueError, match="top_k"):
        Query.locate("t", ["ACGT"], top_k=-5)  # rejected, not coerced to 8
    assert Query.count("t", ["AC", "GT"]).num_patterns == 2


def test_database_routes_and_lifecycle(tmp_path):
    db = Database(str(tmp_path))
    db.create_table("dna", codec.random_dna(500, seed=0), is_dna=True)
    mem = SuffixTable.from_codes(codec.random_dna(300, seed=1), is_dna=True)
    db.attach("scratch", mem)
    assert db.list_tables() == ["dna", "scratch"]
    assert "dna" in db and "scratch" in db and "nope" not in db
    with pytest.raises(ValueError, match="already attached"):
        db.attach("scratch", mem)
    # a second handle over the same root lazily opens the persisted table
    db2 = Database(str(tmp_path))
    assert int(db2.query(Query.count("dna", ["A"])).value[0]) == \
        int(db.query(Query.count("dna", ["A"])).value[0])
    with pytest.raises(KeyError):
        Database.in_memory().table("anything")
    # ensure_attached reuses registrations and dodges name clashes
    assert db.ensure_attached(mem) == "scratch"
    other = SuffixTable.from_codes(codec.random_dna(100, seed=2))
    alt = db.ensure_attached(other, name="dna")    # 'dna' is taken on disk
    assert alt != "dna" and db.table(alt) is other
    # drop_table honors missing_ok on BOTH backends
    mdb = Database.in_memory()
    mdb.attach("t", mem)
    mdb.drop_table("t")
    mdb.drop_table("t", missing_ok=True)           # quiet, like the catalog
    with pytest.raises(KeyError):
        mdb.drop_table("t")
    # a FAILED drop (attached name, not in the catalog) must leave the
    # attached table routed and serving — not detached-and-closed
    with pytest.raises(KeyError):
        db.drop_table("scratch")
    assert int(db.query(Query.count("scratch", ["A"])).value[0]) >= 0
    db.close(), db2.close(), mdb.close()


def test_kinds_payload_and_errors():
    db, table = _db_over(codec.random_dna(2000, seed=3))
    pats = ["ACGT", "TTTTTTTTTTTTTTTTTTTTTTTTTTTTTTTT"]
    want = table.scan(pats, top_k=8)
    assert (db.query(Query.count("dna", pats)).value == want.count).all()
    assert (db.query(Query.contains("dna", pats)).value == want.found).all()
    assert (db.query(Query.locate("dna", pats, top_k=8)).value
            == want.positions).all()
    full = db.query(Query.scan("dna", pats, top_k=8)).value
    assert (full.first_pos == want.first_pos).all()
    # execution failures surface as error results, and .value raises
    bad = db.query(Query.count("nope", ["A"]))
    assert not bad.ok and "KeyError" in bad.error
    with pytest.raises(RuntimeError, match="query failed"):
        bad.value
    toolong = db.query(Query.count("dna", ["A" * 200]))
    assert not toolong.ok and "max_pattern_len" in toolong.error
    db.close()


# ---------------------------------------------------------------------------
# coalescing: bit-identical to per-call, across tables and callers
# ---------------------------------------------------------------------------
def _assert_same(res_a, res_b):
    assert res_a.kind == res_b.kind
    assert (res_a.count == res_b.count).all()
    assert (res_a.found == res_b.found).all()
    assert (res_a.first_pos == res_b.first_pos).all()
    assert (res_a.positions is None) == (res_b.positions is None)
    if res_a.positions is not None:
        assert (res_a.positions == res_b.positions).all()


def test_query_many_coalesces_bit_identical_across_tables():
    db, t1 = _db_over(codec.random_dna(3000, seed=4))
    t2 = db.attach("dna2", SuffixTable.from_codes(
        codec.random_dna(1500, seed=5), is_dna=True))
    t2.append("GATTACA")                      # delta tier on one table
    rng = np.random.default_rng(6)
    queries = []
    for i in range(40):
        name = "dna" if i % 2 == 0 else "dna2"
        pats = Q.random_patterns(int(rng.integers(1, 4)), 1, 9,
                                 seed=100 + i)
        queries.append(Query.scan(name, pats, top_k=int(rng.integers(0, 6))))
    coalesced = db.query_many(queries)
    for q, got in zip(queries, coalesced):
        t1.clear_cache(), t2.clear_cache()
        _assert_same(got, db.query(q))
    # mixed-table wave -> one dispatch per table, not per query
    assert all(r.ok for r in coalesced)
    assert any(r.batch_size > q.num_patterns
               for q, r in zip(queries, coalesced))
    db.close()


def test_scheduler_coalesces_concurrent_callers():
    db, table = _db_over(codec.random_dna(4000, seed=7))
    pats = Q.random_patterns(32, 1, 10, seed=8)
    want = table.scan(pats, top_k=4)
    table.clear_cache()
    results = [None] * len(pats)

    def caller(i):
        results[i] = db.submit(
            Query.scan("dna", [pats[i]], top_k=4)).result(timeout=30.0)

    threads = [threading.Thread(target=caller, args=(i,))
               for i in range(len(pats))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    for i, res in enumerate(results):
        assert res is not None and res.ok
        assert int(res.count[0]) == int(want.count[i])
        assert (res.positions[0] == want.positions[i]).all()
    s = db.scheduler.stats
    assert s.submitted == 32 and s.executed == 32
    assert s.batches < s.submitted          # some coalescing happened
    assert s.coalesced_queries > 0 and s.max_batch_patterns > 1
    db.close()
    with pytest.raises(RuntimeError, match="closed"):
        db.submit(Query.count("dna", ["A"]))


def test_inline_callers_race_scheduler_worker_on_shared_cache():
    """Inline db.query on caller threads races the scheduler worker on
    the SAME hot pattern while writes bump the cache generation — the
    locked TopKCache and serialized group execution must never produce
    an error result or a stale count."""
    db, table = _db_over(codec.random_dna(1500, seed=20))
    probe = "GATTACA"
    floor = int(table.count([probe])[0])       # appends only add matches
    errors: list[str] = []

    def inline_caller():
        for _ in range(12):
            res = db.query(Query.count("dna", [probe]))
            if not res.ok:
                errors.append(res.error)
            elif int(res.count[0]) < floor:
                errors.append(f"stale count {int(res.count[0])} < {floor}")

    def writer():
        for i in range(6):        # client writes serialize against reads
            db.append("dna", codec.random_dna(20, seed=30 + i))

    futs = [db.submit(Query.count("dna", [probe])) for _ in range(8)]
    threads = [threading.Thread(target=inline_caller) for _ in range(3)]
    threads.append(threading.Thread(target=writer))
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    for f in futs:
        res = f.result(timeout=30.0)
        assert res.ok and int(res.count[0]) >= floor
    assert errors == [], errors
    # after the dust settles: exact, and the cache serves the final text
    want = int(table.count([probe])[0])
    assert int(db.query(Query.count("dna", [probe])).count[0]) == want
    db.close()


def test_deadline_is_enforced_not_silently_dropped():
    db, _ = _db_over(codec.random_dna(500, seed=9))
    ok = db.query(Query.count("dna", ["ACGT"], deadline_ms=60_000.0))
    assert ok.ok
    expired = db.query(Query.count("dna", ["ACGT"], deadline_ms=0.0))
    assert not expired.ok and "deadline exceeded" in expired.error
    assert db.scheduler.stats.deadline_expired == 1
    # an expired query in a wave must not poison its neighbours
    wave = db.query_many([Query.count("dna", ["ACGT"], deadline_ms=0.0),
                          Query.count("dna", ["ACGT"])])
    assert not wave[0].ok and wave[1].ok
    db.close()


# ---------------------------------------------------------------------------
# paged streaming (ReadSession)
# ---------------------------------------------------------------------------
def test_read_session_pages_and_cursor_resume():
    db, table = _db_over(codec.random_dna(3000, seed=10))
    probe = "AC"                                # plenty of occurrences
    want = _oracle_positions(table._codes, probe)
    assert len(want) > 30
    pages = list(db.read_rows("dna", probe, page_size=7).pages())
    got = [int(x) for p in pages for x in p.positions]
    assert got == want
    assert pages[-1].is_last and not any(p.is_last for p in pages[:-1])
    assert all(len(p.positions) <= 7 for p in pages)
    # resume from a serialized mid-stream cursor (fresh session object)
    sess = db.read_rows("dna", probe, page_size=7)
    first = sess.next_page()
    rest = [int(x) for x in db.resume_read(first.cursor).positions()]
    assert [int(x) for x in first.positions] + rest == want
    # a pattern with zero matches yields exactly one empty terminal page
    none = list(db.read_rows("dna", "A" * 40, page_size=5).pages())
    assert len(none) == 1 and none[0].is_last \
        and none[0].positions.size == 0
    with pytest.raises(ValueError):
        db.read_rows("dna", probe, page_size=0)
    db.close()


@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(40, 160),
       st.integers(1, 17))
@settings(max_examples=4, deadline=None)
def test_read_session_property_pages_equal_one_shot(seed, n_appends, chunk,
                                                    page_size):
    """Property: for random append/seal schedules, page concatenation ==
    the one-shot locate enumeration == the brute-force oracle; and a
    cursor taken mid-stream resumes exactly after a minor compaction
    reshapes the tiers under it."""
    rng = np.random.default_rng(seed)
    base = codec.random_dna(int(rng.integers(200, 600)), seed=seed)
    db, table = _db_over(base)
    combined = base
    for a in range(n_appends):
        app = codec.random_dna(chunk, seed=seed * 11 + a)
        table.append(app)
        combined = np.concatenate([combined, app])
        if rng.random() < 0.5:
            table.minor_compact()              # seal into a run mid-schedule
    probe = codec.decode_dna(combined[:int(rng.integers(1, 3))])
    want = _oracle_positions(combined, probe)
    one_shot = [int(x) for x in table.locate_range(probe, limit=10**6)]
    assert one_shot == want
    got = [int(x)
           for x in db.read_rows("dna", probe, page_size=page_size)
           .positions()]
    assert got == want

    # resume-from-cursor across a minor compaction AND a fresh append:
    # rows behind the cursor never resurface, rows ahead (old and new) all
    # arrive exactly once
    sess = db.read_rows("dna", probe, page_size=page_size)
    first = sess.next_page()
    cursor = first.cursor
    head = [int(x) for x in first.positions]
    app = codec.random_dna(60, seed=seed + 999)
    table.append(app)
    combined = np.concatenate([combined, app])
    table.minor_compact()
    tail = [int(x) for x in db.resume_read(cursor).positions()]
    new_want = _oracle_positions(combined, probe)
    cut = head[-1] if head else -1
    assert tail == [p for p in new_want if p > cut]
    assert head == [p for p in new_want if p <= cut]
    db.close()


# ---------------------------------------------------------------------------
# cache staleness (the version-stamp bugfix) + stats schema
# ---------------------------------------------------------------------------
def test_topk_cache_generation_stamping():
    c = TopKCache(8)
    c.put("p", 3, 7, 0, None)
    assert c.get("p", 0) == (3, 7, None)
    gen = c.bump()
    assert gen == 1 and c.get("p", 0) is None      # pre-bump entry unservable
    c.put("p", 4, 7, 0, None)
    assert c.get("p", 0) == (4, 7, None)
    # a stale richer entry must not block a fresh poorer one
    c.put("q", 5, 1, 8, np.arange(8))
    c.bump()
    c.put("q", 6, 2, 0, None)
    assert c.get("q", 0) == (6, 2, None)
    assert c.hits == 3 and c.misses == 1


def test_no_stale_counts_after_write_through_any_surface():
    """Regression: a count cached before a write could be served after
    the logical text changed — through the table, through a captured
    planner reference, or through a serving engine built before a major
    compaction replaced the planner's store."""
    table = SuffixTable.from_codes(codec.random_dna(1200, seed=11),
                                   is_dna=True)
    svc = HedgedScanService(table, seed=0)     # captures table.planner
    planner = table.planner
    probe = "GATTACA" * 2
    base = int(table.count([probe])[0])
    planner.scan([probe])                       # populate the planner cache

    table.append(probe)                         # write #1: memtable
    assert int(table.count([probe])[0]) == base + 1
    table.minor_compact()                       # write #2: sealed run
    assert int(table.count([probe])[0]) == base + 1
    table.compact()                             # write #3: new base store
    assert int(table.count([probe])[0]) == base + 1
    # the captured planner was re-bound in place, not replaced: it serves
    # the post-compaction text and was never left pointing at the old SA
    assert planner is table.planner
    assert int(planner.scan([probe]).count[0]) == base + 1
    # and the service keeps serving exact counts through the client
    _, pp, pl = Q.encode_patterns([probe], 128)
    assert int(svc.scan(pp, pl, hedged=False)[0].count[0]) == base + 1


def test_stats_schema_is_stable_and_documented():
    db, table = _db_over(codec.random_dna(800, seed=12),
                         memtable_limit=200)
    db.query(Query.count("dna", ["ACGT"]))
    db.query(Query.count("dna", ["ACGT"]))      # second hit is cached
    table.append(codec.random_dna(250, seed=13))   # triggers a seal
    s = table.stats()
    assert set(s) == {"name", "version", "is_dna", "max_query_len",
                      "tiers", "cache", "build", "planner", "wal",
                      "latency"}
    # latency = tracing-span histograms (docs/observability.md); every
    # span exposes the same quantile schema
    assert "total" in s["latency"]
    assert set(s["latency"]["total"]) == {"p50_ms", "p95_ms", "p99_ms",
                                          "n", "total", "sum_ms"}
    assert set(s["build"]) == {"mode", "n_bases", "rounds", "n_chunks",
                               "chunk_rows", "peak_device_bytes",
                               "spill_bytes", "elapsed_s", "bases_per_s"}
    assert s["build"]["mode"] == "in_memory"    # from_codes: one sort
    assert s["build"]["n_bases"] == 800
    assert set(s["tiers"]) == {"base_rows", "run_count", "run_rows",
                               "memtable_rows", "frozen", "resident_bytes"}
    assert s["tiers"]["frozen"] is False       # no freeze() here
    assert set(s["tiers"]["resident_bytes"]) == {
        "base_sa", "fm", "text_device", "runs", "memtable", "text_host"}
    assert set(s["cache"]) == {"entries", "hits", "misses", "generation"}
    assert set(s["wal"]) == {"enabled", "seq", "log", "recovery"}
    assert s["wal"]["enabled"] is False      # in-memory table: no log
    assert s["tiers"]["base_rows"] == 800 and s["tiers"]["run_count"] == 1
    assert s["cache"]["hits"] >= 1 and s["cache"]["generation"] >= 1
    for key in ("batches", "queries", "bucketed_batches",
                "bucketed_queries", "pad_slots", "mode_counts"):
        assert key in s["planner"], key
    dbs = db.stats()
    assert set(dbs) == {"scheduler", "tables"}
    assert "dna" in dbs["tables"]
    assert dbs["scheduler"]["submitted"] >= 2
    db.close()


def test_scan_batch_bucket_padding_accounts_slots():
    table = SuffixTable.from_codes(codec.random_dna(600, seed=14),
                                   is_dna=True)
    pats = Q.random_patterns(5, 1, 8, seed=15)
    patt, plen = table.planner.encode(pats)
    before = table.planner.stats.as_dict()
    out = table.scan_batch(patt, plen, top_k=4)
    after = table.planner.stats
    assert out.count.shape == (5,) and out.positions.shape == (5, 4)
    assert after.queries - before["queries"] == 5       # real queries only
    assert after.pad_slots - before["pad_slots"] == 3    # 5 -> bucket of 8
    assert after.bucketed_batches - before["bucketed_batches"] == 1
    # identical to the unbucketed string path
    want = table.scan(pats, top_k=4)
    assert (out.count == want.count).all()
    assert (out.positions == want.positions).all()

"""Observability layer: span histograms against a numpy oracle, tracer
semantics, the stats → feed → aggregate round-trip, span/wall
consistency on real queries, the ``--from-feed`` gate, the docs link
checker, and the ``--tuned`` env preset in a fresh interpreter
(docs/observability.md)."""
import importlib.util
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.api import Database, Query, SuffixTable
from repro.core import codec
from repro.serving.metrics import aggregate_metrics, table_record
from repro.serving.trace import SpanHistogram, Tracer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _quantile_oracle(samples):
    """The documented rule: sorted sample at int(frac*n), clamped."""
    data = np.sort(np.asarray(samples, np.float64))
    n = len(data)
    return {f"p{int(f * 100)}_ms":
            round(float(data[min(n - 1, int(f * n))]), 4)
            for f in (0.50, 0.95, 0.99)}


def test_span_histogram_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(0.0, 1.0, size=500)
    h = SpanHistogram()
    for s in samples:
        h.record(float(s))
    q = h.quantiles()
    assert {k: q[k] for k in ("p50_ms", "p95_ms", "p99_ms")} \
        == _quantile_oracle(samples)
    assert q["n"] == 500 and q["total"] == 500
    assert q["sum_ms"] == pytest.approx(float(samples.sum()), rel=1e-6)


def test_span_histogram_ring_wraparound_keeps_latest_window():
    rng = np.random.default_rng(1)
    samples = rng.uniform(0.1, 50.0, size=200)
    h = SpanHistogram(size=64)
    for s in samples:
        h.record(float(s))
    q = h.quantiles()
    # the ring retains exactly the most recent 64 samples
    assert {k: q[k] for k in ("p50_ms", "p95_ms", "p99_ms")} \
        == _quantile_oracle(samples[-64:])
    assert q["n"] == 64 and q["total"] == 200
    assert q["sum_ms"] == pytest.approx(float(samples.sum()), rel=1e-6)


def test_empty_histogram_and_bad_size():
    q = SpanHistogram().quantiles()
    assert q == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                 "n": 0, "total": 0, "sum_ms": 0.0}
    with pytest.raises(ValueError):
        SpanHistogram(size=0)


def test_tracer_spans_measure_and_snapshot_sorts():
    tr = Tracer()
    with tr.span("zz_outer"):
        with tr.span("aa_inner"):
            time.sleep(0.01)
    tr.record("manual", 2.5)
    snap = tr.snapshot()
    assert list(snap) == sorted(snap) == ["aa_inner", "manual",
                                          "zz_outer"]
    assert snap["aa_inner"]["p50_ms"] >= 10.0 * 0.9
    assert snap["zz_outer"]["p50_ms"] >= snap["aa_inner"]["p50_ms"]
    assert snap["manual"] == {"p50_ms": 2.5, "p95_ms": 2.5,
                              "p99_ms": 2.5, "n": 1, "total": 1,
                              "sum_ms": 2.5}
    tr.reset()
    assert tr.snapshot() == {}


def test_disabled_tracer_is_shared_noop():
    tr = Tracer(enabled=False)
    assert tr.span("a") is tr.span("b")       # one shared null context
    with tr.span("a"):
        pass
    tr.record("a", 1.0)
    assert tr.snapshot() == {}
    tr.enabled = True                         # runtime re-enable works
    tr.record("a", 1.0)
    assert tr.snapshot()["a"]["n"] == 1


def test_query_spans_sum_close_to_total_wall():
    """dispatch + merge happen inside scan_batch, so their accumulated
    time can never exceed the end-to-end ``total`` span."""
    table = SuffixTable.from_codes(codec.random_dna(20_000, seed=0),
                                   is_dna=True)
    # distinct patterns each round: the result cache must not collapse
    # the scans we are timing
    for p in ["ACGT", "GATTACA", "TTT", "CCGA", "TAGC"]:
        out = table.scan([p, p + "A"])
        assert int(np.asarray(out.count).sum()) >= 0
    lat = table.stats()["latency"]
    assert {"encode", "dispatch", "merge", "total"} <= set(lat)
    assert lat["total"]["n"] == 5
    inner = lat["dispatch"]["sum_ms"] + lat["merge"]["sum_ms"]
    assert inner <= lat["total"]["sum_ms"] * 1.05 + 0.1
    assert lat["total"]["p50_ms"] > 0.0


def test_scheduler_and_planner_spans_appear():
    with Database.in_memory() as db:
        db.attach("t", SuffixTable.from_codes(
            codec.random_dna(10_000, seed=1), is_dna=True))
        futs = [db.submit(Query.count("t", ["ACG", "TTAA"]))
                for _ in range(4)]
        for f in futs:
            assert f.result(timeout=30.0).ok
        st = db.stats()
        sched_lat = st["scheduler"]["latency"]
        assert sched_lat["execute"]["n"] >= 1
        assert "coalesce_wait" in sched_lat or \
            st["scheduler"]["fast_path_queries"] > 0
        # planner spans ride the table's tracer, one dispatch_* per mode
        tbl_lat = st["tables"]["t"]["latency"]
        assert any(k.startswith("dispatch") for k in tbl_lat)


def test_stats_to_feed_round_trip(tmp_path):
    """One schema end to end: stats() → table_record → metrics.jsonl →
    aggregate_metrics, with typed scalars the aggregator can sum."""
    feed = str(tmp_path / "metrics.jsonl")
    with Database.in_memory() as db:
        table = db.attach("rt", SuffixTable.from_codes(
            codec.random_dna(10_000, seed=2), is_dna=True))
        # final-row-only mode; name= overrides the anonymous table's id
        table.start_metrics(feed, interval_s=0.0, name="rt")
        for _ in range(3):
            assert db.query(Query.count("rt", ["ACGT"])).ok
        table.stop_metrics()

    rows = [json.loads(ln) for ln in open(feed) if ln.strip()]
    assert len(rows) == 1
    row = rows[0]
    assert row["role"] == "table" and row["table"] == "rt"
    assert row["pid"] == os.getpid()
    assert isinstance(row["queries"], int) and row["queries"] >= 1
    for k in ("p50_ms", "p95_ms", "p99_ms"):
        assert isinstance(row[k], float)
    # the full stats tree rides along for drill-down
    assert {"tiers", "planner", "latency", "cache"} <= set(row["stats"])
    # and the row is exactly what table_record would produce again
    assert set(row) - {"ts"} == set(table_record("rt", row["stats"]))

    agg = aggregate_metrics(feed)["summary"]
    assert agg["tables"] == 1 and agg["workers"] == 0
    assert agg["queries"] == row["queries"]
    assert agg["p50_ms_median"] == row["p50_ms"]
    assert agg["p95_ms_max"] == row["p95_ms"]


def _load_check_regression():
    spec = importlib.util.spec_from_file_location(
        "check_regression",
        os.path.join(REPO, "benchmarks", "check_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_from_feed_gate(tmp_path, capsys):
    """--from-feed aggregates worker+table rows and gates against the
    baseline's served block at the sanity ratio."""
    cr = _load_check_regression()
    feed = tmp_path / "feed.jsonl"
    rows = [
        {"role": "worker", "tablet": 0, "replica": 0, "pid": 1,
         "queries": 10, "p50_ms": 1.0, "p95_ms": 2.0, "ts": 1.0},
        {"role": "worker", "tablet": 0, "replica": 0, "pid": 1,
         "queries": 30, "p50_ms": 2.0, "p95_ms": 4.0, "ts": 2.0},
        {"role": "table", "table": "t", "pid": 2,
         "queries": 5, "p50_ms": 4.0, "p95_ms": 6.0, "ts": 2.0},
        {"role": "router", "pid": 3, "rpcs": 40, "ts": 2.0},
    ]
    feed.write_text("\n".join(json.dumps(r) for r in rows)
                    + "\n{torn line\n")
    agg = cr.aggregate_feed(str(feed))
    assert agg["emitters"] == 3           # latest-per-key, router incl.
    assert agg["serving_emitters"] == 2   # router is not a server
    assert agg["queries"] == 35           # latest worker row + table row
    assert agg["p50_ms"] == 4.0 and agg["p95_ms"] == 6.0

    baseline = tmp_path / "BENCH_serving.json"
    baseline.write_text(json.dumps(
        {"bench": "serving_observability",
         "results": {"served": {"p50_ms": 2.0, "p95_ms": 3.0}}}))
    assert cr.check_feed(str(feed), str(baseline), ratio=3.0) == []
    fails = cr.check_feed(str(feed), str(baseline), ratio=1.5)
    assert len(fails) == 2                # both quantiles over 1.5x
    empty = tmp_path / "empty.jsonl"
    empty.write_text("\n")
    assert cr.check_feed(str(empty), str(baseline), ratio=3.0)
    capsys.readouterr()                   # swallow the gate's prints


def test_docs_link_checker_green():
    """The committed docs tree must pass its own CI gate."""
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools",
                                      "check_docs_links.py")],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "docs link check OK" in proc.stdout


def test_serve_tuned_env_lands_before_jax_fresh_process():
    """From a fresh interpreter --tuned must apply the env preset
    before the jax import (jax reads env once) and say so."""
    env = dict(os.environ)
    for k in ("TF_CPP_MIN_LOG_LEVEL",
              "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"):
        env.pop(k, None)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--text-len", "1500", "--queries", "60", "--batch", "24",
         "--max-pattern", "12", "--top-k", "2", "--page-size", "16",
         "--coalesce-window", "0.5", "--tuned"],
        env=env, capture_output=True, text=True, timeout=600).stdout
    assert ("[tune  ] preset: TF_CPP_MIN_LOG_LEVEL=4 "
            "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000") in out
    assert "jax already imported" not in out
    assert "[trace ] span p50/p95/p99 ms:" in out

"""Corpus dedup / contamination via the suffix-array index."""
import numpy as np

from repro.core import dedup
from repro.core.codec import random_dna
from repro.core.tablet import build_tablet_store
from repro.data.pipeline import dedup_token_pool, dna_corpus


def test_duplicate_span_detection():
    base = random_dna(512, seed=2)
    corpus = np.concatenate([base, random_dna(300, seed=9), base[:200]])
    store = build_tablet_store(corpus, is_dna=True)
    mask = np.asarray(dedup.duplicate_span_mask(store, 32))
    assert mask[:150].all()                       # original block marked
    assert mask[812:912].all()                    # copy marked
    assert mask[560:740].mean() < 0.2             # unique middle unmarked


def test_doc_filter():
    base = random_dna(512, seed=2)
    corpus = np.concatenate([base, random_dna(300, seed=9), base[:200]])
    store = build_tablet_store(corpus, is_dna=True)
    doc_ids = np.concatenate([np.zeros(512, int), np.ones(300, int),
                              np.full(200, 2)])
    keep = dedup.filter_duplicate_docs(store, doc_ids, 32, threshold=0.5)
    assert keep[1] and not keep[2]


def test_contamination_check():
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, 1000, 2000).astype(np.int32)
    store = build_tablet_store(corpus, is_dna=False)
    in_corpus = corpus[500:508][None]
    not_in = (corpus[500:508] + 1001)[None]        # tokens outside range
    got = dedup.contamination_check(
        store, np.concatenate([in_corpus, not_in % 2000]))
    assert got[0]


def test_planted_duplicate_fraction():
    corpus = dna_corpus(4000, seed=1, dup_fraction=0.5)
    store = build_tablet_store(corpus, is_dna=True)
    frac = float(dedup.duplicate_fraction(store, 64))
    assert frac > 0.4


def test_dedup_token_pool():
    rng = np.random.default_rng(3)
    doc_a = rng.integers(0, 5000, 200).astype(np.int32)
    doc_b = rng.integers(0, 5000, 200).astype(np.int32)
    tokens = np.concatenate([doc_a, doc_b, doc_a])   # doc 2 duplicates doc 0
    doc_ids = np.repeat([0, 1, 2], 200)
    keep = dedup_token_pool(tokens, doc_ids, min_len=32)
    assert keep[1]
    assert not keep[2] or not keep[0]

"""CLI coverage for ``repro.launch.serve`` — previously hand-run only.

Each test drives ``serve.main`` in-process with tiny sizes and asserts
on the printed protocol: the create/reopen split, LSM knobs, the
coalescing demo, the streaming demo, and the new durability flags
(``--wal`` / ``--group-commit-ms``).
"""
import ast
import os

import pytest

from repro.api import SuffixTable
from repro.launch import serve

TINY = ["--text-len", "1500", "--queries", "120", "--batch", "48",
        "--max-pattern", "12", "--top-k", "2", "--page-size", "16",
        "--coalesce-window", "0.5"]


def test_serve_in_memory_end_to_end(capsys):
    serve.main(TINY)
    out = capsys.readouterr().out
    assert "[build]" in out and "[open ]" not in out
    assert "[single]" in out and "[hedged]" in out
    assert "[client]" in out and "dispatch(es)" in out
    assert "[stream]" in out and "[write ]" in out
    assert "[wal   ] disabled" in out          # in-memory: no log
    # the streaming demo's paged total must equal the one-shot count
    line = next(ln for ln in out.splitlines() if ln.startswith("[stream]"))
    n_pos = int(line.split(":")[1].split()[0])
    want = int(line.split("one-shot count")[1].strip(" )\n"))
    assert n_pos == want


def test_serve_create_then_reopen_honors_flags(tmp_path, capsys):
    root = str(tmp_path / "root")
    args = TINY + ["--root", root, "--table", "t1", "--aux-table", "t2",
                   "--memtable-limit", "600", "--max-runs", "2",
                   "--group-commit-ms", "1.0"]
    serve.main(args)
    first = capsys.readouterr().out
    assert "[build]" in first
    assert "[wal   ] seq=" in first            # log active on the root
    assert os.path.isdir(os.path.join(root, "t1", "wal"))

    serve.main(args + ["--capacity-factor", "1.5"])
    second = capsys.readouterr().out
    assert "[open ]" in second and "[build]" not in second
    assert "(no rebuild, cf=1.5)" in second    # reopen honors the flag
    assert "[tiers ]" in second and "[wal   ] seq=" in second

    # the two runs' write demos both landed durably: each appends the
    # 21-base planted pattern + 993 random bases
    t = SuffixTable.open("t1", root=root)
    assert len(t) == 1500 + 2 * (21 + 993)


def test_serve_no_wal_flag(tmp_path, capsys):
    root = str(tmp_path / "root")
    serve.main(TINY + ["--root", root, "--no-wal"])
    out = capsys.readouterr().out
    assert "[wal   ] disabled" in out
    assert not os.path.exists(os.path.join(root, "dna_serve", "wal"))


def test_serve_rejects_contradictory_sizes():
    with pytest.raises(SystemExit):
        serve.main(["--queries", "not-a-number"])


def test_serve_clamps_max_pattern(capsys):
    serve.main(TINY + ["--max-pattern", "4096"])
    out = capsys.readouterr().out
    assert "[clamp ]" in out and "-> 128" in out


def test_serve_freeze_flags_and_bytes_line(tmp_path, capsys):
    """--freeze routes the whole workload through the frozen FM tier and
    the [bytes ] stats line reports the per-tier residency shift."""
    serve.main(TINY + ["--freeze"])
    out = capsys.readouterr().out
    assert "[freeze]" in out
    bl = next(ln for ln in out.splitlines() if ln.startswith("[bytes ]"))
    assert "frozen=True" in bl and "base_sa=0" in bl
    fm_bytes = int(bl.split("fm=")[1].split()[0])
    assert fm_bytes > 0
    assert "'fm':" in out                      # planner ran in fm mode

    # --fm-threshold persists: auto-freeze at create, still frozen and
    # serving after reopen (artifact reload, no rebuild)
    root = str(tmp_path / "root")
    args = TINY + ["--root", root, "--fm-threshold", "1000"]
    serve.main(args)
    first = capsys.readouterr().out
    assert "[build]" in first and "frozen=True" in first
    assert os.path.isdir(os.path.join(root, "dna_serve", "fm"))
    serve.main(args)
    second = capsys.readouterr().out
    assert "[open ]" in second and "frozen=True" in second

    # without the flags the live path is untouched
    serve.main(TINY)
    plain = capsys.readouterr().out
    assert "frozen=False" in plain and "[freeze]" not in plain


def test_serve_host_devices_warns_when_jax_is_live(capsys):
    """In-process jax is long imported, so the tuned launch path must
    say the flag cannot take effect (instead of silently ignoring it)."""
    serve.main(TINY + ["--host-devices", "4"])
    out = capsys.readouterr().out
    assert "[tune  ] warning: jax already imported" in out
    assert "--host-devices 4 cannot take effect" in out


def test_serve_host_devices_fresh_process():
    """From a fresh interpreter the flag lands in XLA_FLAGS before the
    jax import and the run really sees N host devices."""
    import subprocess
    import sys
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", *TINY,
         "--queries", "48", "--host-devices", "2"],
        env=env, capture_output=True, text=True, timeout=600).stdout
    assert "[tune  ] XLA_FLAGS += " \
           "--xla_force_host_platform_device_count=2" in out
    assert "(2 device(s))" in out


def test_serve_plane_demo_and_varz(tmp_path, capsys):
    """--tablets splits, serves from worker processes, and answers the
    probe scan bit-identically; --dump-stats then aggregates the
    metrics feed the plane left behind, without touching jax."""
    root = str(tmp_path / "root")
    args = TINY + ["--root", root, "--tablets", "2",
                   "--plane-replicas", "2"]
    serve.main(args)
    out = capsys.readouterr().out
    assert "identical=True" in out
    assert "2 tablet(s) x 2 replica(s)" in out
    assert "[plane ] router rpcs=" in out

    serve.main(["--root", root, "--table", "dna_serve", "--dump-stats"])
    varz = capsys.readouterr().out
    assert "[varz  ] table=dna_serve" in varz
    assert "tablets=2" in varz
    assert "[varz  ] worker t0r0" in varz
    assert "[varz  ] queries=" in varz


def test_serve_plane_needs_root(capsys):
    serve.main(TINY + ["--tablets", "2"])
    out = capsys.readouterr().out
    assert "[clamp ] --tablets needs --root" in out
    assert "[plane ]" not in out


def test_serve_dump_stats_needs_root(capsys):
    serve.main(["--dump-stats"])
    out = capsys.readouterr().out
    assert "--dump-stats needs --root" in out


def test_serve_locate_rows_are_real_positions(capsys):
    serve.main(TINY)
    out = capsys.readouterr().out
    # every locate row printed must be ascending non-negative positions
    for line in out.splitlines():
        if line.startswith("[locate]") and "first_" in line:
            shown = line.split("=", 2)[-1].strip()
            row = ast.literal_eval(shown)
            assert row == sorted(row)
            assert all(isinstance(x, int) and x >= 0 for x in row)

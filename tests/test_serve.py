"""CLI coverage for ``repro.launch.serve`` — previously hand-run only.

Each test drives ``serve.main`` in-process with tiny sizes and asserts
on the printed protocol: the create/reopen split, LSM knobs, the
coalescing demo, the streaming demo, and the new durability flags
(``--wal`` / ``--group-commit-ms``).
"""
import ast
import os

import pytest

from repro.api import SuffixTable
from repro.launch import serve

TINY = ["--text-len", "1500", "--queries", "120", "--batch", "48",
        "--max-pattern", "12", "--top-k", "2", "--page-size", "16",
        "--coalesce-window", "0.5"]


def test_serve_in_memory_end_to_end(capsys):
    serve.main(TINY)
    out = capsys.readouterr().out
    assert "[build]" in out and "[open ]" not in out
    assert "[single]" in out and "[hedged]" in out
    assert "[client]" in out and "dispatch(es)" in out
    assert "[stream]" in out and "[write ]" in out
    assert "[wal   ] disabled" in out          # in-memory: no log
    # the streaming demo's paged total must equal the one-shot count
    line = next(ln for ln in out.splitlines() if ln.startswith("[stream]"))
    n_pos = int(line.split(":")[1].split()[0])
    want = int(line.split("one-shot count")[1].strip(" )\n"))
    assert n_pos == want


def test_serve_create_then_reopen_honors_flags(tmp_path, capsys):
    root = str(tmp_path / "root")
    args = TINY + ["--root", root, "--table", "t1", "--aux-table", "t2",
                   "--memtable-limit", "600", "--max-runs", "2",
                   "--group-commit-ms", "1.0"]
    serve.main(args)
    first = capsys.readouterr().out
    assert "[build]" in first
    assert "[wal   ] seq=" in first            # log active on the root
    assert os.path.isdir(os.path.join(root, "t1", "wal"))

    serve.main(args + ["--capacity-factor", "1.5"])
    second = capsys.readouterr().out
    assert "[open ]" in second and "[build]" not in second
    assert "(no rebuild, cf=1.5)" in second    # reopen honors the flag
    assert "[tiers ]" in second and "[wal   ] seq=" in second

    # the two runs' write demos both landed durably: each appends the
    # 21-base planted pattern + 993 random bases
    t = SuffixTable.open("t1", root=root)
    assert len(t) == 1500 + 2 * (21 + 993)


def test_serve_no_wal_flag(tmp_path, capsys):
    root = str(tmp_path / "root")
    serve.main(TINY + ["--root", root, "--no-wal"])
    out = capsys.readouterr().out
    assert "[wal   ] disabled" in out
    assert not os.path.exists(os.path.join(root, "dna_serve", "wal"))


def test_serve_rejects_contradictory_sizes():
    with pytest.raises(SystemExit):
        serve.main(["--queries", "not-a-number"])


def test_serve_clamps_max_pattern(capsys):
    serve.main(TINY + ["--max-pattern", "4096"])
    out = capsys.readouterr().out
    assert "[clamp ]" in out and "-> 128" in out


def test_serve_freeze_flags_and_bytes_line(tmp_path, capsys):
    """--freeze routes the whole workload through the frozen FM tier and
    the [bytes ] stats line reports the per-tier residency shift."""
    serve.main(TINY + ["--freeze"])
    out = capsys.readouterr().out
    assert "[freeze]" in out
    bl = next(ln for ln in out.splitlines() if ln.startswith("[bytes ]"))
    assert "frozen=True" in bl and "base_sa=0" in bl
    fm_bytes = int(bl.split("fm=")[1].split()[0])
    assert fm_bytes > 0
    assert "'fm':" in out                      # planner ran in fm mode

    # --fm-threshold persists: auto-freeze at create, still frozen and
    # serving after reopen (artifact reload, no rebuild)
    root = str(tmp_path / "root")
    args = TINY + ["--root", root, "--fm-threshold", "1000"]
    serve.main(args)
    first = capsys.readouterr().out
    assert "[build]" in first and "frozen=True" in first
    assert os.path.isdir(os.path.join(root, "dna_serve", "fm"))
    serve.main(args)
    second = capsys.readouterr().out
    assert "[open ]" in second and "frozen=True" in second

    # without the flags the live path is untouched
    serve.main(TINY)
    plain = capsys.readouterr().out
    assert "frozen=False" in plain and "[freeze]" not in plain


def test_serve_locate_rows_are_real_positions(capsys):
    serve.main(TINY)
    out = capsys.readouterr().out
    # every locate row printed must be ascending non-negative positions
    for line in out.splitlines():
        if line.startswith("[locate]") and "first_" in line:
            shown = line.split("=", 2)[-1].strip()
            row = ast.literal_eval(shown)
            assert row == sorted(row)
            assert all(isinstance(x, int) and x >= 0 for x in row)

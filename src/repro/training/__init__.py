from repro.training import optimizer
from repro.training.optimizer import OptConfig
from repro.training.train_step import TrainState, make_train_step, train_state_init

__all__ = ["OptConfig", "TrainState", "make_train_step", "optimizer",
           "train_state_init"]

"""Training step: loss/grad, microbatch accumulation, optimizer apply.

Gradient accumulation is a ``lax.scan`` over microbatches with fp32
accumulators; with GSPMD the cross-device grad reduction is deferred to
the single consumer after the loop, which is what lets XLA overlap the
reduce-scatter with the next microbatch's backward (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import forward_train
from repro.models.config import ModelConfig
from repro.training import optimizer as opt


@partial(jax.tree_util.register_dataclass,
         data_fields=("params", "opt_state", "step"),
         meta_fields=())
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


def train_state_init(model_cfg: ModelConfig, opt_cfg: opt.OptConfig, key,
                     dtype=jnp.float32) -> TrainState:
    from repro.models import init_params
    params = init_params(model_cfg, key, dtype)
    return TrainState(params=params, opt_state=opt.init(opt_cfg, params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model_cfg: ModelConfig, opt_cfg: opt.OptConfig,
                    *, microbatches: int = 1, remat: bool = True,
                    shard=None, scan_unroll: int | bool = 1,
                    loss_chunk: int | None = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).  ``batch``
    leading dim must be divisible by ``microbatches``."""
    shard_fn = shard if shard is not None else (lambda x, _n: x)

    def loss_fn(params, mb):
        loss, metrics = forward_train(model_cfg, params, mb,
                                      shard=shard_fn, remat=remat,
                                      scan_unroll=scan_unroll,
                                      loss_chunk=loss_chunk)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def body(acc, mb):
                acc_g, acc_l = acc
                (loss, _m), g = grad_fn(state.params, mb)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), mbs,
                unroll=(microbatches if scan_unroll is True else 1))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {"loss": loss}
        new_params, new_opt, om = opt.apply(
            opt_cfg, grads, state.opt_state, state.params, state.step)
        metrics = dict(metrics)
        metrics.update(om)
        return (TrainState(params=new_params, opt_state=new_opt,
                           step=state.step + 1), metrics)

    return train_step

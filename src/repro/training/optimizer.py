"""Optimizers: AdamW and Adafactor (factored second moment).

Giant-MoE configs (deepseek-v3, kimi-k2) train with Adafactor so optimizer
state fits v5e HBM (DESIGN.md §5); everything else uses AdamW.  Functional
API: ``init(params) -> state``, ``apply(grads, state, params, step, lr) ->
(new_params, new_state)``.  Global-norm clipping and decoupled weight decay
included; LR schedule = linear warmup + cosine decay.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9              # adafactor: 0.0 disables momentum
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # bf16 halves optimizer HBM


def lr_schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(cfg: OptConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_apply(cfg: OptConfig, grads, state, params, step, lr):
    b1, b2 = cfg.b1, cfg.b2
    t = step + 1

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / (1 - b1 ** t)
        vh = v32 / (1 - b2 ** t)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(cfg.state_dtype), v32.astype(cfg.state_dtype))

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored v for matrices, full v for vectors
# ---------------------------------------------------------------------------
def _factored(shape):
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(cfg: OptConfig, params):
    def init_one(p):
        st = {}
        if _factored(p.shape):
            st["vr"] = jnp.zeros(p.shape[:-1], cfg.state_dtype)
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], cfg.state_dtype)
        else:
            st["v"] = jnp.zeros(p.shape, cfg.state_dtype)
        if cfg.b1 > 0:
            st["m"] = jnp.zeros(p.shape, cfg.state_dtype)
        return st
    return jax.tree.map(init_one, params)


def adafactor_apply(cfg: OptConfig, grads, state, params, step, lr):
    b2 = cfg.b2
    t = step + 1
    bias = 1 - b2 ** t

    def upd(g, st, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        new_st = {}
        if "vr" in st:
            vr = b2 * st["vr"].astype(jnp.float32) + (1 - b2) * g2.mean(-1)
            vc = b2 * st["vc"].astype(jnp.float32) + (1 - b2) * g2.mean(-2)
            new_st["vr"] = vr.astype(cfg.state_dtype)
            new_st["vc"] = vc.astype(cfg.state_dtype)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.clip(vr.mean(-1)[..., None, None], 1e-30)) / bias
            rms = jnp.sqrt(denom)
        else:
            v = b2 * st["v"].astype(jnp.float32) + (1 - b2) * g2
            new_st["v"] = v.astype(cfg.state_dtype)
            rms = jnp.sqrt(v / bias)
        delta = g / jnp.maximum(rms, cfg.eps)
        if cfg.b1 > 0:
            m = cfg.b1 * st["m"].astype(jnp.float32) + (1 - cfg.b1) * delta
            new_st["m"] = m.astype(cfg.state_dtype)
            delta = m
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_st)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state)
    outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_state = tdef.unflatten([o[1] for o in outs])
    return new_params, new_state


def init(cfg: OptConfig, params):
    return (adamw_init if cfg.kind == "adamw" else adafactor_init)(cfg, params)


def apply(cfg: OptConfig, grads, state, params, step):
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    fn = adamw_apply if cfg.kind == "adamw" else adafactor_apply
    new_params, new_state = fn(cfg, grads, state, params, step, lr)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

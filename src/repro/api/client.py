"""``repro.api.client`` — the Bigtable-style client frontend.

Bigtable separates the storage layer from the client read API: callers
hold a client handle, describe reads as typed request objects with
row-set restrictions, and stream large results in pages (``ReadRows``).
The storage side of this repo (``SuffixTable`` + the LSM tier) grew
first; this module is the missing frontend:

* :class:`Database` — a handle over one :class:`~repro.api.Catalog`
  root.  It routes typed queries by table name, lazily opens and caches
  tables, owns the :class:`QueryScheduler`, and is the only object a
  serving caller needs;
* :class:`Query` / :class:`QueryResult` — the typed request/response
  pair.  ``kind`` is one of ``count`` / ``contains`` / ``locate`` /
  ``scan``; patterns are strings or raw encoded code rows; ``top_k``,
  ``max_len`` and a per-query deadline ride along;
* :class:`QueryScheduler` — cross-caller micro-batch coalescing: N
  callers each submitting one pattern inside the coalesce window cost
  ONE bucket-padded jitted planner invocation, not N.  This is what the
  paper's Table IV (50 concurrent users) is begging for: sustained
  queries/sec is set by dispatches, not by per-query compare work;
* :class:`ReadSession` — the ``ReadRows`` analogue: a huge ``locate``
  enumeration streams back in bounded pages with a resumable
  continuation cursor (positions are global text offsets, so cursors
  survive minor and major compactions).

Semantics: every path funnels into ``SuffixTable.scan`` /
``scan_batch``, so coalesced results are bit-identical to per-call
results — ``benchmarks/client_bench.py`` asserts this while measuring
the queries/sec win.  See docs/client_api.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time
from typing import Iterator, Optional, Sequence, Union

import numpy as np

from repro.api.catalog import Catalog
from repro.api.table import SuffixTable
from repro.core.planner import ScanOutcome
from repro.serving.trace import Tracer

QUERY_KINDS = ("count", "contains", "locate", "scan")


# ---------------------------------------------------------------------------
# typed request / response
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Query:
    """One typed read request against a named table.

    Exactly one of ``patterns`` (strings, encoded by the table) or
    ``codes`` + ``lens`` (a pre-encoded batch in the table's store
    encoding: packed uint32 DNA words or int32 code rows) must be given.

    ``kind`` picks the payload of :attr:`QueryResult.value`:
    ``count`` → exact counts, ``contains`` → membership, ``locate`` →
    the ``top_k`` smallest positions, ``scan`` → the full result.
    ``max_len`` rejects over-long patterns at construction (the table
    cap still applies at execution); ``deadline_ms`` bounds how long the
    query may wait in the scheduler queue before execution starts —
    an expired query gets an error result, never a silent stale answer.
    ``tenant`` names the quota account the query is charged to when the
    routed table meters admission (``RemoteTable.admit`` — see
    docs/serving_plane.md); unmetered tables ignore it.
    """
    table: str
    kind: str = "scan"
    patterns: Optional[tuple] = None
    codes: Optional[np.ndarray] = None
    lens: Optional[np.ndarray] = None
    top_k: int = 0
    max_len: Optional[int] = None
    deadline_ms: Optional[float] = None
    tenant: Optional[str] = None

    def __post_init__(self):
        if self.kind not in QUERY_KINDS:
            raise ValueError(f"kind must be one of {QUERY_KINDS}, "
                             f"got {self.kind!r}")
        if (self.patterns is None) == (self.codes is None):
            raise ValueError("exactly one of patterns= (strings) or "
                             "codes=+lens= (encoded rows) must be given")
        if self.patterns is not None:
            pats = tuple(self.patterns)
            if not pats:
                raise ValueError("empty pattern list")
            if not all(isinstance(p, str) for p in pats):
                raise TypeError("patterns must be strings; pass encoded "
                                "batches via codes=/lens=")
            object.__setattr__(self, "patterns", pats)
        else:
            if self.lens is None:
                raise ValueError("codes= requires lens= (per-row lengths)")
            codes = np.asarray(self.codes)
            lens = np.asarray(self.lens)
            if codes.ndim != 2 or lens.ndim != 1 \
                    or codes.shape[0] != lens.shape[0]:
                raise ValueError(
                    f"codes must be (B, W) with lens (B,); got "
                    f"{codes.shape} / {lens.shape}")
            if codes.shape[0] == 0:
                raise ValueError("empty encoded batch")
            object.__setattr__(self, "codes", codes)
            object.__setattr__(self, "lens", lens)
        if self.max_len is not None:
            too_long = (max(len(p) for p in self.patterns)
                        if self.patterns is not None
                        else int(np.max(self.lens)))
            if too_long > self.max_len:
                raise ValueError(f"pattern length {too_long} exceeds this "
                                 f"query's max_len={self.max_len}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.kind == "locate" and self.top_k == 0:
            object.__setattr__(self, "top_k", 8)

    @property
    def num_patterns(self) -> int:
        return (len(self.patterns) if self.patterns is not None
                else int(self.codes.shape[0]))

    # -- convenience constructors -------------------------------------------
    @classmethod
    def count(cls, table: str, patterns: Sequence[str], **kw) -> "Query":
        return cls(table=table, kind="count", patterns=tuple(patterns), **kw)

    @classmethod
    def contains(cls, table: str, patterns: Sequence[str], **kw) -> "Query":
        return cls(table=table, kind="contains", patterns=tuple(patterns),
                   **kw)

    @classmethod
    def locate(cls, table: str, patterns: Sequence[str], top_k: int = 8,
               **kw) -> "Query":
        return cls(table=table, kind="locate", patterns=tuple(patterns),
                   top_k=top_k, **kw)

    @classmethod
    def scan(cls, table: str, patterns: Sequence[str], top_k: int = 0,
             **kw) -> "Query":
        return cls(table=table, kind="scan", patterns=tuple(patterns),
                   top_k=top_k, **kw)


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Typed response: always exact, merged over every LSM tier.

    ``positions`` rows follow the table's text-order semantics (the
    ``top_k`` smallest occurrence positions, ascending, −1-padded).
    ``batch_size`` is the number of patterns in the coalesced batch this
    query actually rode in (== ``num_patterns`` for an uncoalesced
    call); ``wait_ms`` is the time it spent queued before execution.
    A deadline expiry or execution failure sets ``error`` (arrays are
    then empty) — check :attr:`ok` or use :attr:`value`, which raises.
    """
    kind: str
    found: np.ndarray                      # (B,)  bool
    count: np.ndarray                      # (B,)  int64
    first_pos: np.ndarray                  # (B,)  int64
    positions: Optional[np.ndarray]        # (B, top_k) int64 | None
    batch_size: int = 0
    wait_ms: float = 0.0
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def overloaded(self) -> bool:
        """True when the query was SHED by admission control — a tenant
        quota or a saturated worker fleet — rather than failed.  Shed is
        a typed, retryable outcome: the caller should back off, not
        treat the answer as wrong (docs/serving_plane.md)."""
        return self.error is not None and "OVERLOADED" in self.error

    @property
    def value(self):
        """The kind-appropriate payload; raises on an error result."""
        if self.error is not None:
            raise RuntimeError(f"query failed: {self.error}")
        if self.kind == "count":
            return self.count
        if self.kind == "contains":
            return self.found
        if self.kind == "locate":
            return self.positions
        return self


def _error_result(query: Query, message: str,
                  wait_ms: float = 0.0) -> QueryResult:
    z = np.zeros((0,), np.int64)
    return QueryResult(kind=query.kind, found=z.astype(bool), count=z,
                       first_pos=z, positions=None, batch_size=0,
                       wait_ms=wait_ms, error=message)


class QueryFuture:
    """Handle for a submitted query; ``result()`` blocks until set."""

    __slots__ = ("_event", "_result")

    def __init__(self):
        self._event = threading.Event()
        self._result: Optional[QueryResult] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> QueryResult:
        if not self._event.wait(timeout):
            raise TimeoutError("query result not ready")
        return self._result

    def _set(self, result: QueryResult) -> None:
        self._result = result
        self._event.set()


# ---------------------------------------------------------------------------
# the coalescing scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SchedulerStats:
    """Counters for the coalescing frontend (``Database.stats()``)."""
    submitted: int = 0            # queries accepted (submit + inline)
    executed: int = 0             # queries that ran to a result
    batches: int = 0              # group executions (device dispatches)
    coalesced_queries: int = 0    # queries that shared a batch with others
    max_batch_patterns: int = 0   # largest coalesced pattern batch seen
    deadline_expired: int = 0
    errors: int = 0
    fast_path_queries: int = 0    # ran inline, bypassing the window
    shed: int = 0                 # rejected by admission (quota/overload)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Pending:
    query: Query
    future: QueryFuture
    t_submit: float


class QueryScheduler:
    """Coalesces concurrent queries from many callers and tables.

    The first query to arrive opens a coalesce window of ``window_ms``;
    everything submitted before it closes (or before ``max_batch``
    queries accumulate) is drained as one wave, grouped by (table,
    encoding), and each group executes as a SINGLE bucket-padded jitted
    planner invocation through ``SuffixTable.scan`` / ``scan_batch``.
    Queries whose ``deadline_ms`` expired while queued get an error
    result instead of running — and the window never waits past the
    earliest live deadline.

    ``window_ms=0`` still coalesces whatever is queued at drain time
    (submissions racing the drain), it just never waits for more.  The
    worker thread starts lazily on the first :meth:`submit` and exits on
    :meth:`close` after draining the queue.

    **Adaptive window** (``adaptive=True``, the default): ``window_ms``
    becomes a CEILING, not a constant price.  The scheduler keeps an
    EWMA of observed inter-arrival gaps and

    * under LOW load (average gap >= ``fastpath_gap_ms``, i.e. waiting
      would not find a peer to coalesce with) a submit with an idle
      queue executes INLINE on the caller thread — no window, no worker
      hop (``stats.fast_path_queries``);
    * otherwise the drain closes once the queue has been quiet for
      ``~2x`` the average gap (capped at ``window_ms``) instead of
      sleeping out the rest of the window — a lone straggler stops
      paying the full window for peers that never arrive, while a
      saturating caller population (gap << window) still fills whole
      waves and keeps the coalesced-throughput win.

    ``adaptive=False`` restores the fixed-window behavior exactly.
    """

    def __init__(self, resolve_table, *, window_ms: float = 2.0,
                 max_batch: int = 1024, adaptive: bool = True,
                 fastpath_gap_ms: Optional[float] = None):
        if window_ms < 0 or max_batch < 1:
            raise ValueError(f"need window_ms >= 0 and max_batch >= 1, got "
                             f"window_ms={window_ms} max_batch={max_batch}")
        self._resolve = resolve_table          # name -> SuffixTable
        self.window_ms = float(window_ms)
        self.max_batch = int(max_batch)
        self.adaptive = bool(adaptive)
        # gap above which a query would (on average) close its window
        # alone — waiting buys nothing, so the fast path takes over
        self.fastpath_gap_ms = (max(self.window_ms, 0.5)
                                if fastpath_gap_ms is None
                                else float(fastpath_gap_ms))
        self._ewma_gap_ms: Optional[float] = None   # arrival-gap EWMA
        self._last_arrival: Optional[float] = None
        self._window_current_ms = self.window_ms    # exported in stats
        self._busy = 0                 # waves executing right now
        self.stats = SchedulerStats()
        # span histograms (stats_snapshot()["latency"]): coalesce_wait,
        # admission, execute — docs/observability.md defines each
        self.tracer = Tracer()
        self._cv = threading.Condition()
        # one lock PER TABLE OBJECT serializes that table's scans and
        # client-side writes: the worker thread draining windowed waves
        # and inline execute_now() callers would otherwise scan the same
        # table (and its caches/stats) concurrently, and a write landing
        # mid-scan would tear the multi-tier view.  Keyed per table so a
        # slow write/compaction on one table never stalls serving of the
        # others.  Coalescing is the concurrency story; dispatches to
        # any single table are serial.
        self._table_locks: dict[int, threading.Lock] = {}
        self._pending: list[_Pending] = []
        self._thread: Optional[threading.Thread] = None
        self._closed = False

    # -- adaptive window ------------------------------------------------------
    def _note_arrival(self, now: float) -> None:
        """Fold one submit into the arrival-gap EWMA and refresh the
        current window size (call with ``_cv`` held)."""
        if self._last_arrival is not None:
            gap_ms = (now - self._last_arrival) * 1e3
            a = 0.25
            self._ewma_gap_ms = (gap_ms if self._ewma_gap_ms is None
                                 else (1 - a) * self._ewma_gap_ms + a * gap_ms)
        self._last_arrival = now
        if not self.adaptive:
            return
        if self._ewma_gap_ms is None:
            self._window_current_ms = self.window_ms
        elif self._ewma_gap_ms >= self.fastpath_gap_ms:
            self._window_current_ms = 0.0      # low load: don't wait at all
        else:
            # quiet for ~2 average gaps => nobody else is coming.  Floored
            # at 0.5 ms: saturated submitters (gap ~ microseconds) stall
            # for that long on GC/GIL hiccups, and closing the window on
            # one would split the wave into fragment batches — each a
            # fresh bucket shape, i.e. a pointless recompile.
            self._window_current_ms = min(self.window_ms,
                                          max(0.5, 2.0 * self._ewma_gap_ms))

    def _fast_path_ok(self) -> bool:
        """Inline execution beats windowing: queue idle, nothing mid-
        drain, and arrivals too sparse for coalescing to find a peer
        (call with ``_cv`` held)."""
        return (self.adaptive and not self._pending and self._busy == 0
                and (self._ewma_gap_ms is None
                     or self._ewma_gap_ms >= self.fastpath_gap_ms))

    # -- async path ----------------------------------------------------------
    def submit(self, query: Query) -> QueryFuture:
        """Enqueue for the current coalesce window; returns a future.
        Under adaptive low load the query instead executes inline on the
        calling thread (the window would buy nothing) — the future is
        already resolved when it returns."""
        fut = QueryFuture()
        now = time.perf_counter()
        pend = _Pending(query, fut, now)
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self.stats.submitted += 1
            self._note_arrival(now)
            if self._fast_path_ok():
                self.stats.fast_path_queries += 1
                self._busy += 1
                inline = True
            else:
                inline = False
                self._pending.append(pend)
                if self._thread is None:
                    self._thread = threading.Thread(
                        target=self._loop, name="query-scheduler",
                        daemon=True)
                    self._thread.start()
                self._cv.notify_all()
        if inline:
            try:
                self._execute([pend])
            finally:
                with self._cv:
                    self._busy -= 1
                    self._cv.notify_all()
        return fut

    def _deadline_of(self, wave_open: float) -> float:
        """Absolute drain time: window close (adaptive: or the queue
        going quiet for the current window), capped by the earliest
        per-query deadline among pending queries."""
        t = wave_open + self.window_ms / 1e3
        if self.adaptive and self._last_arrival is not None:
            t = min(t, self._last_arrival + self._window_current_ms / 1e3)
        for p in self._pending:
            if p.query.deadline_ms is not None:
                t = min(t, p.t_submit + p.query.deadline_ms / 1e3)
        return t

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending and self._closed:
                    return
                wave_open = self._pending[0].t_submit
                while (not self._closed
                       and len(self._pending) < self.max_batch):
                    now = time.perf_counter()
                    left = self._deadline_of(wave_open) - now
                    if left <= 0:
                        break
                    self._cv.wait(timeout=left)
                wave = self._pending[:self.max_batch]
                del self._pending[:len(wave)]
                self._busy += 1
            try:
                self._execute(wave)
            finally:
                with self._cv:
                    self._busy -= 1
                    self._cv.notify_all()

    def _lock_for(self, table):
        # tables that fan work out to OTHER processes (RemoteTable) are
        # safe — and meant — to scan concurrently: serializing their
        # dispatches behind one lock would collapse the plane back to
        # single-worker throughput, so they get a no-op guard
        if getattr(table, "supports_concurrent_scans", False):
            return contextlib.nullcontext()
        with self._cv:
            lock = self._table_locks.get(id(table))
            if lock is None:
                lock = self._table_locks[id(table)] = threading.Lock()
            return lock

    def run_exclusive(self, table, fn):
        """Run ``fn()`` while no query batch is executing against
        ``table`` (the object, not the name — aliased registrations
        share one lock) — the hook client-side writes and paged reads
        use so a mutation never lands mid-scan (a seal between the base
        pass and the delta fan-out would double-count the sealed rows)."""
        with self._lock_for(table):
            return fn()

    def close(self, timeout: float = 10.0) -> None:
        """Stop accepting queries, drain the queue, join the worker."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            thread = self._thread
        if thread is not None:
            thread.join(timeout)

    # -- sync path (inline coalescing, no window wait) -----------------------
    def execute_now(self, queries: Sequence[Query]) -> list[QueryResult]:
        """Run ``queries`` as one coalesced wave on the calling thread —
        the inline path ``Database.query``/``query_many`` use.  Grouping,
        bucketing, and results are identical to the windowed path."""
        now = time.perf_counter()
        wave = [_Pending(q, QueryFuture(), now) for q in queries]
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self.stats.submitted += len(wave)
            self._busy += 1
        try:
            self._execute(wave)
        finally:
            with self._cv:
                self._busy -= 1
                self._cv.notify_all()
        return [p.future.result(timeout=0) for p in wave]

    def stats_snapshot(self) -> dict:
        """``SchedulerStats.as_dict()`` plus the adaptive window's live
        state — ``window_ms_current`` (what the next drain will wait)
        and ``ewma_gap_ms`` (the smoothed inter-arrival gap, ``None``
        before two submits) — plus ``latency``: the scheduler tracer's
        span histograms (``coalesce_wait`` / ``admission`` /
        ``execute``) — schema in docs/client_api.md and
        docs/observability.md."""
        with self._cv:
            d = self.stats.as_dict()
            d["window_ms_current"] = self._window_current_ms
            d["ewma_gap_ms"] = self._ewma_gap_ms
        d["latency"] = self.tracer.snapshot()
        return d

    # -- execution core ------------------------------------------------------
    def _execute(self, wave: list[_Pending]) -> None:
        groups: dict[tuple, list[_Pending]] = {}
        for p in wave:
            if p.query.patterns is not None:
                key = (p.query.table, "str")
            else:                     # raw rows coalesce only on equal width
                key = (p.query.table, "raw", p.query.codes.shape[1],
                       p.query.codes.dtype.str)
            groups.setdefault(key, []).append(p)
        for key, plist in groups.items():
            self._execute_group(key, plist)

    def _fail(self, plist: list[_Pending], msg: str, now: float) -> None:
        with self._cv:
            self.stats.errors += len(plist)
        for p in plist:
            p.future._set(_error_result(
                p.query, msg, wait_ms=(now - p.t_submit) * 1e3))

    def _execute_group(self, key: tuple, plist: list[_Pending]) -> None:
        try:
            table = self._resolve(plist[0].query.table)
        except Exception as e:  # noqa: BLE001 — futures must never hang
            self._fail(plist, f"{type(e).__name__}: {e}",
                       time.perf_counter())
            return
        tr = self.tracer
        with self._lock_for(table):
            # deadlines are judged HERE, lock in hand: time queued behind
            # earlier groups or a long client-side write counts against
            # the budget, so an expired query is reported expired instead
            # of executing late over text it never agreed to wait for
            now = time.perf_counter()
            live: list[_Pending] = []
            for p in plist:
                dl = p.query.deadline_ms
                if dl is not None and (now - p.t_submit) * 1e3 > dl:
                    with self._cv:
                        self.stats.deadline_expired += 1
                    p.future._set(_error_result(
                        p.query,
                        f"deadline exceeded: waited "
                        f"{(now - p.t_submit) * 1e3:.2f}ms of {dl}ms budget",
                        wait_ms=(now - p.t_submit) * 1e3))
                else:
                    tr.record("coalesce_wait", (now - p.t_submit) * 1e3)
                    live.append(p)
            # admission: a metered table (the serving-plane router) may
            # shed per tenant BEFORE any work is dispatched — shed is a
            # typed result (`QueryResult.overloaded`), never an answer
            admit = getattr(table, "admit", None)
            if admit is not None and live:
                admitted = []
                with tr.span("admission"):
                    for p in live:
                        if admit(p.query.tenant, p.query.num_patterns):
                            admitted.append(p)
                        else:
                            with self._cv:
                                self.stats.shed += 1
                            p.future._set(_error_result(
                                p.query,
                                f"OVERLOADED: tenant "
                                f"{p.query.tenant!r} is over quota",
                                wait_ms=(now - p.t_submit) * 1e3))
                live = admitted
            if not live:
                return
            try:
                top_k = max(p.query.top_k for p in live)
                spans, n = [], 0
                for p in live:
                    spans.append((n, n + p.query.num_patterns))
                    n += p.query.num_patterns
                with tr.span("execute"):
                    if key[1] == "str":
                        pats: list[str] = []
                        for p in live:
                            pats.extend(p.query.patterns)
                        out = table.scan(pats, top_k=top_k)
                    else:
                        codes = np.concatenate(
                            [p.query.codes for p in live])
                        lens = np.concatenate(
                            [np.asarray(p.query.lens) for p in live])
                        out = table.scan_batch(codes, lens, top_k=top_k)
            except Exception as e:  # noqa: BLE001
                self._fail(live, f"{type(e).__name__}: {e}", now)
                return
        with self._cv:
            self.stats.batches += 1
            self.stats.executed += len(live)
            if len(live) > 1:
                self.stats.coalesced_queries += len(live)
            self.stats.max_batch_patterns = max(
                self.stats.max_batch_patterns, n)
        for p, (lo, hi) in zip(live, spans):
            p.future._set(self._slice(p.query, out, lo, hi, n,
                                      (now - p.t_submit) * 1e3))

    @staticmethod
    def _slice(query: Query, out: ScanOutcome, lo: int, hi: int,
               batch_size: int, wait_ms: float) -> QueryResult:
        """Carve one query's rows out of the group ScanOutcome.  The
        group ran with the max top_k, and positions are ascending-
        complete, so slicing ``[:top_k]`` is bit-identical to running
        the query alone."""
        positions = None
        if query.top_k > 0 and out.positions is not None:
            positions = np.asarray(out.positions[lo:hi, :query.top_k])
        return QueryResult(
            kind=query.kind,
            found=np.asarray(out.found[lo:hi]),
            count=np.asarray(out.count[lo:hi]),
            first_pos=np.asarray(out.first_pos[lo:hi]),
            positions=positions,
            batch_size=batch_size, wait_ms=wait_ms)


# ---------------------------------------------------------------------------
# paged result streaming (the ReadRows analogue)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Page:
    """One bounded chunk of a streamed enumeration."""
    positions: np.ndarray        # ascending global offsets, <= page_size
    cursor: str                  # resume token for the NEXT page
    is_last: bool


class ReadSession:
    """Streams every occurrence position of one pattern in bounded pages.

    The cursor after each page is the last position returned; the next
    page holds the smallest positions strictly greater than it.  Because
    positions are global text offsets — stable across minor and major
    compactions — a serialized cursor (:attr:`cursor`, a JSON token)
    resumes correctly in another process, after an ``append`` or a
    compaction, via :meth:`Database.resume_read`.  Writes landing behind
    the cursor are (by design) not re-surfaced; writes ahead of it show
    up in later pages.
    """

    def __init__(self, database: "Database", table: str, pattern: str, *,
                 page_size: int = 256, start_after: int = -1):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.database = database
        self.table_name = str(table)
        self.pattern = str(pattern)
        self.page_size = int(page_size)
        self._after = int(start_after)
        self._exhausted = False
        # one enumeration per (table write_generation), sliced per page —
        # a stream of P pages costs one scan, not P scans of everything
        self._enum: Optional[np.ndarray] = None
        self._enum_gen: Optional[int] = None

    @property
    def cursor(self) -> str:
        """Serializable continuation token (``Database.resume_read``)."""
        return json.dumps({"v": 1, "table": self.table_name,
                           "pattern": self.pattern,
                           "after": self._after,
                           "page_size": self.page_size})

    @classmethod
    def from_cursor(cls, database: "Database",
                    cursor: Union[str, dict]) -> "ReadSession":
        tok = json.loads(cursor) if isinstance(cursor, str) else dict(cursor)
        if tok.get("v") != 1:
            raise ValueError(f"unknown cursor version {tok.get('v')!r}")
        return cls(database, tok["table"], tok["pattern"],
                   page_size=int(tok["page_size"]),
                   start_after=int(tok["after"]))

    def next_page(self) -> Optional[Page]:
        """The next bounded chunk, or ``None`` once exhausted.  The final
        chunk (possibly empty) has ``is_last=True``; a later resume from
        its cursor sees only rows appended past it since."""
        if self._exhausted:
            return None
        table = self.database.table(self.table_name)

        def _refresh():
            gen = table.write_generation
            if self._enum is None or self._enum_gen != gen:
                self._enum = table.locate_range(self.pattern, after=-1,
                                                limit=None)
                self._enum_gen = gen

        # under the table's execution lock: a write landing mid-
        # enumeration would tear the base/delta view like a mid-scan write
        self.database.scheduler.run_exclusive(table, _refresh)
        start = int(np.searchsorted(self._enum, self._after, side="right"))
        got = self._enum[start:start + self.page_size]
        more = self._enum.size > start + self.page_size
        if got.size:
            self._after = int(got[-1])
        self._exhausted = not more
        return Page(positions=got, cursor=self.cursor, is_last=not more)

    def pages(self) -> Iterator[Page]:
        while True:
            page = self.next_page()
            if page is None:
                return
            yield page

    def positions(self) -> Iterator[int]:
        """Every remaining position, one page at a time."""
        for page in self.pages():
            yield from (int(x) for x in page.positions)

    def __iter__(self) -> Iterator[Page]:
        return self.pages()


# ---------------------------------------------------------------------------
# the database handle
# ---------------------------------------------------------------------------
class Database:
    """A client handle over one catalog root — the serving entry point.

    ``Database(root)`` opens (or creates) a :class:`Catalog` directory
    and routes queries by table name, opening tables lazily and caching
    the handles; ``Database.in_memory()`` (or ``root=None``) skips the
    catalog entirely and serves only :meth:`attach`-ed in-memory tables
    (persistent roots can attach extra in-memory tables too).  One
    :class:`QueryScheduler` is shared by every table, so concurrent
    callers coalesce ACROSS tables into per-table batches.

    The three ways to read::

        db.query(q)            # inline: coalesces only q's own patterns
        db.query_many(qs)      # inline: coalesces the listed queries
        db.submit(q).result()  # windowed: coalesces with OTHER callers

    plus :meth:`read_rows` for paged streaming.  ``close()`` (or a
    ``with`` block) drains the scheduler.
    """

    def __init__(self, root: Optional[str] = None, *,
                 coalesce_window_ms: float = 2.0, max_batch: int = 1024,
                 adaptive_window: bool = True,
                 fastpath_gap_ms: Optional[float] = None,
                 **open_kw):
        self.catalog = Catalog(root) if root is not None else None
        self._open_kw = dict(open_kw)
        self._tables: dict[str, SuffixTable] = {}
        self._owned: set[str] = set()       # opened/created by this handle
        self._remote: set[str] = set()      # plane handles we must close
        self._closed = False
        self._open_lock = threading.Lock()
        self.scheduler = QueryScheduler(
            self.table, window_ms=coalesce_window_ms, max_batch=max_batch,
            adaptive=adaptive_window, fastpath_gap_ms=fastpath_gap_ms)

    @classmethod
    def in_memory(cls, **kw) -> "Database":
        """A rootless database: serves attached tables only."""
        return cls(None, **kw)

    @property
    def root(self) -> Optional[str]:
        return self.catalog.root if self.catalog is not None else None

    # -- table routing -------------------------------------------------------
    def table(self, name: str) -> SuffixTable:
        """The named table — attached, cached, or lazily opened."""
        if self._closed:
            raise RuntimeError("database is closed")
        t = self._tables.get(name)
        if t is None:
            if self.catalog is None:
                raise KeyError(
                    f"no table {name!r} attached to this in-memory "
                    f"database (attach() it, or open a Database(root))")
            with self._open_lock:         # concurrent callers open once
                t = self._tables.get(name)
                if t is None:
                    t = self.catalog.open_table(name, **self._open_kw)
                    self._tables[name] = t
                    self._owned.add(name)
        return t

    def attach(self, name: str, table: SuffixTable) -> SuffixTable:
        """Register an in-memory table under ``name`` for routing."""
        if name in self._tables:
            raise ValueError(f"table {name!r} is already attached")
        self._tables[name] = table
        return table

    def connect_plane(self, name: str, *, attach_as: Optional[str] = None,
                      **router_kw):
        """Route ``name`` through its deployed serving plane
        (``root/<name>/tablets/`` — see docs/serving_plane.md).

        Reads the tablet manifest + live endpoints, builds a
        :class:`~repro.serving.router.RemoteTable`, and attaches it —
        by default UNDER THE TABLE'S OWN NAME, so every existing typed
        query against ``name`` transparently becomes a routed
        multi-process read (the attached handle shadows the lazy
        on-disk open).  ``attach_as`` registers it under an alias
        instead, keeping the local single-process open reachable for
        side-by-side comparison.  ``router_kw`` reaches the
        ``TabletRouter`` (hedging, quotas, metrics).  The handle is
        owned: :meth:`close` shuts its router down."""
        if self.root is None:
            raise RuntimeError("in-memory database has no catalog root "
                               "to read a tablet manifest from")
        from repro.serving.router import connect
        alias = attach_as or name
        if alias in self._tables:
            raise ValueError(f"table {alias!r} is already attached")
        remote = connect(self.root, name, **router_kw)
        self._tables[alias] = remote
        self._remote.add(alias)
        return remote

    def ensure_attached(self, table: SuffixTable,
                        name: Optional[str] = None) -> str:
        """Route an already-built table through this handle and return
        the name to put in ``Query.table``.  Reuses an existing
        registration of the same object; picks a unique private name
        when the natural name is taken by a DIFFERENT table (attached or
        on disk).  The serving engine uses this to ride a shared handle."""
        if name is None:
            for reg, t in self._tables.items():
                if t is table:
                    return reg
        name = name or table.name or "_served"
        if self._tables.get(name) is table:
            return name
        if (name not in self._tables
                and not (self.catalog is not None and name in self.catalog)):
            self._tables[name] = table
            return name
        alt = f"_{name}_{id(table):x}"
        self._tables[alt] = table
        return alt

    def create_table(self, name: str, codes, **kw) -> SuffixTable:
        """Create + persist a table in this root and route to it."""
        if self.catalog is None:
            raise RuntimeError("in-memory database: attach() a table built "
                               "with SuffixTable.from_codes instead")
        t = self.catalog.create_table(name, codes, **kw)
        self._tables[name] = t
        self._owned.add(name)
        return t

    def drop_table(self, name: str, *, missing_ok: bool = False) -> None:
        if self.catalog is None:
            if self._tables.pop(name, None) is None and not missing_ok:
                raise KeyError(f"no table {name!r} attached to this "
                               f"in-memory database")
            return
        # catalog validates (and raises) BEFORE we detach: a failed drop
        # must leave an attached/cached table routed and usable
        self.catalog.drop_table(name, missing_ok=missing_ok)
        t = self._tables.pop(name, None)
        if t is not None:
            t.close()                 # release the dropped table's log fd
        self._owned.discard(name)

    def list_tables(self) -> list[str]:
        names = set(self._tables)
        if self.catalog is not None:
            names.update(self.catalog.list_tables())
        return sorted(names)

    def __contains__(self, name: str) -> bool:
        return (name in self._tables
                or (self.catalog is not None and name in self.catalog))

    # -- typed reads ---------------------------------------------------------
    def query(self, query: Query) -> QueryResult:
        """Execute one query inline (no window wait)."""
        return self.scheduler.execute_now([query])[0]

    def query_many(self, queries: Sequence[Query]) -> list[QueryResult]:
        """Execute a wave of queries inline, coalesced per table."""
        return self.scheduler.execute_now(list(queries))

    def submit(self, query: Query) -> QueryFuture:
        """Enqueue into the coalesce window shared with other callers."""
        return self.scheduler.submit(query)

    # -- writes through the client -------------------------------------------
    def append(self, table: str, codes) -> int:
        """Append through the client: the write is serialized against
        in-flight query batches, so concurrent readers on this handle
        never observe a torn multi-tier view (mutating a table directly
        while other threads read through the client is not
        synchronized).  On a persistent table this call is a **durable
        write ack**: the commit record is logged under the table lock
        but the fsync is awaited OUTSIDE it, so concurrent clients
        appending to the same table batch into one group-commit fsync
        (the write-side mirror of read coalescing — the table's
        ``group_commit_ms`` sets the batching window) while the next
        writer's mutation proceeds.  Triggers the table's automatic
        minor/major compactions as usual; returns the memtable size."""
        t = self.table(table)
        size, token = self.scheduler.run_exclusive(
            t, lambda: t.append_nowait(codes))
        t.wait_durable(token)
        return size

    def compact(self, table: str) -> int:
        """Major-compact through the client (serialized like
        :meth:`append`, against this table's readers only); returns the
        new version."""
        t = self.table(table)
        return self.scheduler.run_exclusive(t, t.compact)

    def freeze(self, table: str, *, sample_rate: int = 32) -> dict:
        """Freeze ``table`` onto the FM-index tier (serialized against
        its readers like :meth:`compact` — the planner rebind must not
        land mid-scan).  Returns the table's per-tier resident-bytes
        stats so the footprint change is immediately observable."""
        t = self.table(table)
        self.scheduler.run_exclusive(
            t, lambda: t.freeze(sample_rate=sample_rate))
        return t.stats()["tiers"]

    def read_rows(self, table: str, pattern: str, *, page_size: int = 256,
                  start_after: int = -1) -> ReadSession:
        """Stream every occurrence position of ``pattern`` in pages."""
        return ReadSession(self, table, pattern, page_size=page_size,
                           start_after=start_after)

    def resume_read(self, cursor: Union[str, dict]) -> ReadSession:
        """Rebuild a :class:`ReadSession` from a serialized cursor."""
        return ReadSession.from_cursor(self, cursor)

    # -- lifecycle / observability -------------------------------------------
    def stats(self) -> dict:
        """``{"scheduler": ..., "tables": {name: table.stats()}}`` for
        every table this handle has touched (schema: docs/client_api.md)."""
        return {"scheduler": self.scheduler.stats_snapshot(),
                "tables": {name: t.stats()
                           for name, t in sorted(self._tables.items())}}

    def close(self) -> None:
        """Shut the handle down, idempotently: stop accepting queries,
        drain and JOIN the scheduler's worker thread, then release the
        commit-log fds of every table THIS handle opened or created and
        the routers of every plane it connected (attached in-memory
        tables stay open — the attacher owns their lifecycle).  After
        ``close()``, :meth:`table` and new queries raise."""
        if self._closed:
            return
        self._closed = True
        self.scheduler.close()
        for name in sorted(self._owned):
            t = self._tables.get(name)
            if t is not None:
                t.close()
        for alias in sorted(self._remote):
            self._tables[alias].close()

    def __enter__(self) -> "Database":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

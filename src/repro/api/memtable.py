"""Single-device memtable suffix index — the write path of ``SuffixTable``.

Bigtable/Accumulo serve reads from an immutable on-disk base plus an
in-memory *memtable* of recent writes; minor compaction seals the memtable
into an immutable run (``repro.api.runs``) and major compaction folds the
runs into the base.  ``Memtable`` is the mutable head of that LSM stack:
appended codes are indexed in a small single-device ``TabletStore`` built
over ``tail + appended``, where ``tail`` is the last ``max_query_len - 1``
symbols of the logical text before this memtable (the *overlap window* —
base text for a fresh table, base + sealed runs otherwise).

The overlap window makes boundary-straddling occurrences — a match whose
start lies before the memtable's region but whose end lies inside it —
visible to the memtable, while every occurrence ending earlier is left to
the base/run tier that owns it.  The merge rule is exact
(docs/table_api.md): with ``g`` the global start position and ``n_base``
the logical text length when this memtable started, the memtable
contributes exactly the occurrences with ``n_base < g + plen <=
n_base + size``; nothing ending at or before ``n_base`` is its to report,
and no occurrence ending past ``n_base`` can start before
``n_base - (max_query_len - 1)``, the left edge of the window.

The memtable store is rebuilt lazily after each append over text padded
to a power-of-two length (symbol 0) — ``n_real`` is a *static* field of
the jitted query, so padding the text itself (rather than only the SA
rows) is what actually bounds recompilation to O(log appends); the
two-sided position filter makes the pad symbols inert.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.api.runs import padded_segment_store, positions_in_bounds
from repro.core.tablet import TabletStore


class Memtable:
    """Recent appends to a :class:`~repro.api.SuffixTable`, queryable.

    ``match_positions`` returns, per query, the **global** text positions
    of exactly the occurrences this memtable owns (ending inside its
    appended region: straddling the boundary, or entirely inside).
    """

    def __init__(self, base_codes: np.ndarray, *, is_dna: bool,
                 max_query_len: int, n_base: Optional[int] = None):
        """``base_codes`` is the logical text preceding this memtable —
        or, when ``n_base`` is given, just its tail (at least the overlap
        window) with ``n_base`` the true logical length (the post-seal
        constructor: the full base + runs text is never materialized)."""
        base_codes = np.asarray(base_codes)
        self.n_base = (int(base_codes.shape[0]) if n_base is None
                       else int(n_base))
        if base_codes.shape[0] > self.n_base:
            raise ValueError(f"tail of {base_codes.shape[0]} symbols for a "
                             f"logical prefix of only {self.n_base}")
        self.is_dna = bool(is_dna)
        self.max_query_len = int(max_query_len)
        self.overlap = int(min(max(self.max_query_len - 1, 0), self.n_base))
        if base_codes.shape[0] < self.overlap:
            raise ValueError(f"need the last {self.overlap} symbols of the "
                             f"logical prefix, got {base_codes.shape[0]}")
        self._tail = np.ascontiguousarray(
            base_codes[base_codes.shape[0] - self.overlap:])
        self._dtype = base_codes.dtype if base_codes.size else (
            np.uint8 if is_dna else np.int32)
        self._chunks: list[np.ndarray] = []
        self.size = 0                       # appended symbols
        self._store: Optional[TabletStore] = None
        self._sa_host: Optional[np.ndarray] = None

    # -- write --------------------------------------------------------------
    @staticmethod
    def validate_codes(codes, *, is_dna: bool) -> np.ndarray:
        """Shape/range-check an append batch and return it as an array.
        Factored out of :meth:`append` so the table's write-ahead log can
        reject a bad batch BEFORE framing it as a commit record — an
        invalid append must fail the caller, never poison the log."""
        codes = np.asarray(codes)
        if codes.ndim != 1:
            raise ValueError(f"append expects a 1-D code array, "
                             f"got shape {codes.shape}")
        if codes.size == 0:
            return codes
        if int(codes.min()) < 0:
            # a negative code would wrap on the uint8 DNA cast (corrupting
            # the index) and aliases the generic store's -1 padding
            raise ValueError("appended codes must be non-negative "
                             f"(got min {int(codes.min())})")
        if is_dna and int(codes.max()) > 3:
            raise ValueError("DNA table: appended codes must be in {0..3} "
                             "(use codec.encode_dna for strings)")
        return codes

    def append(self, codes, *, _prevalidated: bool = False) -> int:
        """Add codes to the memtable; returns the new memtable size.
        ``_prevalidated`` skips re-checking a batch the table already
        ran through :meth:`validate_codes` before logging it (the
        min/max scans are pure waste the second time)."""
        if not _prevalidated:
            codes = self.validate_codes(codes, is_dna=self.is_dna)
        if codes.size == 0:
            return self.size
        self._chunks.append(codes.astype(self._dtype))
        self.size += int(codes.size)
        self._store = None                  # rebuild lazily on next read
        self._sa_host = None
        return self.size

    @property
    def appended(self) -> np.ndarray:
        """All appended codes, in order (empty array when size == 0)."""
        if not self._chunks:
            return np.zeros((0,), self._dtype)
        if len(self._chunks) > 1:
            self._chunks = [np.concatenate(self._chunks)]
        return self._chunks[0]

    # -- read ---------------------------------------------------------------
    def _ensure_store(self) -> TabletStore:
        if self._store is None:
            text = np.concatenate([self._tail, self.appended])
            self._store = padded_segment_store(
                text, is_dna=self.is_dna, max_query_len=self.max_query_len)
            self._sa_host = np.asarray(self._store.sa)
        return self._store

    def match_positions(self, patt, plen,
                        n_real: Optional[int] = None) -> list[np.ndarray]:
        """Global start positions, ascending, of the occurrences only the
        memtable owns; one exact int64 array per query (no top-k cap).
        ``patt``/``plen`` use the same encoding as the base store;
        ``n_real`` marks trailing shape-bucketing pad rows (skipped on
        the host side, still run through the jitted query)."""
        B = int(np.asarray(plen).shape[0])
        if n_real is not None:
            B = min(B, int(n_real))
        if self.size == 0 or B == 0:
            return [np.zeros((0,), np.int64)] * B
        store = self._ensure_store()
        return positions_in_bounds(store, self._sa_host, patt, plen,
                                   offset=self.n_base - self.overlap,
                                   lo=self.n_base, hi=self.n_base + self.size,
                                   n_real=n_real)

"""Single-device memtable suffix index — the write path of ``SuffixTable``.

Bigtable/Accumulo serve reads from an immutable on-disk base plus an
in-memory *memtable* of recent writes; a background compaction folds the
memtable into the base.  ``Memtable`` is that analogue for a suffix-array
table: appended codes are indexed in a small single-device ``TabletStore``
built over ``tail + appended``, where ``tail`` is the last
``max_query_len - 1`` symbols of the base text (the *overlap window*).

The overlap window makes boundary-straddling occurrences — a match whose
start lies in the base but whose end lies in the appended region — visible
to the memtable, while every occurrence that lies entirely inside the base
is left to the base index.  The merge rule is exact (docs/table_api.md):
with ``g`` the global start position and ``n_base`` the base length, the
memtable contributes exactly the occurrences with ``g + plen > n_base``;
any occurrence it sees with ``g + plen <= n_base`` is already counted by
the base scan, and no occurrence with ``g + plen > n_base`` can start
before ``n_base - (max_query_len - 1)``, the left edge of the window.

The memtable store is rebuilt lazily after each append, padded to
power-of-two row buckets so the jitted query recompiles O(log appends)
times rather than once per append.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.tablet import TabletStore, build_tablet_store


def _bucket_rows(n: int) -> int:
    """Next power of two >= n (floor 16) — the memtable's row padding."""
    return 1 << max(4, (max(n, 1) - 1).bit_length())


class Memtable:
    """Recent appends to a :class:`~repro.api.SuffixTable`, queryable.

    ``match_positions`` returns, per query, the **global** text positions
    of exactly the occurrences the base index cannot see (straddling the
    base/append boundary, or entirely inside appended text).
    """

    def __init__(self, base_codes: np.ndarray, *, is_dna: bool,
                 max_query_len: int):
        base_codes = np.asarray(base_codes)
        self.n_base = int(base_codes.shape[0])
        self.is_dna = bool(is_dna)
        self.max_query_len = int(max_query_len)
        self.overlap = int(min(max(self.max_query_len - 1, 0), self.n_base))
        self._tail = np.ascontiguousarray(
            base_codes[self.n_base - self.overlap:])
        self._dtype = base_codes.dtype if base_codes.size else (
            np.uint8 if is_dna else np.int32)
        self._chunks: list[np.ndarray] = []
        self.size = 0                       # appended symbols
        self._store: Optional[TabletStore] = None
        self._sa_host: Optional[np.ndarray] = None
        self._query = jax.jit(Q.query)

    # -- write --------------------------------------------------------------
    def append(self, codes) -> int:
        """Add codes to the memtable; returns the new memtable size."""
        codes = np.asarray(codes)
        if codes.ndim != 1:
            raise ValueError(f"append expects a 1-D code array, "
                             f"got shape {codes.shape}")
        if codes.size == 0:
            return self.size
        if self.is_dna and int(codes.max()) > 3:
            raise ValueError("DNA table: appended codes must be in {0..3} "
                             "(use codec.encode_dna for strings)")
        self._chunks.append(codes.astype(self._dtype))
        self.size += int(codes.size)
        self._store = None                  # rebuild lazily on next read
        self._sa_host = None
        return self.size

    @property
    def appended(self) -> np.ndarray:
        """All appended codes, in order (empty array when size == 0)."""
        if not self._chunks:
            return np.zeros((0,), self._dtype)
        if len(self._chunks) > 1:
            self._chunks = [np.concatenate(self._chunks)]
        return self._chunks[0]

    # -- read ---------------------------------------------------------------
    def _ensure_store(self) -> TabletStore:
        if self._store is None:
            text = np.concatenate([self._tail, self.appended])
            self._store = build_tablet_store(
                text, is_dna=self.is_dna, max_query_len=self.max_query_len,
                min_rows=_bucket_rows(int(text.shape[0])))
            self._sa_host = np.asarray(self._store.sa)
        return self._store

    def match_positions(self, patt, plen) -> list[np.ndarray]:
        """Global start positions, ascending, of the occurrences only the
        memtable can see; one exact int64 array per query (no top-k cap).
        ``patt``/``plen`` use the same encoding as the base store."""
        plen_np = np.asarray(plen)
        B = int(plen_np.shape[0])
        empty = np.zeros((0,), np.int64)
        if self.size == 0 or B == 0:
            return [empty] * B
        store = self._ensure_store()
        res = self._query(store, jnp.asarray(patt), jnp.asarray(plen))
        count = np.asarray(res.count)
        rank = np.asarray(res.first_rank)
        sa, pad = self._sa_host, store.pad_count
        offset = self.n_base - self.overlap     # local row -> global pos
        out = []
        for i in range(B):
            c = int(count[i])
            if c <= 0 or rank[i] < 0:
                out.append(empty)
                continue
            lb = pad + int(rank[i])
            g = sa[lb:lb + c].astype(np.int64) + offset
            g = g[g + int(plen_np[i]) > self.n_base]
            g.sort()
            out.append(g)
        return out

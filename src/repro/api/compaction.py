"""Major compaction by MERGING — fold appended runs into the base SA
without rebuilding it from scratch.

``SuffixTable.compact()`` used to concatenate the text and re-run the full
prefix-doubling builder over all of it, so compacting a 1% append delta
cost the same as the original build.  The merge here exploits the store's
actual query contract: every compare is depth-capped at ``max_query_len``
(= L), so the suffix array only has to be sorted by each suffix's first L
symbols.  Appending ``d`` symbols perturbs that key for just the *dirty*
suffixes — the ones starting within L-1 of the old end — leaving the
``n0 - L + 1`` *clean* entries of the old SA correctly ordered as they
stand.  So:

1. **dirty-range doubling** — run the existing prefix-doubling builder
   over only the text tail ``combined[n0 - (L-1):]`` (``d + L - 1``
   symbols).  Every dirty/new suffix extends to the text end, so the
   tail's suffix array IS their true relative order.
2. **batched merge** — binary-search each dirty/new suffix's insertion
   point into the clean sequence, comparing its depth-L window (packed
   uint32 words for DNA — the same word compare as
   ``kernels/pattern_scan`` — int32 codes otherwise) against the clean
   suffixes; then one vectorized ``np.insert`` interleaves both orders.

Cost: ``O((d + L) log(d + L))`` for step 1 plus ``(d + L)·log(n0)``
depth-L compares for step 2 — versus ``O((n0 + d) log(n0 + d))`` full
doubling rounds for the rebuild.  ``benchmarks/compaction_bench.py``
reports the measured ratio.

Tie semantics: suffixes sharing an entire L-symbol window (impossible for
random text at L=128, routine for adversarial repeats) are ordered with
the new/dirty entries first (the lower-bound insertion lands before equal
clean entries), in true suffix order among themselves — any order inside
such a block satisfies every depth-capped query, so counts and positions
stay exact; only ``first_rank``-order cosmetics may differ from a
from-scratch build on such inputs (see tests/test_compaction.py).

All searches run inside one jitted kernel with power-of-two padded
shapes, so repeated compactions specialize O(log) times, not once per
delta size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.api.runs import bucket_rows as _pow2   # one padding policy:
from repro.core import codec                       # shared jit buckets
from repro.core import query as Q
from repro.core.suffix_array import build_suffix_array


def _search_body(compare_lt, clean_pad, n_clean, patt, plen):
    """First index in [0, n_clean] whose clean suffix is NOT < the query
    window — lower-bound insertion, vectorized over the query batch.
    ``n_clean`` is dynamic (clean_pad is power-of-two padded), so the loop
    runs ceil(log2(len(clean_pad)+1)) steps with a dynamic ``hi``."""
    M = clean_pad.shape[0]
    steps = max(1, int(np.ceil(np.log2(M + 1))))
    B = patt.shape[0]
    lo = jnp.zeros((B,), jnp.int32)
    hi = jnp.broadcast_to(n_clean.astype(jnp.int32), (B,))

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        pos = jnp.take(clean_pad, jnp.clip(mid, 0, M - 1))
        lt = compare_lt(pos)
        active = lo < hi
        lo = jnp.where(active & lt, mid + 1, lo)
        hi = jnp.where(active & ~lt, mid, hi)
        return lo, hi

    lo, _ = lax.fori_loop(0, steps, body, (lo, hi))
    return lo


@jax.jit
def _insertions_packed(clean_pad, n_clean, packed, n_real, patt, plen):
    """DNA path: depth-L windows as packed uint32 words, word compare."""
    return _search_body(
        lambda pos: Q.compare_packed(packed, n_real, pos, patt, plen)[0],
        clean_pad, n_clean, patt, plen)


@jax.jit
def _insertions_codes(clean_pad, n_clean, codes, n_real, patt, plen):
    """Token path: depth-L windows as int32 code rows."""
    return _search_body(
        lambda pos: Q.compare_codes(codes, n_real, pos, patt, plen)[0],
        clean_pad, n_clean, patt, plen)


def merge_delta_sa(combined: np.ndarray, n0: int, base_sa_real: np.ndarray,
                   *, is_dna: bool, max_query_len: int) -> np.ndarray:
    """Real-row suffix array of ``combined`` (= old text of length ``n0``
    plus the appended delta), merged from ``base_sa_real`` instead of
    rebuilt.  Falls back to the full builder when the base is smaller
    than one compare window (nothing clean to keep)."""
    combined = np.asarray(combined)
    n1 = int(combined.shape[0])
    n0 = int(n0)
    d = n1 - n0
    L = int(max_query_len)
    if d <= 0:
        return np.asarray(base_sa_real, np.int32)
    if n0 <= L:
        return np.asarray(build_suffix_array(combined.astype(np.int32)))

    base_sa_real = np.asarray(base_sa_real, np.int32)
    if base_sa_real.shape[0] != n0:
        raise ValueError(f"base SA has {base_sa_real.shape[0]} rows for "
                         f"{n0} base symbols")
    cut = n0 - L                           # clean suffixes: start <= cut
    clean = base_sa_real[base_sa_real <= cut]            # (n0 - L + 1,)

    # 1) dirty-range doubling: suffixes starting in [cut+1, n1) all run to
    # the text end, so the tail's SA is their true mutual order.
    tail = combined[cut + 1:]
    sa_tail = np.asarray(build_suffix_array(tail.astype(np.int32)))
    new_pos = sa_tail.astype(np.int64) + (cut + 1)       # (d + L - 1,)
    B = int(new_pos.shape[0])
    plen = np.minimum(L, n1 - new_pos).astype(np.int32)

    # 2) batched lower-bound merge, shapes power-of-two padded so the
    # jitted search recompiles O(log) times across compactions.
    Bp = _pow2(B)
    pos_p = np.concatenate(
        [new_pos, np.zeros(Bp - B, np.int64)]).astype(np.int32)
    plen_p = np.concatenate([plen, np.ones(Bp - B, np.int32)])
    Mc = int(clean.shape[0])
    clean_pad = np.concatenate(
        [clean, np.zeros(_pow2(Mc) - Mc, np.int32)])
    n_clean = jnp.asarray(Mc, jnp.int32)

    if is_dna:
        W = codec.packed_length(L)
        packed = np.asarray(codec.pack_2bit(combined))
        packed = np.concatenate(
            [packed, np.zeros(_pow2(packed.shape[0]) - packed.shape[0],
                              np.uint32)])
        patt = codec.extract_window(jnp.asarray(packed),
                                    jnp.asarray(pos_p), W)
        ins = _insertions_packed(jnp.asarray(clean_pad), n_clean,
                                 jnp.asarray(packed),
                                 jnp.asarray(n1, jnp.int32),
                                 patt, jnp.asarray(plen_p))
    else:
        codes32 = combined.astype(np.int32)
        codes_pad = np.concatenate(
            [codes32, np.full(_pow2(n1) - n1, -1, np.int32)])
        offs = np.arange(L, dtype=np.int64)
        idx = pos_p.astype(np.int64)[:, None] + offs[None, :]
        patt = np.where(idx < n1, codes_pad[np.clip(idx, 0, n1 - 1)], -1)
        ins = _insertions_codes(jnp.asarray(clean_pad), n_clean,
                                jnp.asarray(codes_pad),
                                jnp.asarray(n1, jnp.int32),
                                jnp.asarray(patt),
                                jnp.asarray(plen_p))

    ins = np.asarray(ins)[:B]
    # np.insert places values before clean[ins[k]], preserving the given
    # (true suffix) order among entries that share an insertion point.
    return np.insert(clean, ins, new_pos.astype(np.int32))

"""``Catalog`` — Accumulo's METADATA table, scaled down to one root dir.

A catalog manages multiple named :class:`~repro.api.table.SuffixTable`\\ s
(a DNA chromosome next to a token corpus) in a single root directory:

    root/
      catalog.json                 # {"tables": {name: {is_dna, ...}}}
      <name>/                      # one dir per table (CheckpointManager)
        step_0000000001/           #   atomic versioned snapshots
          arrays.npz  meta.json    #   codes + sa_real + mem_codes
        step_0000000002/ ...
        wal/wal.log                #   the table's live commit-log segment
        fm/step_.../               #   frozen-tier FM-index artifact
                                   #   (repro.api.fm, docs/storage_tiers.md)

``catalog.json`` is rewritten atomically (tmp + ``os.replace``) so a
preempted create/drop never corrupts the listing.  Commit logs
(``repro.api.wal``) live under the catalog root INSIDE each table's
directory, so ``drop_table`` and the crashed-create reconcile in
``SuffixTable.create`` remove a table's log together with its
snapshots — an orphan log can never be replayed into a different
table that later reuses the name.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Optional

from repro.api.table import SuffixTable, _check_name, default_root

_STEP_RE = re.compile(r"step_(\d+)")


def _has_snapshot(table_dir: str) -> bool:
    """True iff ``table_dir`` holds at least one PUBLISHED snapshot (a
    ``step_*`` dir with its meta.json — the same test as
    ``CheckpointManager.all_steps``, without the ctor's mkdir)."""
    if not os.path.isdir(table_dir):
        return False
    for entry in os.listdir(table_dir):
        if _STEP_RE.fullmatch(entry) and os.path.exists(
                os.path.join(table_dir, entry, "meta.json")):
            return True
    return False


def _is_table_remnant(table_dir: str) -> bool:
    """True iff every entry of ``table_dir`` is table machinery — step
    dirs (published or ``.tmp`` partial streams), ``wal/``, ``fm/``, the
    serving plane's ``tablets/`` map and ``metrics.jsonl`` feed.  The
    guard that keeps reconcile from deleting an unrelated directory (a
    user's spill dir, say) that merely lives under the catalog root."""
    for entry in os.listdir(table_dir):
        if entry in ("wal", "fm", "tablets", "metrics.jsonl"):
            continue
        if _STEP_RE.fullmatch(entry.removesuffix(".tmp")):
            continue
        return False
    return True


def table_wal_dir(root: str, name: str) -> str:
    """Directory holding ``name``'s commit-log segments under ``root``
    (the single place the WAL path layout is decided)."""
    return os.path.join(root, name, "wal")


def table_fm_dir(root: str, name: str) -> str:
    """Directory holding ``name``'s frozen-tier FM-index artifact (the
    single place the fm/ path layout is decided — ``drop_table`` and the
    crashed-create reconcile remove it with the table dir)."""
    return os.path.join(root, name, "fm")


def table_tablets_dir(root: str, name: str) -> str:
    """Directory holding ``name``'s serving-plane METADATA — the tablet
    ``manifest.json`` written by ``repro.serving.plane.split_table`` and
    the live ``serving.json`` endpoints (docs/serving_plane.md).  Like
    wal/ and fm/, it rides inside the table directory so drop/reconcile
    remove the tablet map together with the table."""
    return os.path.join(root, name, "tablets")


class Catalog:
    """Named-table registry over one root directory."""

    def __init__(self, root: Optional[str] = None, *,
                 reconcile: bool = True):
        self.root = root or default_root()
        os.makedirs(self.root, exist_ok=True)
        if reconcile:
            self.reconcile()

    def reconcile(self) -> list[str]:
        """Garbage-collect crashed-create remnants; returns the names
        removed.  Three cases (docs/build_pipeline.md, "Crash safety"):

        * a REGISTERED table with no published snapshot — a create
          (including the staged shard-streaming path) died between
          ``register`` and the atomic publish: its entry and directory
          (holding at most a ``step_*.tmp`` partial stream, a wal/, an
          empty fm/) are removed;
        * an UNREGISTERED directory with no published snapshot whose
          contents are all table machinery (step dirs / .tmp stages /
          wal/ / fm/) — a pre-register crash: removed.  A directory
          holding anything else is NOT touched — it is the user's, not a
          remnant;
        * a stale ``step_*.tmp`` staging dir inside an otherwise healthy
          table — a crashed re-publish (flush/compact): just the .tmp is
          removed, the table survives.

        Directories with a published snapshot but no catalog entry (a
        crashed ``drop_table``) are left for ``drop_table`` to finish —
        they hold real data, so an open-time GC must not guess."""
        removed: list[str] = []
        data = self.load()
        dirty = False
        for name in list(data["tables"]):
            table_dir = os.path.join(self.root, name)
            if not _has_snapshot(table_dir):
                shutil.rmtree(table_dir, ignore_errors=True)
                del data["tables"][name]
                dirty = True
                removed.append(name)
        if dirty:
            self._write(data)
        for entry in os.listdir(self.root):
            path = os.path.join(self.root, entry)
            if not os.path.isdir(path):
                continue
            if (entry in data["tables"] or _has_snapshot(path)
                    or not _is_table_remnant(path)):
                # healthy (or data-bearing orphan, or not ours at all):
                # drop only stale .tmp stages left by a crashed republish
                for sub in os.listdir(path):
                    if sub.endswith(".tmp") and \
                            _STEP_RE.fullmatch(sub.removesuffix(".tmp")):
                        shutil.rmtree(os.path.join(path, sub),
                                      ignore_errors=True)
                continue
            shutil.rmtree(path, ignore_errors=True)
            removed.append(entry)
        return removed

    # -- the metadata file ---------------------------------------------------
    @property
    def path(self) -> str:
        return os.path.join(self.root, "catalog.json")

    def load(self) -> dict:
        if not os.path.exists(self.path):
            return {"tables": {}}
        with open(self.path) as f:
            data = json.load(f)
        data.setdefault("tables", {})
        return data

    def _write(self, data: dict) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".catalog.tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)            # atomic publish

    def register(self, name: str, meta: dict) -> None:
        data = self.load()
        data["tables"][name] = dict(meta)
        self._write(data)

    # -- queries -------------------------------------------------------------
    def list_tables(self) -> list[str]:
        return sorted(self.load()["tables"])

    def table_meta(self, name: str) -> dict:
        tables = self.load()["tables"]
        if name not in tables:
            raise KeyError(f"no table {name!r} in catalog {self.root!r}")
        return tables[name]

    def __contains__(self, name: str) -> bool:
        return name in self.load()["tables"]

    def wal_dir(self, name: str) -> str:
        """Where ``name``'s commit log lives (``repro.api.wal``)."""
        return table_wal_dir(self.root, name)

    def fm_dir(self, name: str) -> str:
        """Where ``name``'s frozen FM-index artifact lives
        (``repro.api.fm``)."""
        return table_fm_dir(self.root, name)

    def tablets_dir(self, name: str) -> str:
        """Where ``name``'s serving-plane tablet map lives
        (``repro.serving.plane``)."""
        return table_tablets_dir(self.root, name)

    # -- table lifecycle -----------------------------------------------------
    def create_table(self, name: str, codes, **kw) -> SuffixTable:
        return SuffixTable.create(name, codes, root=self.root, **kw)

    def open_table(self, name: str, **kw) -> SuffixTable:
        return SuffixTable.open(name, root=self.root, **kw)

    def drop_table(self, name: str, *, missing_ok: bool = False) -> None:
        """Unregister ``name`` and delete its on-disk state — snapshots,
        commit log, and every per-table auxiliary artifact dir (wal/,
        fm/) under the table directory.

        An UNREGISTERED name whose directory still exists is a crashed
        create/drop remnant: its orphan dir (which can hold a frozen
        FM-index or a stale log, not just snapshots) is removed too,
        instead of leaking forever behind the KeyError.  The name is
        validated before any rmtree so a crafted name can never escape
        the root."""
        _check_name(name)
        data = self.load()
        table_dir = os.path.join(self.root, name)
        if name not in data["tables"]:
            if os.path.isdir(table_dir):      # orphan-dir reconcile
                shutil.rmtree(table_dir, ignore_errors=True)
                return
            if missing_ok:
                return
            raise KeyError(f"no table {name!r} in catalog {self.root!r}")
        del data["tables"][name]
        self._write(data)
        shutil.rmtree(table_dir, ignore_errors=True)

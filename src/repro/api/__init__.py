"""repro.api — the table-level public API of the suffix-array store.

Storage side: ``SuffixTable`` (create/open/scan/append/compact) builds,
persists, and queries suffix-array tables; ``Catalog`` manages multiple
named tables in one root directory.  Client side (the Bigtable-style
frontend, docs/client_api.md): ``Database`` routes typed ``Query``
requests by table name, coalesces concurrent callers through a
``QueryScheduler``, and streams huge enumerations in pages via
``ReadSession``.  See docs/table_api.md and docs/client_api.md.

Exports resolve lazily (PEP 562): importing a light submodule such as
``repro.api.wal`` does NOT drag in the jax-backed table machinery.  The
serving plane's tablet workers (``repro.serving.tablet_server``) depend
on this — they replay WAL segments and snapshot slices with numpy only,
so a worker process starts in milliseconds instead of paying a full jax
import per tablet replica.
"""
import importlib

_EXPORTS = {
    "Catalog": "repro.api.catalog",
    "Database": "repro.api.client",
    "Page": "repro.api.client",
    "Query": "repro.api.client",
    "QueryFuture": "repro.api.client",
    "QueryResult": "repro.api.client",
    "QueryScheduler": "repro.api.client",
    "ReadSession": "repro.api.client",
    "FMIndex": "repro.api.fm",
    "Memtable": "repro.api.memtable",
    "Run": "repro.api.runs",
    "SuffixTable": "repro.api.table",
    "default_root": "repro.api.table",
    "open_table": "repro.api.table",
    "RecoverySummary": "repro.api.wal",
    "WriteAheadLog": "repro.api.wal",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value        # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

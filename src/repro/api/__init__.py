"""repro.api — the table-level public API of the suffix-array store.

``SuffixTable`` (create/open/scan/append/compact) is the single entry
point for building, persisting, and querying suffix-array tables;
``Catalog`` manages multiple named tables in one root directory.
See docs/table_api.md.
"""
from repro.api.catalog import Catalog
from repro.api.memtable import Memtable
from repro.api.runs import Run
from repro.api.table import SuffixTable, default_root, open_table

__all__ = ["Catalog", "Memtable", "Run", "SuffixTable", "default_root",
           "open_table"]

"""repro.api — the table-level public API of the suffix-array store.

Storage side: ``SuffixTable`` (create/open/scan/append/compact) builds,
persists, and queries suffix-array tables; ``Catalog`` manages multiple
named tables in one root directory.  Client side (the Bigtable-style
frontend, docs/client_api.md): ``Database`` routes typed ``Query``
requests by table name, coalesces concurrent callers through a
``QueryScheduler``, and streams huge enumerations in pages via
``ReadSession``.  See docs/table_api.md and docs/client_api.md.
"""
from repro.api.catalog import Catalog
from repro.api.client import Database, Page, Query, QueryFuture, \
    QueryResult, QueryScheduler, ReadSession
from repro.api.fm import FMIndex
from repro.api.memtable import Memtable
from repro.api.runs import Run
from repro.api.table import SuffixTable, default_root, open_table
from repro.api.wal import RecoverySummary, WriteAheadLog

__all__ = ["Catalog", "Database", "FMIndex", "Memtable", "Page", "Query",
           "QueryFuture", "QueryResult", "QueryScheduler", "ReadSession",
           "RecoverySummary", "Run", "SuffixTable", "WriteAheadLog",
           "default_root", "open_table"]

"""Immutable LSM runs — the middle tier of the ``SuffixTable`` write path.

Bigtable/Accumulo never let the memtable grow unboundedly: a *minor
compaction* seals it into an immutable on-disk run, and reads fan out over
base + runs + memtable until a *major compaction* folds the runs back into
the base.  :class:`Run` is that sealed memtable for a suffix-array table:
the frozen suffix index a :class:`~repro.api.memtable.Memtable` had built
over ``tail + appended`` (the overlap window plus this run's codes), now
immutable, queryable, and persisted alongside the base snapshot.

Tier layout, with ``start_i`` the logical text length when run *i* was
sealed (``end_i = start_i + len(codes_i)``)::

    base [0, n_base) | run 0 [start_0, end_0) | run 1 ... | memtable

Every occurrence of a pattern ends in exactly one tier, which gives the
exact merge rule (the per-run generalization of the memtable's
``g + plen > n_base`` straddle rule, docs/table_api.md):

* the base reports occurrences with ``g + plen <= n_base``;
* run *i* reports occurrences with ``start_i < g + plen <= end_i`` —
  straddling into, or entirely inside, this run's appended codes;
* the memtable reports occurrences ending past the last run.

No occurrence ending inside run *i* can start before ``start_i -
(max_query_len - 1)``, the left edge of its overlap window, so each run's
small index sees everything it must report.

Durability: a run becomes durable the moment the seal's snapshot publish
lands (``SuffixTable.minor_compact`` re-persists), at which point the
commit log (:mod:`repro.api.wal`) that was covering those appends is
truncated — the log only ever protects the *active* memtable, never
sealed runs or the base.

Run stores share the memtable's *bucket-padded* text layout: the text is
padded to a power-of-two length with symbol 0, so the jitted query
specializes on O(log) distinct shapes instead of one per run, and the
two-sided position filter above makes the padding inert (any match using
pad symbols ends past ``end_i``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.tablet import (TabletStore, TierStack, build_tablet_store,
                               stack_tier_stores)

# One jitted query shared by every run and memtable generation: jax.jit
# caches per (store shape/meta, batch shape), so equally-sized runs and
# successive memtables reuse compilations instead of re-jitting per object.
_shared_query = jax.jit(Q.query)


def bucket_rows(n: int) -> int:
    """Next power of two >= n (floor 16) — text padding for run/memtable
    stores, bounding jit specializations to O(log appends)."""
    return 1 << max(4, (max(n, 1) - 1).bit_length())


def padded_segment_store(text: np.ndarray, *, is_dna: bool,
                         max_query_len: int) -> TabletStore:
    """Single-device store over ``text`` padded to a power-of-two length
    with symbol 0.  The pad symbols are REAL to the store (they keep
    ``n_real`` — a static jit field — stable across appends); callers must
    filter out any occurrence that overlaps them, which the two-sided
    ``lo < g + plen <= hi`` rule does for free."""
    n = int(text.shape[0])
    padded = np.pad(text, (0, bucket_rows(n) - n))
    return build_tablet_store(padded, is_dna=is_dna,
                              max_query_len=max_query_len)


def positions_in_bounds(store: TabletStore, sa_host: np.ndarray,
                        patt, plen, *, offset: int, lo: int, hi: int,
                        n_real: Optional[int] = None) -> list[np.ndarray]:
    """Query ``store`` and return, per query, the ascending GLOBAL start
    positions of occurrences with ``lo < g + plen <= hi`` (the tier's
    exact contribution).  ``offset`` maps local store rows to global text
    positions.  ``n_real`` marks the trailing rows as the client's
    shape-bucketing padding: they still ride the jitted query (keeping
    the compilation bucketed) but skip the host-side gather/filter, and
    only ``n_real`` lists are returned."""
    plen_np = np.asarray(plen)
    B = int(plen_np.shape[0])
    if n_real is not None:
        B = min(B, int(n_real))
    empty = np.zeros((0,), np.int64)
    if B == 0:
        return []
    res = _shared_query(store, jnp.asarray(patt), jnp.asarray(plen))
    count = np.asarray(res.count)
    rank = np.asarray(res.first_rank)
    pad = store.pad_count
    out = []
    for i in range(B):
        c = int(count[i])
        if c <= 0 or rank[i] < 0:
            out.append(empty)
            continue
        lb = pad + int(rank[i])
        g = sa_host[lb:lb + c].astype(np.int64) + offset
        e = g + int(plen_np[i])
        g = g[(e > lo) & (e <= hi)]
        g.sort()
        out.append(g)
    return out


def logical_tail(segments: list[np.ndarray], k: int) -> np.ndarray:
    """Last ``k`` symbols of ``concatenate(segments)`` without
    materializing the concatenation (the overlap window of the next
    memtable after a seal)."""
    if k <= 0:
        return np.zeros((0,), segments[0].dtype if segments else np.uint8)
    parts: list[np.ndarray] = []
    need = k
    for seg in reversed(segments):
        if need <= 0:
            break
        seg = np.asarray(seg)
        take = seg[max(0, seg.shape[0] - need):]
        if take.size:
            parts.append(take)
            need -= int(take.shape[0])
    parts.reverse()
    if not parts:
        return np.zeros((0,), segments[0].dtype if segments else np.uint8)
    return np.ascontiguousarray(np.concatenate(parts))


class Run:
    """One immutable, persisted LSM run: a sealed memtable.

    ``tail`` is the overlap window (the last ``max_query_len - 1`` symbols
    of the logical text before ``start``), ``codes`` this run's appended
    symbols.  The suffix index over ``tail + codes`` is taken frozen from
    the sealing memtable when available, rebuilt lazily otherwise (the
    restore path persists it, so ``open`` never rebuilds).
    """

    def __init__(self, tail: np.ndarray, codes: np.ndarray, *, start: int,
                 is_dna: bool, max_query_len: int,
                 store: Optional[TabletStore] = None,
                 sa_host: Optional[np.ndarray] = None):
        self.tail = np.ascontiguousarray(tail)
        self.codes = np.ascontiguousarray(codes)
        self.start = int(start)
        self.length = int(self.codes.shape[0])
        self.is_dna = bool(is_dna)
        self.max_query_len = int(max_query_len)
        self.overlap = int(self.tail.shape[0])
        self._store = store
        self._sa_host = (np.asarray(sa_host) if sa_host is not None
                         else None)

    @property
    def end(self) -> int:
        return self.start + self.length

    @classmethod
    def from_memtable(cls, mem) -> "Run":
        """Seal a memtable: freeze its codes, window, and (if already
        built) its store — minor compaction's only real work."""
        mem._ensure_store()                   # seal an index, not raw codes
        return cls(mem._tail, mem.appended.copy(), start=mem.n_base,
                   is_dna=mem.is_dna, max_query_len=mem.max_query_len,
                   store=mem._store, sa_host=mem._sa_host)

    def _ensure_store(self) -> TabletStore:
        if self._store is None:
            text = np.concatenate([self.tail, self.codes])
            self._store = padded_segment_store(
                text, is_dna=self.is_dna, max_query_len=self.max_query_len)
            self._sa_host = np.asarray(self._store.sa)
        return self._store

    @property
    def sa_padded(self) -> np.ndarray:
        """The run's full suffix array over its padded text (persisted so
        ``open`` restores the index instead of rebuilding it)."""
        self._ensure_store()
        return self._sa_host

    @classmethod
    def restore(cls, tail: np.ndarray, codes: np.ndarray, sa_padded, *,
                start: int, is_dna: bool, max_query_len: int) -> "Run":
        """Rebuild a run from persisted arrays (no suffix sort)."""
        run = cls(tail, codes, start=start, is_dna=is_dna,
                  max_query_len=max_query_len)
        if sa_padded is not None:
            from repro.core.tablet import store_from_arrays
            text = np.concatenate([run.tail, run.codes])
            padded = np.pad(text, (0, bucket_rows(int(text.shape[0]))
                                  - int(text.shape[0])))
            run._store = store_from_arrays(
                padded, np.asarray(sa_padded, np.int32), is_dna=is_dna,
                max_query_len=max_query_len)
            run._sa_host = np.asarray(run._store.sa)
        return run

    def match_positions(self, patt, plen,
                        n_real: Optional[int] = None) -> list[np.ndarray]:
        """Global start positions, ascending, of exactly the occurrences
        this run owns: ``start < g + plen <= end``."""
        B = int(np.asarray(plen).shape[0])
        if n_real is not None:
            B = min(B, int(n_real))
        if self.length == 0 or B == 0:
            return [np.zeros((0,), np.int64)] * B
        store = self._ensure_store()
        return positions_in_bounds(store, self._sa_host, patt, plen,
                                   offset=self.start - self.overlap,
                                   lo=self.start, hi=self.end,
                                   n_real=n_real)


class TierSet:
    """All delta tiers of a table as ONE stacked device view plus the
    host-side suffix arrays needed to enumerate matches.

    The old read path dispatched one jitted query per run plus one for
    the memtable, then ran a per-query Python loop per tier to apply the
    straddle bounds (~9x base-only latency with runs live,
    BENCH_compaction.json).  A TierSet feeds the whole set to the fused
    tier scan (:mod:`repro.kernels.tier_scan`) in a single launch; the
    bounds live in the trace, and the host only slices already-located
    SA runs when positions are actually enumerated.

    Instances are immutable snapshots: ``SuffixTable`` rebuilds its
    cached TierSet whenever the tier population changes (append, seal,
    compaction, restore), exactly where it already invalidated the
    per-tier caches.  Tier order is runs (oldest first) then memtable —
    same order the old fan-out scanned, so enumeration output matches
    bit for bit.
    """

    def __init__(self, stores, offsets, bounds, kinds):
        self.stack: TierStack = stack_tier_stores(
            stores, offsets=offsets, bounds=bounds)
        R = self.stack.rows
        self.sa_host = np.zeros((len(stores), R), np.int64)
        for t, s in enumerate(stores):
            self.sa_host[t, :s.n_pad] = np.asarray(s.sa)
        self.offsets = np.asarray(offsets, np.int64)
        self.los = np.asarray([b[0] for b in bounds], np.int64)
        self.his = np.asarray([b[1] for b in bounds], np.int64)
        self.kinds = tuple(kinds)
        self.num_tiers = len(stores)

    @classmethod
    def build(cls, runs, memtable) -> Optional["TierSet"]:
        """Snapshot the live tiers (non-empty runs, then the memtable if
        it has appends).  Returns None when there are no delta tiers —
        the caller dispatches base-only."""
        stores, offsets, bounds, kinds = [], [], [], []
        for r in runs:
            if r.length == 0:
                continue
            stores.append(r._ensure_store())
            offsets.append(r.start - r.overlap)
            bounds.append((r.start, r.end))
            kinds.append("run")
        if memtable is not None and memtable.size > 0:
            stores.append(memtable._ensure_store())
            offsets.append(memtable.n_base - memtable.overlap)
            bounds.append((memtable.n_base,
                           memtable.n_base + memtable.size))
            kinds.append("memtable")
        if not stores:
            return None
        return cls(stores, offsets, bounds, kinds)

    def delta_positions(self, tless, tmatch, plen,
                        n_real: Optional[int] = None) -> list[np.ndarray]:
        """Per query, the ascending GLOBAL positions owned by any delta
        tier, assembled from the fused scan's ``less``/``matches``
        outputs ((T, B) int32) — pure host slicing, no further device
        dispatch.  ``n_real`` trims trailing shape-bucketing pad
        queries."""
        tless = np.asarray(tless)
        tmatch = np.asarray(tmatch)
        plen_np = np.asarray(plen)
        B = int(plen_np.shape[0])
        if n_real is not None:
            B = min(B, int(n_real))
        empty = np.zeros((0,), np.int64)
        out = []
        for i in range(B):
            parts = []
            for t in range(self.num_tiers):
                m = int(tmatch[t, i])
                if m <= 0:
                    continue
                lb = int(tless[t, i])
                g = self.sa_host[t, lb:lb + m] + self.offsets[t]
                e = g + int(plen_np[i])
                g = g[(e > self.los[t]) & (e <= self.his[t])]
                if g.size:
                    parts.append(g)
            if not parts:
                out.append(empty)
                continue
            g = np.concatenate(parts)
            g.sort()
            out.append(g)
        return out

"""Per-table write-ahead commit log — the durability half of the memtable.

Bigtable pairs every memtable with a commit log: a mutation is appended
to the log and fsync'd *before* it is applied to the memtable and acked,
so an acknowledged write survives any crash; recovery replays the log
tail into a fresh memtable.  ``SuffixTable.append`` was volatile until
now (acked appends lived only in the memtable until ``flush`` /
``minor_compact``); :class:`WriteAheadLog` closes that hole:

* every append is encoded as one **CRC-framed record** (``u32 length +
  u32 crc32(payload)`` header, payload = monotone sequence number +
  dtype + raw code bytes) and fsync'd before the ack;
* an optional **group-commit window** batches concurrent writers into
  one fsync — the write-side mirror of the ``QueryScheduler``'s
  read-side coalescing: appends are buffered under a short lock, one
  *leader* sleeps ``group_commit_ms`` and fsyncs for the whole wave,
  then every waiter acks (``benchmarks/wal_bench.py`` measures the
  acked-appends/sec win);
* :meth:`recover` replays a segment on ``SuffixTable.open``: records
  are validated (CRC, framing, strictly increasing sequence) and a
  **torn or corrupt tail is cleanly discarded** — a record is either
  applied whole or not at all, never partially — with the outcome
  reported as a recovery summary (``SuffixTable.stats()["wal"]``);
* :meth:`seal` truncates the segment **via atomic rename** (a fresh
  header-only segment is fsync'd beside the live one, then
  ``os.replace``'d over it) — called only *after* the memtable's
  content has been persisted by a snapshot/run, so there is no moment
  with zero durable copies.  Records carry sequence numbers precisely
  so a crash *between* persist and seal is harmless: replay skips
  records at or below the snapshot's ``wal_seq`` instead of
  double-applying them.

Segment layout (little-endian)::

    header   magic 8s | start_seq u64 | crc32(magic+start_seq) u32
    record   payload_len u32 | crc32(payload) u32 | payload
    payload  seq u64 | dtype 8s | n u64 | data (n * itemsize bytes)

The log lives under the table's directory in the catalog root
(``root/<name>/wal/wal.log`` — see ``Catalog.wal_dir``), so dropping or
reconciling a table removes its log with it.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import threading
import time
import zlib

import numpy as np

MAGIC = b"SAWAL\x00\x01\n"
_HEADER = struct.Struct("<8sQI")           # magic, start_seq, header crc
_FRAME = struct.Struct("<II")              # payload_len, crc32(payload)
_PAYLOAD = struct.Struct("<Q8sQ")          # seq, dtype str, element count
# enforced on BOTH sides: append() refuses to frame a larger record (the
# failure must reach the writer before the ack, not surface as a
# silently-discarded 'bad_frame' on recovery), and read_segment treats a
# frame claiming more as corruption
_MAX_PAYLOAD = 1 << 30

HEADER_SIZE = _HEADER.size


@dataclasses.dataclass
class RecoverySummary:
    """What :meth:`WriteAheadLog.recover` found in a segment.

    ``records_replayed`` / ``records_skipped`` are filled in by the
    table (the log cannot know the snapshot's ``wal_seq``); everything
    else is segment-level: ``torn_bytes`` were discarded past the last
    valid record, ``reason`` says why scanning stopped (``"clean"`` for
    a segment that ends exactly at a record boundary).
    """
    segment_start_seq: int = 0
    records_scanned: int = 0
    records_replayed: int = 0
    records_skipped: int = 0
    valid_bytes: int = 0
    torn_bytes: int = 0
    reason: str = "clean"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def encode_record(seq: int, codes: np.ndarray) -> bytes:
    """One CRC-framed append record (the unit of atomicity on replay)."""
    codes = np.ascontiguousarray(codes)
    dt = codes.dtype.str.encode("ascii")
    if len(dt) > 8:
        raise ValueError(f"dtype tag {dt!r} too long for the WAL frame")
    payload = _PAYLOAD.pack(int(seq), dt.ljust(8, b"\x00"),
                            int(codes.size)) + codes.tobytes()
    if len(payload) > _MAX_PAYLOAD:
        raise ValueError(
            f"append of {codes.size} x {codes.dtype} ({len(payload)} "
            f"bytes) exceeds the WAL record cap ({_MAX_PAYLOAD}); split "
            f"the batch — a larger frame would be unrecoverable")
    return _FRAME.pack(len(payload), zlib.crc32(payload)) + payload


def _decode_payload(payload: bytes) -> tuple[int, np.ndarray]:
    seq, dt, n = _PAYLOAD.unpack_from(payload, 0)
    dtype = np.dtype(dt.rstrip(b"\x00").decode("ascii"))
    data = payload[_PAYLOAD.size:]
    if len(data) != n * dtype.itemsize:
        raise ValueError(f"payload claims {n} x {dtype} but carries "
                         f"{len(data)} bytes")
    return int(seq), np.frombuffer(data, dtype=dtype).copy()


def read_segment(path: str) -> tuple[int, list, RecoverySummary]:
    """Scan a segment file: ``(start_seq, [(seq, codes, end_offset)],
    summary)``.  Scanning stops at the first torn or corrupt frame; every
    returned record passed its CRC and the strict seq monotonicity check.
    Shared by :meth:`WriteAheadLog.recover` and the crash-injection tests
    (which need record boundaries to aim their kills at)."""
    summary = RecoverySummary()
    with open(path, "rb") as f:
        blob = f.read()
    if len(blob) < HEADER_SIZE:
        summary.reason = "missing_header"
        summary.torn_bytes = len(blob)
        return 0, [], summary
    magic, start_seq, hcrc = _HEADER.unpack_from(blob, 0)
    if magic != MAGIC or hcrc != zlib.crc32(blob[:_HEADER.size - 4]):
        summary.reason = "bad_header"
        summary.torn_bytes = len(blob)
        return 0, [], summary
    summary.segment_start_seq = int(start_seq)
    records: list[tuple[int, np.ndarray, int]] = []
    off, last_seq = HEADER_SIZE, int(start_seq) - 1
    while True:
        if off == len(blob):
            break                                       # clean end
        if off + _FRAME.size > len(blob):
            summary.reason = "torn_frame"
            break
        plen, crc = _FRAME.unpack_from(blob, off)
        if plen < _PAYLOAD.size or plen > _MAX_PAYLOAD:
            summary.reason = "bad_frame"
            break
        start, end = off + _FRAME.size, off + _FRAME.size + plen
        if end > len(blob):
            summary.reason = "torn_record"
            break
        payload = blob[start:end]
        if zlib.crc32(payload) != crc:
            summary.reason = "crc_mismatch"
            break
        try:
            seq, codes = _decode_payload(payload)
        except Exception:  # noqa: BLE001 — any malformed payload is torn
            summary.reason = "bad_payload"
            break
        if seq != last_seq + 1:
            # a gap or regression can only come from tampering, never
            # from a torn tail; nothing after it can be trusted
            summary.reason = "seq_gap"
            break
        records.append((seq, codes, end))
        last_seq = seq
        off = end
        summary.records_scanned += 1
    summary.valid_bytes = off
    summary.torn_bytes = len(blob) - off
    return int(start_seq), records, summary


def _fsync_dir(path: str) -> None:
    """fsync the directory so a just-created/renamed entry is durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class WriteAheadLog:
    """One table's commit log: a single live segment, group-commit fsync.

    Thread-safe: :meth:`append` may be called under the table's write
    lock while :meth:`wait` (the durability barrier) is called *outside*
    it, so concurrent clients overlap their fsync waits — that overlap
    is what group commit batches.  Sequence numbers are assigned by the
    caller (the table owns the counter and persists it in snapshots).
    """

    def __init__(self, path: str, *, group_commit_ms: float = 0.0):
        if group_commit_ms < 0:
            raise ValueError(f"group_commit_ms must be >= 0, "
                             f"got {group_commit_ms}")
        self.path = path
        self.group_commit_ms = float(group_commit_ms)
        self._cond = threading.Condition()
        self._file = None                   # set by create()/recover()
        self._last_written_seq = 0          # highest seq buffered
        self._synced_seq = 0                # highest seq durable
        self._leader_active = False
        # counters (surfaced by SuffixTable.stats()["wal"])
        self.appends = 0
        self.fsyncs = 0
        self.acked = 0                      # appends acked via wait()
        self.seals = 0

    # -- segment lifecycle ---------------------------------------------------
    @classmethod
    def create(cls, path: str, *, start_seq: int,
               group_commit_ms: float = 0.0) -> "WriteAheadLog":
        """Start a fresh segment expecting ``start_seq`` as its first
        record (replacing any file already at ``path``)."""
        wal = cls(path, group_commit_ms=group_commit_ms)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        wal._publish_fresh_segment(start_seq)
        wal._last_written_seq = wal._synced_seq = int(start_seq) - 1
        return wal

    def _publish_fresh_segment(self, start_seq: int) -> None:
        """Write a header-only segment beside the live path and atomically
        rename it into place (crash-safe truncation)."""
        tmp = self.path + ".new"
        hdr = MAGIC + struct.pack("<Q", int(start_seq))
        with open(tmp, "wb") as f:
            f.write(hdr + struct.pack("<I", zlib.crc32(hdr)))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        _fsync_dir(os.path.dirname(self.path))
        if self._file is not None:
            self._file.close()
        self._file = open(self.path, "r+b")
        self._file.seek(0, os.SEEK_END)

    def recover(self) -> tuple[list, RecoverySummary]:
        """Scan the live segment, truncate any torn tail in place, and
        open it for appending.  Returns ``([(seq, codes)], summary)``;
        a missing segment recovers as empty (``reason="missing_segment"``,
        a fresh header is published lazily by the first append via
        :meth:`seal`, or eagerly by the caller)."""
        if not os.path.exists(self.path):
            summary = RecoverySummary(reason="missing_segment")
            return [], summary
        start_seq, records, summary = read_segment(self.path)
        self._file = open(self.path, "r+b")
        self._file.truncate(summary.valid_bytes)   # drop the torn tail
        self._file.seek(0, os.SEEK_END)
        if summary.torn_bytes:
            self._file.flush()
            os.fsync(self._file.fileno())
        last = records[-1][0] if records else int(start_seq) - 1
        self._last_written_seq = self._synced_seq = last
        return [(seq, codes) for seq, codes, _ in records], summary

    # -- the write path ------------------------------------------------------
    def append(self, codes: np.ndarray, seq: int) -> int:
        """Buffer one record; returns a durability token for
        :meth:`wait`.  The record is NOT yet on disk — callers must not
        ack until ``wait(token)`` returns.  Must be called with ``seq``
        strictly increasing (the table's mutation lock guarantees it)."""
        if self._file is None:
            raise RuntimeError("WAL has no live segment — use create() "
                               "or recover() first")
        rec = encode_record(seq, codes)
        with self._cond:
            if seq != self._last_written_seq + 1:
                raise ValueError(f"non-contiguous WAL seq {seq} after "
                                 f"{self._last_written_seq}")
            self._file.write(rec)
            self._last_written_seq = int(seq)
            self.appends += 1
        return int(seq)

    def wait(self, token: int) -> None:
        """Block until the record with seq ``token`` is durable (fsync'd
        or covered by a sealed snapshot).  The first waiter of a wave
        becomes the *leader*: it sleeps the group-commit window so later
        writers can join, then fsyncs once for everyone."""
        with self._cond:
            self.acked += 1
            while self._synced_seq < token:
                if not self._leader_active:
                    self._leader_active = True
                    break
                self._cond.wait()
            else:
                return
        # leader: sleep the window OUTSIDE the lock, so writers joining
        # the wave can buffer their records into it meanwhile
        if self.group_commit_ms > 0:
            time.sleep(self.group_commit_ms / 1e3)
        with self._cond:
            try:
                if self._file is not None:
                    self._file.flush()
                    os.fsync(self._file.fileno())
                    self.fsyncs += 1
                # else: close() fsync'd and marked everything synced
                # already.  _synced_seq advances ONLY after a successful
                # fsync — on an fsync error the exception reaches this
                # caller and the other waiters retry leadership, so no
                # writer ever acks a record that missed the disk.
                self._synced_seq = max(self._synced_seq,
                                       self._last_written_seq)
            finally:
                self._leader_active = False
                self._cond.notify_all()

    def append_durable(self, codes: np.ndarray, seq: int) -> None:
        """``append`` + ``wait`` in one call (the single-writer path)."""
        self.wait(self.append(codes, seq))

    # -- truncation ----------------------------------------------------------
    def seal(self, start_seq: int) -> None:
        """Truncate the segment after its content has been persisted by a
        snapshot: publish a fresh header-only segment (expecting
        ``start_seq`` next) over the live one via atomic rename.  Every
        outstanding record is durable by definition — the snapshot holds
        it — so all waiters are released."""
        with self._cond:
            self._publish_fresh_segment(start_seq)
            self._last_written_seq = max(self._last_written_seq,
                                         int(start_seq) - 1)
            self._synced_seq = self._last_written_seq
            self.seals += 1
            self._cond.notify_all()

    def close(self) -> None:
        with self._cond:
            if self._file is not None:
                self._file.flush()
                os.fsync(self._file.fileno())
                self._file.close()
                self._file = None
            self._synced_seq = self._last_written_seq
            self._cond.notify_all()

    def stats(self) -> dict:
        return {"appends": self.appends, "acked": self.acked,
                "fsyncs": self.fsyncs, "seals": self.seals,
                "group_commit_ms": self.group_commit_ms,
                "synced_seq": self._synced_seq}

"""FM-index artifact: build / persist / serve a frozen table's BWT tier.

``FMIndex`` is the host-side owner of one table's compressed index (the
artifact ``SuffixTable.freeze()`` emits): it derives the BWT from the
base suffix array, packs it (2-bit for DNA via the ``pack2bit`` layout),
builds the blocked Occ checkpoints and the sampled-SA structures, and
persists everything through the same ``CheckpointManager`` the table
snapshot uses (atomic publish, versioned, GC'd) — under the table's
``fm/`` directory so ``Catalog`` reconcile and ``drop_table`` manage it
with the rest of the table state.

Bytes per symbol (DNA, defaults SB=64, sample_rate=32):

====================  ================  =======
structure             size              B/sym
====================  ================  =======
packed BWT            n/4 bytes         0.25
Occ checkpoints       4*4*n/64          0.25
sampled SA            4*n/32            0.125
marked bitvector      n/8 + rank words  ~0.16
====================  ================  =======

~0.78 B/sym total vs ~8 B/sym for the live base tier (device SA + host
mirror) — the ~10x footprint win ROADMAP item 2 targets.

Conventions (must match ``kernels.fm_scan`` and the binary-search path):
the index is over ``T$``; ``SA$ = [n] + SA`` because the base builder
orders equal-prefix suffixes shorter-first, which IS the sentinel
order.  The sentinel row (``SA$ == 0``) stores dummy symbol 0 in the
BWT; Occ counts the raw stream and ``rank()`` subtracts the dummy.
"""
from __future__ import annotations

import re
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import codec
from repro.core.suffix_array import build_suffix_array
from repro.kernels import fm_scan
from repro.kernels.fm_scan import SB, WPB, FMArrays

FM_FORMAT = 1
DEFAULT_SAMPLE_RATE = 32
MAX_VOCAB = 64          # token tables above this stay on the live tier


def _named(arrays: dict) -> dict:
    """Strip checkpoint path decoration: ``"['bwt']"`` -> ``"bwt"``."""
    return {re.sub(r"[^0-9A-Za-z_]", "", k): v for k, v in arrays.items()}


if hasattr(np, "bitwise_count"):            # numpy >= 2.0
    def _popcount32(x: np.ndarray) -> np.ndarray:
        return np.bitwise_count(x).astype(np.int64)
else:                                       # byte-LUT fallback
    _POP8 = np.array([bin(i).count("1") for i in range(256)], dtype=np.int64)

    def _popcount32(x: np.ndarray) -> np.ndarray:
        b = np.ascontiguousarray(x, dtype=np.uint32).view(np.uint8)
        return _POP8[b].reshape(*x.shape, 4).sum(axis=-1)


def sa_is_fully_sorted(codes: np.ndarray, sa: np.ndarray) -> bool:
    """True iff ``sa`` is the FULL lexicographic suffix order of ``codes``
    (shorter-suffix-first on ties).  ``merge_delta_sa`` only guarantees
    depth-L order, which is enough for depth-capped scans but NOT for a
    BWT — freeze() checks and falls back to a fresh sort."""
    n = len(codes)
    if len(sa) != n:
        return False
    if n <= 1:
        return n == 0 or sa[0] == 0
    rank = np.empty(n + 1, dtype=np.int64)
    rank[sa] = np.arange(n)
    rank[n] = -1                      # empty suffix sorts first
    a, b = sa[:-1].astype(np.int64), sa[1:].astype(np.int64)
    ca, cb = codes[a].astype(np.int64), codes[b].astype(np.int64)
    ok = (ca < cb) | ((ca == cb) & (rank[a + 1] < rank[b + 1]))
    return bool(np.all(ok)) and bool(np.all(np.sort(sa) == np.arange(n)))


class FMIndex:
    """One table's frozen-tier index.  Host arrays are authoritative;
    the device view (``.arrays``) is materialized lazily."""

    def __init__(self, *, bwt, occ, cc, marked, marked_rank, samples,
                 sent_row: int, n: int, is_dna: bool, sample_rate: int,
                 vocab: int):
        self.bwt = bwt                    # DNA: (Wb,) u32 | tokens: (L,) u8
        self.occ = occ                    # (nblk + 1, vocab) int32
        self.cc = cc                      # (vocab,) int32
        self.marked = marked              # (Wm,) uint32
        self.marked_rank = marked_rank    # (Wm,) int32
        self.samples = samples            # (S,) int32
        self.sent_row = int(sent_row)
        self.n = int(n)
        self.is_dna = bool(is_dna)
        self.sample_rate = int(sample_rate)
        self.vocab = int(vocab)
        self._arrays: Optional[FMArrays] = None

    # ------------------------------------------------------------- build
    @classmethod
    def build(cls, codes: np.ndarray, sa_real: Optional[np.ndarray] = None,
              *, is_dna: bool, sample_rate: int = DEFAULT_SAMPLE_RATE,
              validate: bool = True) -> "FMIndex":
        """Derive the index from text ``codes`` and (optionally) its base
        suffix array.  A non-fully-sorted or missing SA triggers a fresh
        ``build_suffix_array`` — correctness never depends on the LSM
        merge depth."""
        codes = np.asarray(codes, dtype=np.uint8)
        n = len(codes)
        if n == 0:
            raise ValueError("cannot freeze an empty table")
        if sample_rate < 2:
            raise ValueError("sample_rate must be >= 2")
        vocab = 4 if is_dna else int(codes.max()) + 1
        if vocab > MAX_VOCAB:
            raise ValueError(
                f"vocab {vocab} exceeds the frozen tier's cap {MAX_VOCAB}")
        if sa_real is not None:
            sa_real = np.asarray(sa_real, dtype=np.int64)
        if sa_real is None or (validate
                               and not sa_is_fully_sorted(codes, sa_real)):
            sa_real = np.asarray(build_suffix_array(codes), dtype=np.int64)

        rows = n + 1
        sa_dollar = np.empty(rows, dtype=np.int64)
        sa_dollar[0] = n                    # the $-only suffix
        sa_dollar[1:] = sa_real
        prev = sa_dollar - 1
        sent_row = int(np.nonzero(sa_dollar == 0)[0][0])
        bwt_codes = codes[np.where(prev >= 0, prev, 0)].copy()
        bwt_codes[sent_row] = 0             # dummy symbol for $

        # C$[c] = 1 + #{symbols in T < c}  (the +1 is the sentinel)
        counts = np.bincount(codes, minlength=vocab).astype(np.int64)
        cc = (1 + np.concatenate(([0], np.cumsum(counts)[:-1]))).astype(
            np.int32)

        nblk = -(-rows // SB)
        if is_dna:
            packed = codec.pack_2bit_batch(bwt_codes[None, :])[0]
            pad_w = nblk * WPB - len(packed)
            if pad_w:
                packed = np.pad(packed, (0, pad_w))
            # Occ from the PACKED words (what rank() reads), not the raw
            # codes — guarantees checkpoint/popcount agreement by design.
            blocks = codec.unpack_2bit_batch(packed.reshape(nblk, WPB), SB)
            blocks = blocks.astype(np.int16)
            tail = np.arange(nblk * SB).reshape(nblk, SB) >= rows
            blocks[tail] = -1               # pad slots count as nothing
            bwt_store = packed
        else:
            padded = np.full(nblk * SB, -1, dtype=np.int16)
            padded[:rows] = bwt_codes
            blocks = padded.reshape(nblk, SB)
            bwt_store = bwt_codes
        per_blk = np.stack(
            [(blocks == c).sum(axis=1) for c in range(vocab)], axis=1)
        occ = np.zeros((nblk + 1, vocab), dtype=np.int32)
        occ[1:] = np.cumsum(per_blk, axis=0)

        # sampled SA: mark rows whose TEXT position is ≡ 0 (mod k); the
        # p == 0 row is always marked, so every LF walk terminates.
        mark = (sa_dollar % sample_rate) == 0
        wm = -(-rows // 32)
        bits = np.zeros(wm * 32, dtype=np.uint32)
        bits[:rows] = mark
        words = bits.reshape(wm, 32)
        marked = (words << np.arange(32, dtype=np.uint32)).sum(
            axis=1, dtype=np.uint32)
        per_word = words.sum(axis=1, dtype=np.int64)
        marked_rank = np.concatenate(
            ([0], np.cumsum(per_word)[:-1])).astype(np.int32)
        samples = sa_dollar[mark].astype(np.int32)

        return cls(bwt=bwt_store, occ=occ, cc=cc, marked=marked,
                   marked_rank=marked_rank, samples=samples,
                   sent_row=sent_row, n=n, is_dna=is_dna,
                   sample_rate=sample_rate, vocab=vocab)

    # ------------------------------------------------------- device view
    @property
    def arrays(self) -> FMArrays:
        if self._arrays is None:
            bwt = (jnp.asarray(self.bwt, jnp.uint32) if self.is_dna
                   else jnp.asarray(self.bwt, jnp.int32))
            self._arrays = FMArrays(
                bwt=bwt,
                occ=jnp.asarray(self.occ, jnp.int32),
                cc=jnp.asarray(self.cc, jnp.int32),
                marked=jnp.asarray(self.marked, jnp.uint32),
                marked_rank=jnp.asarray(self.marked_rank, jnp.int32),
                samples=jnp.asarray(self.samples, jnp.int32),
                sent_row=jnp.int32(self.sent_row),
                n=jnp.int32(self.n),
                is_dna=self.is_dna,
                sample_rate=self.sample_rate,
                vocab=self.vocab)
        return self._arrays

    # --------------------------------------------------------- host rank
    def _rank_host(self, c: np.ndarray, i: np.ndarray) -> np.ndarray:
        """Occ(c, i) vectorized on the host — locate walks and the
        frozen-compaction SA reconstruction run here, off-device."""
        c = np.asarray(c, dtype=np.int64)
        i = np.asarray(i, dtype=np.int64)
        blk = i // SB
        base = self.occ[blk, c].astype(np.int64)
        rem = i - blk * SB
        if self.is_dna:
            idx = blk[:, None] * WPB + np.arange(WPB)
            w = self.bwt[np.clip(idx, 0, len(self.bwt) - 1)]
            v = np.clip(rem[:, None] - 16 * np.arange(WPB), 0, 16)
            x = w ^ (c[:, None].astype(np.uint32) * np.uint32(0x55555555))
            y = (~x) & ((~x) >> np.uint32(1)) & np.uint32(0x55555555)
            sh = (2 * (16 - np.clip(v, 1, 16))).astype(np.uint32)
            keep = np.where(v > 0,
                            np.uint32(0x55555555) << sh, np.uint32(0))
            cnt = _popcount32(y & keep).sum(axis=1)
        else:
            offs = np.arange(SB)
            idx = blk[:, None] * SB + offs
            vals = self.bwt[np.clip(idx, 0, len(self.bwt) - 1)]
            cnt = ((vals == c[:, None]) & (offs < rem[:, None])).sum(axis=1)
        return base + cnt - ((c == 0) & (self.sent_row < i)).astype(np.int64)

    def _bwt_sym_host(self, r: np.ndarray) -> np.ndarray:
        r = np.asarray(r, dtype=np.int64)
        if self.is_dna:
            w = self.bwt[r // 16]
            return ((w >> (30 - 2 * (r % 16)).astype(np.uint32)) & 3).astype(
                np.int64)
        return self.bwt[r].astype(np.int64)

    def ranks_to_positions(self, rows: np.ndarray) -> np.ndarray:
        """SA$[row] for a batch of rows, via LF walks to the nearest
        sampled position (≤ ``sample_rate`` steps each, all host numpy)."""
        r = np.asarray(rows, dtype=np.int64).copy()
        pos = np.full(r.shape, -1, dtype=np.int64)
        steps = np.zeros(r.shape, dtype=np.int64)
        done = np.zeros(r.shape, dtype=bool)
        cc = self.cc.astype(np.int64)
        for _ in range(self.sample_rate + 1):
            w = self.marked[r // 32]
            hit = (((w >> (r % 32).astype(np.uint32)) & 1) != 0) & ~done
            if hit.any():
                rh = r[hit]
                wlow = self.marked[rh // 32] & (
                    (np.uint32(1) << (rh % 32).astype(np.uint32))
                    - np.uint32(1))
                si = (self.marked_rank[rh // 32].astype(np.int64)
                      + _popcount32(wlow))
                pos[hit] = self.samples[si].astype(np.int64) + steps[hit]
                done |= hit
            act = ~done
            if not act.any():
                break
            s = self._bwt_sym_host(r[act])
            r[act] = cc[s] + self._rank_host(s, r[act])
            steps[act] += 1
        return pos

    def suffix_array(self) -> np.ndarray:
        """Reconstruct the full real SA (rows 1..n of SA$) — frozen-table
        compaction rebuilds its merge input from this instead of keeping
        an 8 B/sym live copy around."""
        return self.ranks_to_positions(np.arange(1, self.n + 1))

    def count(self, patt, plen):
        """(lo, hi) -> host (count, first_rank) for an encoded batch —
        convenience used by tests and benches.  ``first_rank`` follows
        the planner contract: the real-SA lower bound when found, -1
        otherwise."""
        lo, hi = fm_scan.backward_search(self.arrays, jnp.asarray(patt),
                                         jnp.asarray(plen, jnp.int32))
        lo = np.asarray(lo, dtype=np.int64)
        hi = np.asarray(hi, dtype=np.int64)
        return hi - lo, np.where(hi > lo, lo - 1, -1)

    # ------------------------------------------------------- persistence
    def state_dict(self) -> dict:
        return {"bwt": np.asarray(self.bwt), "occ": self.occ,
                "cc": self.cc, "marked": self.marked,
                "marked_rank": self.marked_rank, "samples": self.samples}

    def extra_dict(self) -> dict:
        return {"kind": "fm_index", "format": FM_FORMAT, "n": self.n,
                "sample_rate": self.sample_rate, "sb": SB,
                "is_dna": self.is_dna, "vocab": self.vocab,
                "sent_row": self.sent_row}

    def save(self, directory: str, version: int) -> str:
        mgr = CheckpointManager(directory, keep_n=2)
        return mgr.save(version, self.state_dict(), extra=self.extra_dict())

    @classmethod
    def load(cls, directory: str) -> Optional["FMIndex"]:
        """Latest persisted artifact in ``directory``, or None when the
        dir is absent/empty or from an incompatible format — callers
        rebuild from codes in that case."""
        mgr = CheckpointManager(directory, keep_n=2)
        step = mgr.latest_step()
        if step is None:
            return None
        arrays, extra = mgr.restore_arrays(step)
        if extra.get("kind") != "fm_index" or extra.get("sb") != SB \
                or extra.get("format") != FM_FORMAT:
            return None
        a = _named(arrays)
        is_dna = bool(extra["is_dna"])
        return cls(bwt=a["bwt"].astype(np.uint32 if is_dna else np.uint8),
                   occ=a["occ"].astype(np.int32),
                   cc=a["cc"].astype(np.int32),
                   marked=a["marked"].astype(np.uint32),
                   marked_rank=a["marked_rank"].astype(np.int32),
                   samples=a["samples"].astype(np.int32),
                   sent_row=int(extra["sent_row"]), n=int(extra["n"]),
                   is_dna=is_dna, sample_rate=int(extra["sample_rate"]),
                   vocab=int(extra["vocab"]))

    # ------------------------------------------------------------- stats
    def resident_bytes(self) -> int:
        """Index bytes (host copy == device copy sizes)."""
        return int(np.asarray(self.bwt).nbytes + self.occ.nbytes
                   + self.cc.nbytes + self.marked.nbytes
                   + self.marked_rank.nbytes + self.samples.nbytes)

"""``SuffixTable`` — the Bigtable-style table facade over the whole store.

The paper's deliverable is not a function but a *table*: a durable, named
suffix index you open, scan, and mutate (Accumulo gives Randazzo & Rombo
and Wu et al. the same thing).  This module is that single public entry
point; callers no longer hand-wire ``build_tablet_store`` + ``ScanPlanner``
+ mesh plumbing:

* :meth:`SuffixTable.create` builds the suffix array (distributed over the
  local mesh when more than one device is visible) and persists it through
  ``CheckpointManager``-style atomic versioned files;
* :meth:`SuffixTable.open` restores a table on **any** device count — the
  persisted real-row suffix array is re-padded for the local tablet count
  and the right mesh/planner are constructed internally;
* reads (:meth:`count` / :meth:`contains` / :meth:`scan` / :meth:`locate`)
  delegate to the :class:`~repro.core.planner.ScanPlanner` for the base
  index and merge in the LSM delta tiers (below);
* the write path is a real LSM stack **paired with a commit log**
  (:mod:`repro.api.wal`, Bigtable's memtable+log discipline): every
  :meth:`append` on a persistent table is CRC-framed, fsync'd, and only
  then acked, so acknowledged writes survive crashes — :meth:`open`
  replays the live log tail through the normal memtable path and
  reports a recovery summary in :meth:`stats`; :meth:`append` lands
  codes in a single-device :class:`~repro.api.memtable.Memtable`;
  :meth:`minor_compact` seals the memtable into an immutable, persisted
  :class:`~repro.api.runs.Run` (automatic at ``memtable_limit``); reads
  fan out to base + runs + memtable and merge exact counts and positions,
  each tier owning the occurrences that END in its region (the per-run
  generalization of the ``g + plen > n_base`` straddle rule —
  docs/table_api.md); :meth:`compact` (major compaction) folds runs and
  memtable into the base SA **by merging** — prefix doubling over only
  the dirty suffix range plus a batched window-compare merge
  (:mod:`repro.api.compaction`), never a from-scratch rebuild — and bumps
  the persisted version; :meth:`flush` makes un-compacted state durable.

Multiple named tables live in one root directory under a
:class:`~repro.api.catalog.Catalog` (Accumulo's METADATA analogue).
"""
from __future__ import annotations

import os
import re
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

import time

from repro.api.compaction import merge_delta_sa
from repro.api.memtable import Memtable
from repro.api.runs import Run, TierSet, logical_tail
from repro.api.wal import WriteAheadLog
from repro.checkpoint.manager import CheckpointManager
from repro.core import codec
from repro.core.build_pipeline import BuildStats, chunk_rows_for_budget, \
    in_memory_build_stats, staged_suffix_array
from repro.core.planner import ScanOutcome, ScanPlanner, TopKCache
from repro.core.query import MatchResult
from repro.serving.metrics import MetricsEmitter, table_record
from repro.serving.trace import Tracer
from repro.core.suffix_array import build_suffix_array
from repro.core.tablet import TabletStore, build_tablet_store, \
    store_from_arrays
from repro.launch.mesh import make_tablet_mesh

# no leading dot: forbids '.', '..' (path traversal — drop_table rmtree's
# the name under root) and hidden-file collisions; 'catalog.json' is the
# catalog's own metadata file
_NAME_RE = re.compile(r"(?!\.)[A-Za-z0-9._-]{1,128}")
_RESERVED_NAMES = frozenset({"catalog.json"})


def default_root() -> str:
    """Root directory for persisted tables (``REPRO_TABLE_ROOT`` env var,
    falling back to ``./repro_tables``)."""
    return os.environ.get("REPRO_TABLE_ROOT", "repro_tables")


def _check_name(name: str) -> str:
    if not _NAME_RE.fullmatch(name or "") or name in _RESERVED_NAMES:
        raise ValueError(f"table name {name!r} must match "
                         f"{_NAME_RE.pattern} and not be reserved "
                         f"(it becomes a directory under the root)")
    return name


def _as_codes(codes, is_dna: Optional[bool]):
    """Normalize input text: DNA strings/bytes become uint8 codes.

    DNA is only *inferred* for uint8 arrays (what ``codec.encode_dna`` /
    ``random_dna`` produce).  Any other integer dtype defaults to the
    generic token path — a small-vocab token corpus must not silently
    take the packed 2-bit codec; pass ``is_dna=True`` explicitly to opt
    a non-uint8 code array into it."""
    if isinstance(codes, (str, bytes, bytearray)):
        return codec.encode_dna(codes), True
    codes = np.asarray(codes)
    if is_dna is None:
        is_dna = bool(codes.size > 0 and codes.dtype == np.uint8
                      and codes.max() < 4)
    return codes, bool(is_dna)


def _named_arrays(arrays: dict) -> dict:
    """Strip ``_flatten`` path decoration: ``"['codes']"`` -> ``"codes"``."""
    return {re.sub(r"[^0-9A-Za-z_]", "", k): v for k, v in arrays.items()}


class SuffixTable:
    """A named, versioned, mutable suffix-array table.

    Construct through :meth:`create` / :meth:`open` (persistent) or
    :meth:`from_codes` / :meth:`from_store` (in-memory); the constructor
    itself wires the runtime (store + mesh + planner) for the *current*
    device count from host arrays.
    """

    def __init__(self, codes: np.ndarray, sa_real: np.ndarray, *,
                 is_dna: bool, max_query_len: int = 128,
                 name: Optional[str] = None, root: Optional[str] = None,
                 version: int = 0, cache_size: int = 4096, keep_n: int = 3,
                 capacity_factor: float = 2.0, routed_min_batch: int = 64,
                 memtable_limit: Optional[int] = None,
                 max_runs: Optional[int] = None,
                 distributed_build: Optional[bool] = None,
                 wal: Optional[bool] = None,
                 group_commit_ms: float = 0.0,
                 fm_threshold: Optional[int] = None,
                 _store: Optional[TabletStore] = None,
                 _planner: Optional[ScanPlanner] = None,
                 _fm=None):
        self.name = name
        self.root = root
        self.version = int(version)
        self.is_dna = bool(is_dna)
        self.max_query_len = int(max_query_len)
        self.keep_n = int(keep_n)
        self.capacity_factor = float(capacity_factor)
        self.routed_min_batch = int(routed_min_batch)
        self.cache_size = int(cache_size)
        self.memtable_limit = memtable_limit
        self.max_runs = max_runs
        self.fm_threshold = fm_threshold
        self.fm = None
        self.runs: list[Run] = []
        self._codes = np.asarray(codes)
        # span histograms (stats()["latency"]): created before the
        # planner so freeze/compaction rebinds keep one shared tracer
        self.tracer = Tracer()
        self._metrics: Optional[MetricsEmitter] = None

        if _store is not None:                       # from_store: adopt as-is
            self.mesh = _planner.mesh if _planner is not None else None
            self.store = _store
            self.planner = _planner or ScanPlanner(
                _store, cache_size=cache_size,
                capacity_factor=capacity_factor,
                routed_min_batch=routed_min_batch, tracer=self.tracer)
            if _planner is not None:
                self.tracer = _planner.tracer        # adopt its histograms
        elif _fm is not None:                        # open(): frozen tier
            self.mesh = None
            self._attach_frozen(_fm)
        else:
            n_dev = len(jax.devices())
            self.mesh = make_tablet_mesh(n_dev) if n_dev > 1 else None
            self._attach(self._codes, np.asarray(sa_real, np.int32))
        self._distributed_build = (self.mesh is not None
                                   if distributed_build is None
                                   else bool(distributed_build))
        self.memtable = Memtable(self._codes, is_dna=self.is_dna,
                                 max_query_len=self.max_query_len)
        # cached TierSet snapshot for the fused read path; rebuilt lazily
        # after any write changes the tier population (docs/read_path.md)
        self._tiers: Optional[TierSet] = None
        self._tiers_valid = False
        self._cache = TopKCache(cache_size)
        self._manager: Optional[CheckpointManager] = None
        if self.root is not None and self.name is not None:
            self._manager = CheckpointManager(
                os.path.join(self.root, self.name), keep_n=self.keep_n)
        # commit log (repro.api.wal): defaults ON for persistent tables;
        # attached by create()/open() — after the snapshot exists, so the
        # log only ever covers appends the snapshot does not
        if wal and self._manager is None:
            raise ValueError("wal=True needs a persistent table (create/"
                             "open with a root); in-memory tables have "
                             "nothing to recover into")
        self._wal_on = (self._manager is not None) if wal is None else wal
        self.group_commit_ms = float(group_commit_ms)
        self._wal: Optional[WriteAheadLog] = None
        self._wal_seq = 0            # seq of the last logged/applied append
        self._recovery: Optional[dict] = None
        self._replaying = False
        # construction telemetry (stats()["build"]); set by create()/
        # from_codes()/open(), persisted across versions
        self._build: Optional[BuildStats] = None

    # -- construction --------------------------------------------------------
    @classmethod
    def from_codes(cls, codes, *, is_dna: Optional[bool] = None,
                   max_query_len: int = 128, **kw) -> "SuffixTable":
        """In-memory table (no persistence): build over ``codes`` now,
        distributed over the local mesh when >1 device is visible."""
        codes, is_dna = _as_codes(codes, is_dna)
        t0 = time.perf_counter()
        sa = cls._build_sa_for(codes, max_query_len, is_dna)
        table = cls(codes, sa, is_dna=is_dna,
                    max_query_len=max_query_len, **kw)
        table._build = in_memory_build_stats(
            len(codes), time.perf_counter() - t0)
        table._maybe_freeze()
        return table

    @classmethod
    def from_store(cls, store: TabletStore, *,
                   planner: Optional[ScanPlanner] = None,
                   **kw) -> "SuffixTable":
        """Wrap an existing :class:`TabletStore` (deprecation shim for
        pre-table callers).  The store and optional planner are adopted
        unchanged; appends and merged reads work, persistence needs
        :meth:`create`."""
        codes = np.asarray(store.text_codes[:store.n_real])
        if store.is_dna:
            codes = codes.astype(np.uint8)
        return cls(codes, None, is_dna=store.is_dna,
                   max_query_len=store.max_query_len,
                   _store=store, _planner=planner, **kw)

    @classmethod
    def create(cls, name: str, codes, *, root: Optional[str] = None,
               is_dna: Optional[bool] = None, max_query_len: int = 128,
               overwrite: bool = False, staged: Optional[bool] = None,
               max_device_bytes: Optional[int] = None,
               spill_dir: Optional[str] = None,
               build_chunk_rows: Optional[int] = None,
               shard_rows: Optional[int] = None, **kw) -> "SuffixTable":
        """Build AND persist version 1 of a named table under ``root``,
        registering it in the root's :class:`Catalog`.

        Two build paths, bit-identical results (docs/build_pipeline.md):
        the default in-memory builder, and — when ``staged=True`` or any
        of ``max_device_bytes`` / ``spill_dir`` / ``build_chunk_rows`` is
        given — the out-of-core staged pipeline, which sorts in
        device-budgeted chunks, spills working state to host RAM or
        ``spill_dir``, and streams finished SA shards of ``shard_rows``
        rows straight into the snapshot (register -> stream shards ->
        publish atomically), so the full array is never resident during
        construction.

        Crash-safe ordering: the catalog entry is written BEFORE the
        snapshot, so a create that dies mid-persist (or mid-shard-stream)
        leaves a *visible* registered-but-empty table rather than an
        invisible orphan directory; ``Catalog.reconcile`` (run on every
        catalog open) and a later ``create`` of the same name both
        garbage-collect such remnants (no published snapshot) instead of
        refusing."""
        import shutil
        from repro.api.catalog import Catalog
        _check_name(name)
        root = root or default_root()
        catalog = Catalog(root)
        table_dir = os.path.join(root, name)
        if name in catalog or os.path.isdir(table_dir):
            # only a PUBLISHED snapshot makes the table real; a bare dir
            # or catalog entry is a crashed create's remnant — reconcile
            has_snapshot = (os.path.isdir(table_dir) and
                            CheckpointManager(table_dir).latest_step()
                            is not None)
            if has_snapshot and not overwrite:
                raise FileExistsError(
                    f"table {name!r} already exists in {root!r} — "
                    f"SuffixTable.open() it, or pass overwrite=True")
            # drop stale snapshots: a survivor with a higher step would
            # shadow (or GC) the fresh version-1 save below
            shutil.rmtree(table_dir, ignore_errors=True)
        codes, is_dna = _as_codes(codes, is_dna)
        if staged is None:
            staged = (max_device_bytes is not None or spill_dir is not None
                      or build_chunk_rows is not None)
        if staged:
            return cls._create_staged(
                name, codes, root=root, catalog=catalog, is_dna=is_dna,
                max_query_len=max_query_len,
                max_device_bytes=max_device_bytes, spill_dir=spill_dir,
                build_chunk_rows=build_chunk_rows, shard_rows=shard_rows,
                **kw)
        t0 = time.perf_counter()
        sa = cls._build_sa_for(codes, max_query_len, is_dna)
        table = cls(codes, sa, is_dna=is_dna, max_query_len=max_query_len,
                    name=name, root=root, version=1, **kw)
        table._build = in_memory_build_stats(
            len(codes), time.perf_counter() - t0)
        catalog.register(name, {"is_dna": table.is_dna,
                                "max_query_len": table.max_query_len})
        table._persist()
        table._maybe_freeze()       # fm_threshold policy; re-persists frozen
        table._open_wal(fresh=True)
        return table

    @classmethod
    def _create_staged(cls, name: str, codes: np.ndarray, *, root: str,
                       catalog, is_dna: bool, max_query_len: int,
                       max_device_bytes: Optional[int],
                       spill_dir: Optional[str],
                       build_chunk_rows: Optional[int],
                       shard_rows: Optional[int], **kw) -> "SuffixTable":
        """The out-of-core create: staged chunked build
        (``core.build_pipeline``) with SA shards streamed into a
        :class:`~repro.checkpoint.manager.ShardedSave` as they finish,
        published atomically, then reopened through the normal
        :meth:`open` path (which re-attaches wal/fm policy)."""
        chunk_rows = (int(build_chunk_rows) if build_chunk_rows
                      else chunk_rows_for_budget(max_device_bytes))
        if shard_rows is None:
            shard_rows = chunk_rows
        n_dev = len(jax.devices())
        mesh = make_tablet_mesh(n_dev) if n_dev > 1 else None
        mgr = CheckpointManager(os.path.join(root, name),
                                keep_n=int(kw.get("keep_n", 3)))
        catalog.register(name, {"is_dna": is_dna,
                                "max_query_len": max_query_len})
        stage = mgr.stage_sharded(1)
        try:
            _, stats = staged_suffix_array(
                codes, chunk_rows=chunk_rows,
                max_device_bytes=max_device_bytes, spill_dir=spill_dir,
                mesh=mesh, axis_name="tablets", shard_rows=shard_rows,
                emit_shard=lambda i, blk: stage.add_shard("sa_real", i,
                                                          blk))
            if "sa_real" not in stage._shards:   # empty corpus: no shards
                stage.add_shard("sa_real", 0, np.zeros((0,), np.int32))
            state = {"codes": codes,
                     "mem_codes": np.zeros((0,), codes.dtype)}
            extra = {"kind": "suffix_table", "name": name, "version": 1,
                     "is_dna": is_dna, "max_query_len": max_query_len,
                     "n_base": int(len(codes)), "runs": [], "mem_len": 0,
                     "wal_seq": 0, "frozen": False, "fm_sample_rate": None,
                     "build": stats.to_dict()}
            stage.commit(state, extra)
        except BaseException:
            stage.abort()
            raise
        return cls.open(name, root=root, **kw)

    @classmethod
    def open(cls, name: str, *, root: Optional[str] = None,
             **kw) -> "SuffixTable":
        """Restore the latest persisted version of ``name`` on the current
        device count (the saved SA is re-padded; no rebuild).  Sealed runs
        and un-compacted appends saved by :meth:`flush` /
        :meth:`minor_compact` are restored too — run indexes come back
        frozen from disk, never re-sorted."""
        _check_name(name)
        root = root or default_root()
        table_dir = os.path.join(root, name)
        if not os.path.isdir(table_dir):        # before CheckpointManager:
            raise FileNotFoundError(            # its ctor mkdirs the path
                f"no table {name!r} under {root!r}")
        mgr = CheckpointManager(table_dir)
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no persisted version of table {name!r} under {root!r}")
        arrays, extra = mgr.restore_arrays(step)
        arrays = _named_arrays(arrays)
        fm = None
        if extra.get("frozen"):
            from repro.api.catalog import table_fm_dir
            from repro.api.fm import FMIndex
            fm = FMIndex.load(table_fm_dir(root, name))
            if fm is None or fm.n != int(arrays["codes"].shape[0]):
                # artifact missing/stale (partial copy, old format):
                # rebuild from codes — freeze state survives, bit-exactly
                fm = FMIndex.build(
                    arrays["codes"], None, is_dna=bool(extra["is_dna"]),
                    sample_rate=int(extra.get("fm_sample_rate") or 32))
        table = cls(arrays["codes"], arrays["sa_real"],
                    is_dna=bool(extra["is_dna"]),
                    max_query_len=int(extra["max_query_len"]),
                    name=name, root=root, version=int(extra["version"]),
                    _fm=fm, **kw)
        if extra.get("build"):
            table._build = BuildStats.from_dict(extra["build"])
        for i, rm in enumerate(extra.get("runs", [])):
            table.runs.append(Run.restore(
                arrays[f"run{i}_tail"], arrays[f"run{i}_codes"],
                arrays.get(f"run{i}_sa"), start=int(rm["start"]),
                is_dna=table.is_dna, max_query_len=table.max_query_len))
        if table.runs:
            table._reset_memtable()
        mem = arrays.get("mem_codes")
        if mem is not None and mem.size:
            table.memtable.append(mem)
        # crash recovery: replay the commit-log tail (appends acked after
        # this snapshot was published) through the normal memtable path
        table._wal_seq = int(extra.get("wal_seq", 0))
        table._open_wal(fresh=False)
        table._maybe_freeze()       # threshold may be new on this open
        return table

    @staticmethod
    def _build_sa_for(codes: np.ndarray, max_query_len: int,
                      is_dna: bool) -> np.ndarray:
        """Real-row SA over ``codes`` — distributed over the local mesh
        when >1 device is visible, single-device otherwise."""
        n_dev = len(jax.devices())
        if n_dev > 1:
            mesh = make_tablet_mesh(n_dev)
            store = build_tablet_store(codes, is_dna=is_dna,
                                       max_query_len=max_query_len,
                                       mesh=mesh, axis_name="tablets")
            return np.asarray(store.sa)[store.pad_count:]
        return np.asarray(build_suffix_array(codes.astype(np.int32)))

    def _attach(self, codes: np.ndarray, sa_real: np.ndarray) -> None:
        """(Re)build the runtime store for the current mesh.  An existing
        planner is re-bound IN PLACE (not replaced): captured references
        — the serving engine holds one — keep serving the post-compaction
        text, and accumulated planner stats survive."""
        from repro.distributed.sharding import mesh_axis_size
        p = mesh_axis_size(self.mesh)
        self.store = store_from_arrays(
            codes, sa_real, is_dna=self.is_dna,
            max_query_len=self.max_query_len, num_tablets=p)
        planner = getattr(self, "planner", None)
        if planner is None:
            self.planner = ScanPlanner(
                self.store, mesh=self.mesh, cache_size=self.cache_size,
                capacity_factor=self.capacity_factor,
                routed_min_batch=self.routed_min_batch,
                tracer=self.tracer)
        else:
            planner.rebind(self.store)          # also drops any FM binding
        self.fm = None

    def _attach_frozen(self, fm) -> None:
        """Swap the base tier onto the FM-index: base reads route through
        the backward-search kernel and the raw SA (device array + host
        mirror + packed text) is DROPPED — that is the footprint win.  A
        metadata-only store keeps the shape facts (``n_real``/``n_pad``/
        codecs) the planner and delta tiers read; the raw host codes stay
        (memtable overlap windows, compaction, persistence all need
        them).  Frozen tables serve single-replica — an active mesh is
        released."""
        if fm.n != self.n_base or fm.is_dna != self.is_dna:
            raise ValueError(
                f"FM-index (n={fm.n}, is_dna={fm.is_dna}) does not match "
                f"the table (n={self.n_base}, is_dna={self.is_dna})")
        self.fm = fm
        self.mesh = None
        self.store = TabletStore(
            text_packed=None, text_codes=None,
            sa=jnp.zeros((0,), jnp.int32),
            n_real=self.n_base, n_pad=self.n_base,
            is_dna=self.is_dna, max_query_len=self.max_query_len)
        planner = getattr(self, "planner", None)
        if planner is None:
            self.planner = ScanPlanner(
                self.store, cache_size=self.cache_size,
                capacity_factor=self.capacity_factor,
                routed_min_batch=self.routed_min_batch, fm=fm,
                tracer=self.tracer)
        else:
            planner.rebind(self.store, fm=fm)

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        """Total indexed symbols: base + sealed runs + memtable."""
        return self.n_logical + self.memtable.size

    @property
    def n_base(self) -> int:
        return int(self._codes.shape[0])

    @property
    def n_logical(self) -> int:
        """Symbols covered by the immutable tiers (base + sealed runs) —
        the memtable's boundary."""
        return self.n_base + sum(r.length for r in self.runs)

    @property
    def is_persistent(self) -> bool:
        return self._manager is not None

    @property
    def is_frozen(self) -> bool:
        """True when the base tier serves from the FM-index."""
        return self.fm is not None

    @property
    def write_generation(self) -> int:
        """Monotone counter bumped by every write (``append`` /
        ``minor_compact`` / ``compact``) — the staleness stamp for
        cached results (``ReadSession`` re-enumerates only when this
        moves)."""
        return self._cache.generation

    def stats(self) -> dict:
        """Observability snapshot with a STABLE schema (docs/client_api.md
        documents every key; serve.py prints it):

        * ``name`` / ``version`` / ``is_dna`` / ``max_query_len`` —
          identity;
        * ``tiers`` — ``base_rows``, ``run_count``, ``run_rows``,
          ``memtable_rows`` (the LSM stack, in symbols);
        * ``cache`` — the table-level string-result cache: ``entries``,
          ``hits``, ``misses``, ``generation`` (bumped by every write);
        * ``planner`` — ``PlannerStats.as_dict()``: batches, queries,
          mode counts, retry counters, and the bucketed-batch slot
          accounting (``bucketed_batches`` / ``bucketed_queries`` /
          ``pad_slots``) fed by the client frontend, plus the fused
          read-path counters ``fused_batches`` / ``base_only_batches``
          / ``tier_reads`` (docs/read_path.md).  (True cross-caller
          coalescing counters live in ``Database.stats()["scheduler"]``.)
        * ``build`` — how the base index was constructed (``None`` for
          adopted stores): ``mode`` (``"staged"``/``"in_memory"``),
          ``rounds``, ``n_chunks``, ``chunk_rows``,
          ``peak_device_bytes``, ``spill_bytes``, ``elapsed_s``,
          ``bases_per_s`` — the :class:`~repro.core.build_pipeline.
          BuildStats` schema, persisted with the table
          (docs/build_pipeline.md);
        * ``latency`` — rolling span histograms from the table's
          :class:`~repro.serving.trace.Tracer` (``encode`` /
          ``dispatch`` / ``merge`` / ``total`` plus the planner's
          ``dispatch_*`` modes), each ``{p50_ms, p95_ms, p99_ms, n,
          total, sum_ms}`` — docs/observability.md defines every span;
        * ``wal`` — durability: ``enabled``, ``seq`` (last append's
          commit sequence), ``log`` (appends/fsyncs/seals counters, or
          ``None`` with no log), and ``recovery`` — ``None`` on a clean
          open, otherwise the last recovery summary
          (``records_replayed`` / ``records_skipped`` / ``torn_bytes`` /
          ``reason`` — docs/table_api.md gives the full schema).

        New keys may be added; existing keys keep their meaning."""
        return {
            "name": self.name,
            "version": self.version,
            "is_dna": self.is_dna,
            "max_query_len": self.max_query_len,
            "tiers": {
                "base_rows": self.n_base,
                "run_count": len(self.runs),
                "run_rows": self.n_logical - self.n_base,
                "memtable_rows": self.memtable.size,
                "frozen": self.fm is not None,
                "resident_bytes": self._resident_bytes(),
            },
            "cache": {
                "entries": len(self._cache),
                "hits": self._cache.hits,
                "misses": self._cache.misses,
                "generation": self._cache.generation,
            },
            "build": (self._build.to_dict() if self._build is not None
                      else None),
            "planner": self.planner.stats.as_dict(),
            "latency": self.tracer.snapshot(),
            "wal": {
                "enabled": self._wal is not None,
                "seq": self._wal_seq,
                "log": (self._wal.stats() if self._wal is not None
                        else None),
                "recovery": self._recovery,
            },
        }

    def _resident_bytes(self) -> dict:
        """Per-tier index footprint in bytes (the ``stats()["tiers"]
        ["resident_bytes"]`` schema, docs/storage_tiers.md).  ``base_sa``
        counts the device SA plus the lazily-materialized host mirror;
        ``text_device`` the packed/padded device text; both drop to 0 on
        a frozen table, where ``fm`` carries the compressed index
        instead.  ``text_host`` (the raw 1 B/sym code array every table
        keeps for compaction and memtable overlap) is reported separately
        so the index-vs-index comparison stays clean."""
        base_sa = int(self.store.sa.size) * 4
        if self.planner._sa_host is not None:
            base_sa += int(self.planner._sa_host.nbytes)
        text_dev = 0
        if self.store.text_packed is not None:
            text_dev += int(self.store.text_packed.size) * 4
        if self.store.text_codes is not None:
            text_dev += int(self.store.text_codes.size) * 4
        run_bytes = 0
        for r in self.runs:
            run_bytes += int(np.asarray(r.tail).nbytes)
            run_bytes += int(np.asarray(r.codes).nbytes)
            sa_p = getattr(r, "sa_padded", None)
            if sa_p is not None:
                run_bytes += int(np.asarray(sa_p).nbytes)
        return {
            "base_sa": base_sa,
            "fm": self.fm.resident_bytes() if self.fm is not None else 0,
            "text_device": text_dev,
            "runs": run_bytes,
            "memtable": int(self.memtable.size),
            "text_host": int(self._codes.nbytes),
        }

    def _invalidate_caches(self) -> None:
        """Generation-bump the table AND planner string-result caches —
        the logical text just changed, so any cached count/top-k from
        before this write must never be served again (previously the
        planner's own cache was left stale across table writes)."""
        self._cache.bump()
        self.planner.invalidate_cache()
        self._tiers = None
        self._tiers_valid = False

    def clear_cache(self) -> None:
        """Drop all cached string-scan results (benchmarks use this to
        time cold reads)."""
        self._cache.clear()
        self.planner.clear_cache()

    def _reset_memtable(self) -> None:
        """Fresh empty memtable whose overlap window is the tail of the
        current logical text (base + sealed runs)."""
        if not self.runs:
            self.memtable = Memtable(self._codes, is_dna=self.is_dna,
                                     max_query_len=self.max_query_len)
            self._tiers = None
            self._tiers_valid = False
            return
        n = self.n_logical
        tail = logical_tail([self._codes] + [r.codes for r in self.runs],
                            min(self.max_query_len - 1, n))
        self.memtable = Memtable(tail.astype(self._codes.dtype, copy=False),
                                 is_dna=self.is_dna,
                                 max_query_len=self.max_query_len, n_base=n)
        self._tiers = None
        self._tiers_valid = False

    def _sa(self) -> np.ndarray:
        # the planner already caches a host copy of the same store.sa —
        # don't materialize a second one per table
        return self.planner._sa()

    # -- read path -----------------------------------------------------------
    def _tierset(self) -> Optional[TierSet]:
        """The cached delta-tier snapshot for the fused read path — None
        when there are no delta tiers (the base-only fast path).
        Rebuilt lazily after any write that changes the tier population
        (append / seal / compaction / restore all invalidate it)."""
        if not self._tiers_valid:
            self._tiers = TierSet.build(self.runs, self.memtable)
            self._tiers_valid = True
        return self._tiers

    def _scan_tiers(self, patt, plen, *, mode=None, n_real=None):
        """One fused merged dispatch: (merged MatchResult, TierScanResult
        | None, delta positions per query | None, base-only count)."""
        merged, tres = self.planner.scan_tiers(
            self._tierset(), patt, plen, mode=mode, n_real=n_real)
        B = int(np.asarray(plen).shape[0]) if n_real is None else int(n_real)
        count = np.asarray(merged.count).astype(np.int64)[:B]
        if tres is None:
            return merged, None, None, count
        delta = self._tiers.delta_positions(tres.less, tres.matches,
                                            plen, n_real=B)
        base_count = count - np.asarray(
            tres.count)[:, :B].astype(np.int64).sum(axis=0)
        return merged, tres, delta, base_count

    def _base_min_positions(self, base_count, base_rank) -> np.ndarray:
        """Per query, the smallest BASE text position among its base-tier
        matches (-1 when none): one vectorized flat gather + segmented
        min over the SA slices ``[lb, lb + count)`` — the text-order
        ``first_pos`` reduction, with no per-query dispatch."""
        B = int(base_count.shape[0])
        out = np.full(B, -1, np.int64)
        nz = np.flatnonzero((base_count > 0) & (base_rank >= 0))
        if nz.size == 0:
            return out
        cnt = base_count[nz].astype(np.int64)
        starts = self.store.pad_count + base_rank[nz].astype(np.int64)
        seg = np.concatenate([[0], np.cumsum(cnt)[:-1]])
        flat = np.repeat(starts - seg, cnt) + np.arange(int(cnt.sum()))
        if self.fm is not None:
            # frozen tier: no SA to gather — LF-walk the SA$ rows
            # (real-SA row r is SA$ row r + 1) back to text positions
            vals = self.fm.ranks_to_positions(flat + 1)
        else:
            vals = self._sa()[flat]
        out[nz] = np.minimum.reduceat(vals.astype(np.int64), seg)
        return out

    def scan_encoded(self, patt, plen, *, mode: Optional[str] = None
                     ) -> MatchResult:
        """Exact merged scan of an encoded batch (see ``ScanPlanner.
        scan_encoded`` for encodings).  With no runs and an empty memtable
        this is a pure base delegation; otherwise the fused tier scan
        (``ScanPlanner.scan_tiers``) adds the run/memtable-only
        occurrences in the same launch and ``first_pos`` is the smallest
        of the base's reported position and every delta-tier occurrence
        position.  ``first_rank`` always refers to the BASE suffix array
        (−1 when the only matches are in the delta tiers) — do not feed a
        merged result to ``planner.positions_from_result``, use
        :meth:`scan`/:meth:`locate` for merged enumeration."""
        merged, _tres = self.planner.scan_tiers(self._tierset(), patt,
                                                plen, mode=mode)
        return merged

    def _base_slice(self, base_count, base_rank, i) -> np.ndarray:
        """Base-tier SA slice of row ``i``'s matches (text positions,
        unsorted — suffix-rank order)."""
        cb = int(base_count[i])
        if cb <= 0 or base_rank[i] < 0:
            return np.zeros((0,), np.int64)
        lb = self.store.pad_count + int(base_rank[i])
        if self.fm is not None:
            rows = np.arange(lb + 1, lb + 1 + cb)     # SA$ rows of the run
            return self.fm.ranks_to_positions(rows).astype(np.int64)
        return self._sa()[lb:lb + cb].astype(np.int64)

    def scan_batch(self, patt, plen, top_k: int = 0) -> ScanOutcome:
        """Merged scan of an encoded batch with **text-order** semantics
        — the client frontend's batch entry point (no string cache).

        The batch is padded to a power-of-two bucket (row 0 repeated)
        before the fused merged dispatch, so coalesced batches of varying
        size reuse O(log B) compilations instead of one per size; pad
        slots are discarded here and attributed to
        ``planner.stats.pad_slots`` (slot accounting under
        ``bucketed_batches``), never to ``queries``.
        """
        plen_np = np.asarray(plen)
        B = int(plen_np.shape[0])
        if B == 0:
            return ScanOutcome(
                found=np.zeros(0, bool), count=np.zeros(0, np.int64),
                first_pos=np.full(0, -1, np.int64),
                positions=(np.full((0, top_k), -1, np.int64)
                           if top_k else None))
        tr = self.tracer
        t_all = time.monotonic_ns()
        patt_np = np.asarray(patt)
        bucket = 1 << (B - 1).bit_length() if B > 1 else 1
        if bucket != B:
            reps = bucket - B
            patt_np = np.concatenate(
                [patt_np, np.repeat(patt_np[:1], reps, axis=0)])
            plen_np = np.concatenate(
                [plen_np, np.repeat(plen_np[:1], reps)])
        # "dispatch" covers the fused launch; any async device wait is
        # forced (and therefore timed) by the host conversions inside
        # _scan_tiers, so "merge" below is pure host-side reduction
        with tr.span("dispatch"):
            merged, _tres, delta, base_count = self._scan_tiers(
                jnp.asarray(patt_np), jnp.asarray(plen_np), n_real=B)
        with tr.span("merge"):
            count = np.asarray(merged.count).astype(np.int64)[:B]
            base_rank = np.asarray(merged.first_rank)[:B]
            first_pos = self._base_min_positions(base_count, base_rank)
            positions = (np.full((B, top_k), -1, np.int64)
                         if top_k else None)
            for i in range(B):
                g = (delta[i] if delta is not None
                     else np.zeros((0,), np.int64))
                if g.size and (first_pos[i] < 0 or g[0] < first_pos[i]):
                    first_pos[i] = int(g[0])
                if top_k:
                    run = self._base_slice(base_count, base_rank, i)
                    cand = np.concatenate([run, g])
                    if cand.size > top_k:
                        cand = np.partition(cand, top_k - 1)[:top_k]
                    cand.sort()
                    positions[i, :cand.size] = cand
        tr.record("total", (time.monotonic_ns() - t_all) / 1e6)
        return ScanOutcome(found=count > 0, count=count,
                           first_pos=first_pos, positions=positions)

    def scan(self, patterns: list[str], top_k: int = 0) -> ScanOutcome:
        """String-level merged scan with **text-order** semantics: exact
        ``count``; ``first_pos`` is the smallest occurrence position;
        ``positions`` (when ``top_k > 0``) are the ``top_k`` smallest
        occurrence start positions, ascending, −1-padded — the complete
        set whenever ``count <= top_k``.  (The planner's own string API
        instead reports suffix-rank order over the base only.)  Results
        are LRU-cached; every write (:meth:`append` /
        :meth:`minor_compact` / :meth:`compact`) generation-bumps the
        cache so pre-write results are never served."""
        B = len(patterns)
        count = np.zeros(B, np.int64)
        first_pos = np.full(B, -1, np.int64)
        positions = (np.full((B, top_k), -1, np.int64) if top_k else None)
        miss_idx: list[int] = []
        for i, pat in enumerate(patterns):
            hit = self._cache.get(pat, top_k)
            if hit is not None:
                count[i], first_pos[i] = hit[0], hit[1]
                if top_k:
                    positions[i] = hit[2]
            else:
                miss_idx.append(i)
        if miss_idx:
            with self.tracer.span("encode"):
                patt, plen = self.planner.encode(
                    [patterns[i] for i in miss_idx])
            sub = self.scan_batch(patt, plen, top_k=top_k)
            for j, i in enumerate(miss_idx):
                count[i] = sub.count[j]
                first_pos[i] = sub.first_pos[j]
                row = sub.positions[j] if top_k else None
                if top_k:
                    positions[i] = row
                self._cache.put(patterns[i], int(count[i]),
                                int(first_pos[i]), top_k, row)
        return ScanOutcome(found=count > 0, count=count,
                           first_pos=first_pos, positions=positions)

    def locate_range(self, pattern: str, *, after: int = -1,
                     limit: Optional[int] = 256) -> np.ndarray:
        """Up to ``limit`` occurrence start positions of ``pattern``
        STRICTLY greater than ``after``, ascending int64 — the paged-read
        primitive under :class:`repro.api.client.ReadSession`
        (``limit=None`` returns the complete enumeration, which the
        session caches per :attr:`write_generation` so a stream of pages
        costs ONE scan, not one per page).

        Positions are global text offsets, which are stable identifiers
        across minor and major compactions: a cursor (= the last position
        of the previous page) taken before a compaction resumes exactly
        after it.  The host-side gather is O(count) for the base tier;
        the returned chunk is what stays bounded."""
        if limit is not None and limit <= 0:
            raise ValueError(f"limit must be positive, got {limit}")
        patt, plen = self.planner.encode([pattern])
        merged, _tres, delta, base_count = self._scan_tiers(patt, plen,
                                                            n_real=1)
        run = self._base_slice(base_count, np.asarray(merged.first_rank), 0)
        g = delta[0] if delta is not None else np.zeros((0,), np.int64)
        cand = np.concatenate([run, g]) if g.size else run
        cand = cand[cand > after]
        if limit is not None and cand.size > limit:
            cand = np.partition(cand, limit - 1)[:limit]
        cand.sort()
        return cand.astype(np.int64)

    def count(self, patterns: list[str]) -> np.ndarray:
        """Exact occurrence counts, (B,) int64."""
        return self.scan(patterns).count

    def contains(self, patterns: list[str]) -> np.ndarray:
        """Per-pattern membership, (B,) bool."""
        return self.scan(patterns).found

    def locate(self, patterns: list[str], top_k: int = 8) -> np.ndarray:
        """Up to ``top_k`` smallest occurrence positions per pattern,
        ascending, (B, top_k) int64, −1-padded."""
        return self.scan(patterns, top_k=top_k).positions

    # -- write path ----------------------------------------------------------
    def _open_wal(self, *, fresh: bool) -> None:
        """Attach the table's commit log.  ``fresh=True`` (create) starts
        an empty segment; ``fresh=False`` (open) recovers the live one:
        torn tails are discarded by CRC, records the latest snapshot
        already covers are skipped by sequence number, and the rest —
        exactly the appends acked after that snapshot — replay through
        the normal memtable path.  The summary lands in
        ``stats()["wal"]["recovery"]``."""
        from repro.api.catalog import table_wal_dir
        if self._manager is None:
            return
        path = os.path.join(table_wal_dir(self.root, self.name), "wal.log")
        if not self._wal_on:
            # opting out with a live log on disk: move it aside.  The
            # table's state will diverge from the log (appends now take
            # sequence numbers the log never sees), so a LATER wal=True
            # open must not find this segment and splice its stale
            # records into the diverged text — the orphan is preserved
            # for manual inspection, never replayed.
            if os.path.exists(path):
                os.replace(path, path + ".orphaned")
            return
        if fresh or not os.path.exists(path):
            self._wal = WriteAheadLog.create(
                path, start_seq=self._wal_seq + 1,
                group_commit_ms=self.group_commit_ms)
            return
        wal = WriteAheadLog(path, group_commit_ms=self.group_commit_ms)
        records, summary = wal.recover()
        self._wal = wal
        self._replaying = True      # no auto-seal mid-replay: a seal here
        try:                        # would truncate records not yet applied
            for seq, codes in records:
                if seq <= self._wal_seq:
                    summary.records_skipped += 1
                    continue
                if seq != self._wal_seq + 1:
                    # the log starts past the snapshot: records between
                    # them are gone, so nothing later can be applied
                    summary.reason = "snapshot_gap"
                    break
                self._apply_append(codes)
                self._wal_seq = seq
                summary.records_replayed += 1
        finally:
            self._replaying = False
        self._recovery = summary.as_dict()
        if wal._last_written_seq != self._wal_seq:
            # only stale (< snapshot) or unreachable (snapshot_gap)
            # records remain in the segment — re-seal so the next append
            # gets a contiguous sequence
            wal.seal(self._wal_seq + 1)
        if (self.memtable_limit is not None
                and self.memtable.size >= self.memtable_limit):
            self.minor_compact()    # deferred from replay; persists + seals

    def append(self, codes) -> int:
        """Append text to the table (memtable write path); visible to all
        subsequent reads with exact merged counts.  On a persistent table
        the batch is committed to the write-ahead log and **fsync'd
        before this method returns** — the returned ack means durable.
        Returns the memtable size; triggers :meth:`minor_compact` at
        ``memtable_limit`` (and, through it, :meth:`compact` at
        ``max_runs``)."""
        size, token = self.append_nowait(codes)
        self.wait_durable(token)
        return size

    def append_nowait(self, codes) -> tuple[int, Optional[int]]:
        """The two-phase append underneath :meth:`append`: validate, log
        the commit record (buffered, not yet fsync'd), apply to the
        memtable, and return ``(memtable_size, durability_token)``.  The
        caller must pass the token to :meth:`wait_durable` before acking
        — ``Database.append`` does exactly that, waiting OUTSIDE the
        table's write lock so concurrent clients share one group-commit
        fsync.  Readers may observe the appended text before it is
        durable (standard commit-wait semantics); the ack is what
        promises crash survival."""
        if isinstance(codes, (str, bytes, bytearray)):
            if not self.is_dna:
                raise TypeError("string appends are DNA-only; pass a code "
                                "array for token tables")
            codes = codec.encode_dna(codes)
        # validate BEFORE logging: a bad batch must fail the caller, not
        # poison the log with a record that re-raises on every recovery
        codes = Memtable.validate_codes(codes, is_dna=self.is_dna)
        if codes.size == 0:
            return self.memtable.size, None
        token = None
        if self._wal is not None:
            # log first, bump after: a failed write (disk full) leaves
            # the counter aligned with the log so a retry isn't wedged
            # on a phantom sequence number
            token = self._wal.append(codes, self._wal_seq + 1)
        self._wal_seq += 1          # counted even unlogged: snapshots
        self._apply_append(codes)   # persist it, keeping replay aligned
        return self.memtable.size, token

    def wait_durable(self, token: Optional[int]) -> None:
        """Block until the append that returned ``token`` is on disk
        (fsync'd, or covered by a sealed snapshot).  No-op for ``None``
        (empty appends, tables without a log)."""
        if token is not None and self._wal is not None:
            self._wal.wait(token)

    def _apply_append(self, codes: np.ndarray) -> None:
        """Memtable apply + cache invalidation — shared by live appends
        and log replay (replay defers the ``memtable_limit`` check: an
        auto-seal mid-replay would truncate not-yet-applied records).
        Callers guarantee ``codes`` passed ``validate_codes`` (live
        appends check before logging; replayed records were checked
        before they were ever logged)."""
        self.memtable.append(codes, _prevalidated=True)
        self._invalidate_caches()
        if (not self._replaying and self.memtable_limit is not None
                and self.memtable.size >= self.memtable_limit):
            self.minor_compact()

    def minor_compact(self) -> int:
        """Seal the active memtable into an immutable
        :class:`~repro.api.runs.Run` and start a fresh one, so appends
        stay fast (the rebuilt-per-read memtable index never grows past
        ``memtable_limit``) without losing read visibility.  Persistent
        tables re-publish the snapshot (same version) so the sealed run
        is durable.  No-op on an empty memtable.  Returns the number of
        live runs; when ``max_runs`` is reached the runs are folded into
        the base via :meth:`compact` first."""
        if self.memtable.size == 0:
            return len(self.runs)
        self.runs.append(Run.from_memtable(self.memtable))
        self._reset_memtable()
        self._invalidate_caches()
        if self.max_runs is not None and len(self.runs) >= self.max_runs:
            self.compact()
        elif self._manager is not None:
            self._persist()
        return len(self.runs)

    # -- frozen tier ---------------------------------------------------------
    def _fm_dir(self) -> str:
        from repro.api.catalog import table_fm_dir
        return table_fm_dir(self.root, self.name)

    def freeze(self, *, sample_rate: int = 32) -> "SuffixTable":
        """Convert the base tier to a frozen FM-index (docs/
        storage_tiers.md): the BWT is derived from the current base SA,
        2-bit-packed with blocked Occ checkpoints and a sampled SA, and
        the raw suffix array is dropped — ~10x less resident index per
        symbol.  Reads route through the backward-search kernel;
        ``count()`` becomes O(pattern_len), independent of text size.
        Post-freeze appends keep working: they land in the memtable /
        runs as usual and merge with FM base results through the same
        fused tier path.  Persistent tables save the artifact under the
        table's ``fm/`` dir and re-publish the snapshot.  Idempotent."""
        if self.fm is not None:
            return self
        from repro.api.fm import FMIndex
        sa_real = np.asarray(self.store.sa)[self.store.pad_count:]
        # merge-built SAs are exact only to the compare depth; build()
        # verifies full order and re-sorts if the check fails, so the
        # BWT is always derived from a true full suffix array
        fm = FMIndex.build(self._codes, sa_real, is_dna=self.is_dna,
                           sample_rate=sample_rate)
        self._attach_frozen(fm)
        if self._manager is not None:
            fm.save(self._fm_dir(), self.version)
            self._persist()
        return self

    def _maybe_freeze(self) -> None:
        """Apply the ``fm_threshold`` policy: freeze once the base tier
        reaches the threshold (checked after create/open/compact — the
        points where the base grows)."""
        if (self.fm is None and self.fm_threshold is not None
                and self.n_base >= int(self.fm_threshold)):
            from repro.api.fm import MAX_VOCAB
            if (not self.is_dna and self._codes.size
                    and int(self._codes.max()) >= MAX_VOCAB):
                return      # policy no-op: vocab beyond the frozen cap
            self.freeze()

    def _delta_codes(self) -> np.ndarray:
        """All un-compacted symbols (sealed runs + memtable), in order."""
        parts = [r.codes for r in self.runs]
        if self.memtable.size:
            parts.append(self.memtable.appended)
        if not parts:
            return np.zeros((0,), self._codes.dtype)
        return np.concatenate(
            [p.astype(self._codes.dtype, copy=False) for p in parts])

    def compact(self) -> int:
        """Major compaction: fold every sealed run plus the memtable into
        the base suffix array, clear the delta tiers, bump and persist
        the version.  Single-device tables MERGE (prefix doubling over
        only the dirty suffix range + batched window-compare insertion —
        see :mod:`repro.api.compaction`) so a small delta compacts far
        faster than a from-scratch build; tables with a live mesh keep
        the distributed full rebuild (the merge is a host-side path).
        No-op when there is nothing to fold.  Returns the version."""
        delta = self._delta_codes()
        if delta.size == 0:
            return self.version
        combined = np.concatenate([self._codes, delta])
        was_frozen = self.fm is not None
        fm_rate = self.fm.sample_rate if was_frozen else None
        if was_frozen:
            # the raw SA was dropped at freeze time; reconstruct it from
            # the index (vectorized LF walks) as the merge input, then
            # compact live and re-freeze over the merged text below
            base_sa = self.fm.suffix_array().astype(np.int32)
            sa_real = merge_delta_sa(
                combined, self.n_base, base_sa,
                is_dna=self.is_dna, max_query_len=self.max_query_len)
        elif self.mesh is not None and self._distributed_build:
            sa_real = self.__class__._build_sa_for(
                combined, self.max_query_len, self.is_dna)
        else:
            pad = self.store.pad_count
            sa_real = merge_delta_sa(
                combined, self.n_base, np.asarray(self.store.sa)[pad:],
                is_dna=self.is_dna, max_query_len=self.max_query_len)
        self._codes = combined
        self._attach(combined, sa_real)      # rebind bumps the planner
        self.runs = []                       # cache AND drops any FM binding
        self._reset_memtable()
        self._invalidate_caches()
        self.version += 1
        self._persist()
        if was_frozen:
            self.freeze(sample_rate=fm_rate)  # frozen is a sticky tier state
        else:
            self._maybe_freeze()
        return self.version

    def flush(self) -> None:
        """Persist the current state — base arrays, sealed runs, AND
        un-compacted memtable codes — without compacting (same version,
        re-published atomically).  :meth:`open` restores all of it.
        Raises on an in-memory table: durability is this method's entire
        contract."""
        if self._manager is None:
            raise RuntimeError(
                "flush() on a non-persistent table — build it with "
                "SuffixTable.create(...) to get durable storage")
        self._persist()

    def start_metrics(self, path: str, interval_s: float = 1.0,
                      name: Optional[str] = None) -> None:
        """Stream this table's full :meth:`stats` tree into a
        ``metrics.jsonl`` feed — the SAME feed schema the serving
        plane's workers and routers append to, so ``serve.py
        --dump-stats`` (and ``check_regression.py --from-feed``)
        aggregate one schema whether serving is in-process or
        multi-process (docs/observability.md).  Each row is
        ``metrics.table_record(name, stats())``; ``name`` overrides the
        row identity for anonymous in-memory tables (``self.name`` is
        the default).  Idempotent — a second call restarts the emitter
        on the new path/interval."""
        self.stop_metrics()
        row_name = name if name is not None else self.name
        self._metrics = MetricsEmitter(
            path, lambda: table_record(row_name, self.stats()),
            interval_s=interval_s)

    def stop_metrics(self) -> None:
        """Stop the feed emitter (writes one final row)."""
        if self._metrics is not None:
            self._metrics.stop()
            self._metrics = None

    def close(self) -> None:
        """Release the commit-log file handle and stop the metrics
        emitter.  Reads keep working; a later :meth:`append` raises
        instead of silently losing durability (reopen the table to
        resume writing)."""
        self.stop_metrics()
        if self._wal is not None:
            self._wal.close()

    def _persist(self) -> None:
        if self._manager is None:
            return
        if self.fm is not None:
            # frozen: the SA was dropped — the FM artifact (saved under
            # fm/ by freeze()) is the base index on disk; open() rebuilds
            # from codes if the artifact is ever missing
            sa_real = np.zeros((0,), np.int32)
        else:
            sa_real = self._sa()[self.store.pad_count:]
        state = {"codes": self._codes,
                 "sa_real": sa_real,
                 "mem_codes": self.memtable.appended}
        runs_meta = []
        for i, r in enumerate(self.runs):
            state[f"run{i}_tail"] = r.tail
            state[f"run{i}_codes"] = r.codes
            state[f"run{i}_sa"] = r.sa_padded   # frozen index, no re-sort
            runs_meta.append({"start": r.start, "length": r.length,
                              "overlap": r.overlap})
        extra = {"kind": "suffix_table", "name": self.name,
                 "version": self.version, "is_dna": self.is_dna,
                 "max_query_len": self.max_query_len,
                 "n_base": self.n_base, "runs": runs_meta,
                 "mem_len": self.memtable.size,
                 "wal_seq": self._wal_seq,
                 "frozen": self.fm is not None,
                 "fm_sample_rate": (self.fm.sample_rate
                                    if self.fm is not None else None),
                 "build": (self._build.to_dict()
                           if self._build is not None else None)}
        # always publish under a FRESH step: CheckpointManager.save on an
        # existing step rmtree's it before the rename, so re-publishing
        # the same version in place (flush / every automatic seal) would
        # open a crash window with zero live snapshots.  The step is a
        # plain publish sequence; the table version rides in ``extra``.
        step = (self._manager.latest_step() or 0) + 1
        self._manager.save(step, state, extra=extra)
        if self._wal is not None:
            # ONLY after the snapshot is published may the log be
            # truncated — there is never a moment with zero durable
            # copies of an acked append.  A crash landing between save
            # and seal is caught by the seq skip on replay.
            self._wal.seal(self._wal_seq + 1)


# Back-compat: the pre-table spelling, one call deep.
def open_table(name: str, *, root: Optional[str] = None,
               **kw) -> SuffixTable:
    return SuffixTable.open(name, root=root, **kw)


TableLike = Union[SuffixTable, TabletStore]

"""``SuffixTable`` — the Bigtable-style table facade over the whole store.

The paper's deliverable is not a function but a *table*: a durable, named
suffix index you open, scan, and mutate (Accumulo gives Randazzo & Rombo
and Wu et al. the same thing).  This module is that single public entry
point; callers no longer hand-wire ``build_tablet_store`` + ``ScanPlanner``
+ mesh plumbing:

* :meth:`SuffixTable.create` builds the suffix array (distributed over the
  local mesh when more than one device is visible) and persists it through
  ``CheckpointManager``-style atomic versioned files;
* :meth:`SuffixTable.open` restores a table on **any** device count — the
  persisted real-row suffix array is re-padded for the local tablet count
  and the right mesh/planner are constructed internally;
* reads (:meth:`count` / :meth:`contains` / :meth:`scan` / :meth:`locate`)
  delegate to the :class:`~repro.core.planner.ScanPlanner` for the base
  index and merge in the memtable (below);
* the write path: :meth:`append` lands codes in a single-device
  :class:`~repro.api.memtable.Memtable`; reads fan out to base + memtable
  and merge exact counts and positions, including matches straddling the
  base/append boundary (overlap window — see docs/table_api.md);
  :meth:`compact` folds the memtable into the base SA and bumps the
  persisted version; :meth:`flush` makes un-compacted appends durable.

Multiple named tables live in one root directory under a
:class:`~repro.api.catalog.Catalog` (Accumulo's METADATA analogue).
"""
from __future__ import annotations

import os
import re
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.memtable import Memtable
from repro.checkpoint.manager import CheckpointManager
from repro.core import codec
from repro.core.planner import ScanOutcome, ScanPlanner, TopKCache
from repro.core.query import MatchResult
from repro.core.suffix_array import build_suffix_array
from repro.core.tablet import TabletStore, build_tablet_store, \
    store_from_arrays
from repro.launch.mesh import make_tablet_mesh

# no leading dot: forbids '.', '..' (path traversal — drop_table rmtree's
# the name under root) and hidden-file collisions; 'catalog.json' is the
# catalog's own metadata file
_NAME_RE = re.compile(r"(?!\.)[A-Za-z0-9._-]{1,128}")
_RESERVED_NAMES = frozenset({"catalog.json"})


def default_root() -> str:
    """Root directory for persisted tables (``REPRO_TABLE_ROOT`` env var,
    falling back to ``./repro_tables``)."""
    return os.environ.get("REPRO_TABLE_ROOT", "repro_tables")


def _check_name(name: str) -> str:
    if not _NAME_RE.fullmatch(name or "") or name in _RESERVED_NAMES:
        raise ValueError(f"table name {name!r} must match "
                         f"{_NAME_RE.pattern} and not be reserved "
                         f"(it becomes a directory under the root)")
    return name


def _as_codes(codes, is_dna: Optional[bool]):
    """Normalize input text: DNA strings/bytes become uint8 codes."""
    if isinstance(codes, (str, bytes, bytearray)):
        return codec.encode_dna(codes), True
    codes = np.asarray(codes)
    if is_dna is None:
        is_dna = bool(codes.size > 0 and codes.max() < 4)
    return codes, bool(is_dna)


def _named_arrays(arrays: dict) -> dict:
    """Strip ``_flatten`` path decoration: ``"['codes']"`` -> ``"codes"``."""
    return {re.sub(r"[^0-9A-Za-z_]", "", k): v for k, v in arrays.items()}


class SuffixTable:
    """A named, versioned, mutable suffix-array table.

    Construct through :meth:`create` / :meth:`open` (persistent) or
    :meth:`from_codes` / :meth:`from_store` (in-memory); the constructor
    itself wires the runtime (store + mesh + planner) for the *current*
    device count from host arrays.
    """

    def __init__(self, codes: np.ndarray, sa_real: np.ndarray, *,
                 is_dna: bool, max_query_len: int = 128,
                 name: Optional[str] = None, root: Optional[str] = None,
                 version: int = 0, cache_size: int = 4096, keep_n: int = 3,
                 capacity_factor: float = 2.0, routed_min_batch: int = 64,
                 memtable_limit: Optional[int] = None,
                 distributed_build: Optional[bool] = None,
                 _store: Optional[TabletStore] = None,
                 _planner: Optional[ScanPlanner] = None):
        self.name = name
        self.root = root
        self.version = int(version)
        self.is_dna = bool(is_dna)
        self.max_query_len = int(max_query_len)
        self.keep_n = int(keep_n)
        self.capacity_factor = float(capacity_factor)
        self.routed_min_batch = int(routed_min_batch)
        self.cache_size = int(cache_size)
        self.memtable_limit = memtable_limit
        self._codes = np.asarray(codes)

        if _store is not None:                       # from_store: adopt as-is
            self.mesh = _planner.mesh if _planner is not None else None
            self.store = _store
            self.planner = _planner or ScanPlanner(
                _store, cache_size=cache_size,
                capacity_factor=capacity_factor,
                routed_min_batch=routed_min_batch)
        else:
            n_dev = len(jax.devices())
            self.mesh = make_tablet_mesh(n_dev) if n_dev > 1 else None
            self._attach(self._codes, np.asarray(sa_real, np.int32))
        self._distributed_build = (self.mesh is not None
                                   if distributed_build is None
                                   else bool(distributed_build))
        self.memtable = Memtable(self._codes, is_dna=self.is_dna,
                                 max_query_len=self.max_query_len)
        self._cache = TopKCache(cache_size)
        self._manager: Optional[CheckpointManager] = None
        if self.root is not None and self.name is not None:
            self._manager = CheckpointManager(
                os.path.join(self.root, self.name), keep_n=self.keep_n)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_codes(cls, codes, *, is_dna: Optional[bool] = None,
                   max_query_len: int = 128, **kw) -> "SuffixTable":
        """In-memory table (no persistence): build over ``codes`` now,
        distributed over the local mesh when >1 device is visible."""
        codes, is_dna = _as_codes(codes, is_dna)
        table = cls(codes, cls._build_sa_for(codes, max_query_len, is_dna),
                    is_dna=is_dna, max_query_len=max_query_len, **kw)
        return table

    @classmethod
    def from_store(cls, store: TabletStore, *,
                   planner: Optional[ScanPlanner] = None,
                   **kw) -> "SuffixTable":
        """Wrap an existing :class:`TabletStore` (deprecation shim for
        pre-table callers).  The store and optional planner are adopted
        unchanged; appends and merged reads work, persistence needs
        :meth:`create`."""
        codes = np.asarray(store.text_codes[:store.n_real])
        if store.is_dna:
            codes = codes.astype(np.uint8)
        return cls(codes, None, is_dna=store.is_dna,
                   max_query_len=store.max_query_len,
                   _store=store, _planner=planner, **kw)

    @classmethod
    def create(cls, name: str, codes, *, root: Optional[str] = None,
               is_dna: Optional[bool] = None, max_query_len: int = 128,
               overwrite: bool = False, **kw) -> "SuffixTable":
        """Build AND persist version 1 of a named table under ``root``,
        registering it in the root's :class:`Catalog`."""
        import shutil
        from repro.api.catalog import Catalog
        _check_name(name)
        root = root or default_root()
        catalog = Catalog(root)
        table_dir = os.path.join(root, name)
        if name in catalog or os.path.isdir(table_dir):
            if not overwrite:
                raise FileExistsError(
                    f"table {name!r} already exists in {root!r} — "
                    f"SuffixTable.open() it, or pass overwrite=True")
            # drop stale snapshots: a survivor with a higher step would
            # shadow (or GC) the fresh version-1 save below
            shutil.rmtree(table_dir, ignore_errors=True)
        codes, is_dna = _as_codes(codes, is_dna)
        table = cls(codes, cls._build_sa_for(codes, max_query_len, is_dna),
                    is_dna=is_dna, max_query_len=max_query_len,
                    name=name, root=root, version=1, **kw)
        table._persist()
        catalog.register(name, {"is_dna": table.is_dna,
                                "max_query_len": table.max_query_len})
        return table

    @classmethod
    def open(cls, name: str, *, root: Optional[str] = None,
             **kw) -> "SuffixTable":
        """Restore the latest persisted version of ``name`` on the current
        device count (the saved SA is re-padded; no rebuild).  Un-compacted
        appends saved by :meth:`flush` are restored into the memtable."""
        _check_name(name)
        root = root or default_root()
        table_dir = os.path.join(root, name)
        if not os.path.isdir(table_dir):        # before CheckpointManager:
            raise FileNotFoundError(            # its ctor mkdirs the path
                f"no table {name!r} under {root!r}")
        mgr = CheckpointManager(table_dir)
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no persisted version of table {name!r} under {root!r}")
        arrays, extra = mgr.restore_arrays(step)
        arrays = _named_arrays(arrays)
        table = cls(arrays["codes"], arrays["sa_real"],
                    is_dna=bool(extra["is_dna"]),
                    max_query_len=int(extra["max_query_len"]),
                    name=name, root=root, version=int(extra["version"]),
                    **kw)
        mem = arrays.get("mem_codes")
        if mem is not None and mem.size:
            table.memtable.append(mem)
        return table

    @staticmethod
    def _build_sa_for(codes: np.ndarray, max_query_len: int,
                      is_dna: bool) -> np.ndarray:
        """Real-row SA over ``codes`` — distributed over the local mesh
        when >1 device is visible, single-device otherwise."""
        n_dev = len(jax.devices())
        if n_dev > 1:
            mesh = make_tablet_mesh(n_dev)
            store = build_tablet_store(codes, is_dna=is_dna,
                                       max_query_len=max_query_len,
                                       mesh=mesh, axis_name="tablets")
            return np.asarray(store.sa)[store.pad_count:]
        return np.asarray(build_suffix_array(codes.astype(np.int32)))

    def _attach(self, codes: np.ndarray, sa_real: np.ndarray) -> None:
        """(Re)build the runtime store + planner for the current mesh."""
        p = 1 if self.mesh is None else int(
            np.prod([self.mesh.shape[a] for a in self.mesh.axis_names]))
        self.store = store_from_arrays(
            codes, sa_real, is_dna=self.is_dna,
            max_query_len=self.max_query_len, num_tablets=p)
        self.planner = ScanPlanner(
            self.store, mesh=self.mesh, cache_size=self.cache_size,
            capacity_factor=self.capacity_factor,
            routed_min_batch=self.routed_min_batch)

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        """Total indexed symbols: base + un-compacted appends."""
        return int(self._codes.shape[0]) + self.memtable.size

    @property
    def n_base(self) -> int:
        return int(self._codes.shape[0])

    @property
    def is_persistent(self) -> bool:
        return self._manager is not None

    def stats(self) -> dict:
        return {"name": self.name, "version": self.version,
                "n_base": self.n_base, "memtable_rows": self.memtable.size,
                "is_dna": self.is_dna, "planner": self.planner.stats.as_dict()}

    def _sa(self) -> np.ndarray:
        # the planner already caches a host copy of the same store.sa —
        # don't materialize a second one per table
        return self.planner._sa()

    # -- read path -----------------------------------------------------------
    def scan_encoded(self, patt, plen, *, mode: Optional[str] = None
                     ) -> MatchResult:
        """Exact merged scan of an encoded batch (see ``ScanPlanner.
        scan_encoded`` for encodings).  With an empty memtable this is a
        pure delegation; otherwise ``count`` adds the memtable-only
        occurrences, and ``first_pos`` of a base miss becomes the smallest
        straddle/append position.  ``first_rank`` always refers to the
        BASE suffix array (−1 when the only matches are in the memtable)
        — do not feed a merged result to ``planner.positions_from_result``,
        use :meth:`scan`/:meth:`locate` for merged enumeration."""
        base = self.planner.scan_encoded(patt, plen, mode=mode)
        if self.memtable.size == 0:
            return base
        extra = self.memtable.match_positions(patt, plen)
        count = np.asarray(base.count).astype(np.int64)
        first_pos = np.asarray(base.first_pos).astype(np.int64)
        for i, g in enumerate(extra):
            if g.size:
                count[i] += g.size
                if first_pos[i] < 0:
                    first_pos[i] = int(g[0])
        found = count > 0
        return MatchResult(found=jnp.asarray(found),
                           count=jnp.asarray(count),
                           first_rank=base.first_rank,
                           first_pos=jnp.asarray(first_pos))

    def scan(self, patterns: list[str], top_k: int = 0) -> ScanOutcome:
        """String-level merged scan with **text-order** semantics: exact
        ``count``; ``first_pos`` is the smallest occurrence position;
        ``positions`` (when ``top_k > 0``) are the ``top_k`` smallest
        occurrence start positions, ascending, −1-padded — the complete
        set whenever ``count <= top_k``.  (The planner's own string API
        instead reports suffix-rank order over the base only.)  Results
        are LRU-cached; the cache is dropped on :meth:`append` /
        :meth:`compact`."""
        B = len(patterns)
        count = np.zeros(B, np.int64)
        first_pos = np.full(B, -1, np.int64)
        positions = (np.full((B, top_k), -1, np.int64) if top_k else None)
        miss_idx: list[int] = []
        for i, pat in enumerate(patterns):
            hit = self._cache.get(pat, top_k)
            if hit is not None:
                count[i], first_pos[i] = hit[0], hit[1]
                if top_k:
                    positions[i] = hit[2]
            else:
                miss_idx.append(i)
        if miss_idx:
            patt, plen = self.planner.encode([patterns[i] for i in miss_idx])
            base = self.planner.scan_encoded(patt, plen)
            extra = self.memtable.match_positions(patt, plen)
            base_count = np.asarray(base.count).astype(np.int64)
            base_rank = np.asarray(base.first_rank)
            sa, pad = self._sa(), self.store.pad_count
            for j, i in enumerate(miss_idx):
                run = np.zeros((0,), np.int64)
                cb = int(base_count[j])
                if cb > 0 and base_rank[j] >= 0:
                    lb = pad + int(base_rank[j])
                    run = sa[lb:lb + cb].astype(np.int64)
                g = extra[j]
                count[i] = cb + g.size
                firsts = ([int(run.min())] if run.size else []) + \
                    ([int(g[0])] if g.size else [])
                if firsts:
                    first_pos[i] = min(firsts)
                row = None
                if top_k:
                    cand = np.concatenate([run, g])
                    if cand.size > top_k:
                        cand = np.partition(cand, top_k - 1)[:top_k]
                    cand.sort()
                    row = np.full(top_k, -1, np.int64)
                    row[:cand.size] = cand
                    positions[i] = row
                self._cache.put(patterns[i], int(count[i]),
                                int(first_pos[i]), top_k, row)
        return ScanOutcome(found=count > 0, count=count,
                           first_pos=first_pos, positions=positions)

    def count(self, patterns: list[str]) -> np.ndarray:
        """Exact occurrence counts, (B,) int64."""
        return self.scan(patterns).count

    def contains(self, patterns: list[str]) -> np.ndarray:
        """Per-pattern membership, (B,) bool."""
        return self.scan(patterns).found

    def locate(self, patterns: list[str], top_k: int = 8) -> np.ndarray:
        """Up to ``top_k`` smallest occurrence positions per pattern,
        ascending, (B, top_k) int64, −1-padded."""
        return self.scan(patterns, top_k=top_k).positions

    # -- write path ----------------------------------------------------------
    def append(self, codes) -> int:
        """Append text to the table (memtable write path); visible to all
        subsequent reads with exact merged counts.  Returns the memtable
        size; triggers :meth:`compact` at ``memtable_limit``."""
        if isinstance(codes, (str, bytes, bytearray)):
            if not self.is_dna:
                raise TypeError("string appends are DNA-only; pass a code "
                                "array for token tables")
            codes = codec.encode_dna(codes)
        self.memtable.append(codes)
        self._cache.clear()
        if (self.memtable_limit is not None
                and self.memtable.size >= self.memtable_limit):
            self.compact()
        return self.memtable.size

    def compact(self) -> int:
        """Fold the memtable into the base suffix array (full rebuild over
        the concatenated text — distributed when the table has a mesh),
        clear the memtable, bump and persist the version.  No-op on an
        empty memtable.  Returns the current version."""
        if self.memtable.size == 0:
            return self.version
        combined = np.concatenate(
            [self._codes, self.memtable.appended.astype(self._codes.dtype,
                                                        copy=False)])
        if self.mesh is not None and self._distributed_build:
            sa_real = self.__class__._build_sa_for(
                combined, self.max_query_len, self.is_dna)
        else:
            sa_real = np.asarray(
                build_suffix_array(combined.astype(np.int32)))
        self._codes = combined
        self._attach(combined, sa_real)
        self.memtable = Memtable(combined, is_dna=self.is_dna,
                                 max_query_len=self.max_query_len)
        self._cache.clear()
        self.version += 1
        self._persist()
        return self.version

    def flush(self) -> None:
        """Persist the current state — base arrays AND un-compacted
        memtable codes — without compacting (same version, re-published
        atomically).  :meth:`open` restores the memtable.  Raises on an
        in-memory table: durability is this method's entire contract."""
        if self._manager is None:
            raise RuntimeError(
                "flush() on a non-persistent table — build it with "
                "SuffixTable.create(...) to get durable storage")
        self._persist()

    def _persist(self) -> None:
        if self._manager is None:
            return
        pad = self.store.pad_count
        sa_real = np.asarray(self.store.sa)[pad:]
        state = {"codes": self._codes,
                 "sa_real": sa_real,
                 "mem_codes": self.memtable.appended}
        extra = {"kind": "suffix_table", "name": self.name,
                 "version": self.version, "is_dna": self.is_dna,
                 "max_query_len": self.max_query_len,
                 "n_base": self.n_base, "mem_len": self.memtable.size}
        self._manager.save(self.version, state, extra=extra)


# Back-compat: the pre-table spelling, one call deep.
def open_table(name: str, *, root: Optional[str] = None,
               **kw) -> SuffixTable:
    return SuffixTable.open(name, root=root, **kw)


TableLike = Union[SuffixTable, TabletStore]

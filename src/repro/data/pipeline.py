"""Data pipeline: deterministic synthetic streams + SA-dedup hook.

The token stream is a pure function of (seed, step) — iterator state IS the
step counter, which makes data-restart after preemption exact (the
checkpoint stores the step; no iterator pickling).  The dedup hook filters
documents through the TabletSA duplicate-span index (DESIGN.md §3) before
batching — the paper's technique sitting in the training input path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core import dedup as _dedup
from repro.core.tablet import build_tablet_store
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    dedup_min_len: int = 0          # >0 enables SA dedup of the doc pool
    dedup_threshold: float = 0.5


def synthetic_batch(cfg: ModelConfig, data: DataConfig, step: int) -> dict:
    """Batch for ``step`` — pure function of (seed, step)."""
    rng = np.random.default_rng((data.seed, step))
    B, S = data.global_batch, data.seq_len
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = rng.normal(size=(B, S, cfg.d_model)
                                     ).astype(np.float32)
        batch["labels"] = rng.integers(0, cfg.vocab_size, (B, S)
                                       ).astype(np.int32)
    else:
        batch["tokens"] = rng.integers(0, cfg.vocab_size, (B, S)
                                       ).astype(np.int32)
        if cfg.frontend == "vlm_stub":
            batch["patches"] = rng.normal(
                size=(B, cfg.num_patches, cfg.d_model)).astype(np.float32)
    return batch


def dna_corpus(n: int, seed: int = 0, dup_fraction: float = 0.0
               ) -> np.ndarray:
    """Synthetic DNA with optional planted duplicates (dedup benchmarks)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 4, size=n, dtype=np.uint8)
    if dup_fraction > 0:
        span = int(n * dup_fraction / 2)
        base[n - span:] = base[:span]            # plant an exact duplicate
    return base


def make_batch_iter(cfg: ModelConfig, data: DataConfig,
                    start_step: int = 0) -> Iterator[tuple[int, dict]]:
    step = start_step
    while True:
        yield step, synthetic_batch(cfg, data, step)
        step += 1


def dedup_token_pool(tokens: np.ndarray, doc_ids: np.ndarray,
                     min_len: int, threshold: float = 0.5) -> np.ndarray:
    """Filter a document pool through the TabletSA index: returns the keep
    mask over docs.  This is the paper's scan engine applied to LM data."""
    store = build_tablet_store(tokens.astype(np.int32), is_dna=False,
                               max_query_len=min_len)
    return _dedup.filter_duplicate_docs(store, doc_ids, min_len, threshold)

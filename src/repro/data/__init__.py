from repro.data.pipeline import (DataConfig, dna_corpus, make_batch_iter,
                                 synthetic_batch)

__all__ = ["DataConfig", "dna_corpus", "make_batch_iter", "synthetic_batch"]

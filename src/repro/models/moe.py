"""Mixture-of-Experts FFN (token-choice top-k, capacity dropping, shared
experts) — DeepSeek-V3 / Kimi-K2 / Jamba MoE blocks.

Dispatch is sort-based (GShard-style priority, choice-major so first
choices win slots): tokens are argsorted by expert id, positions within
each expert group come from a searchsorted start table, tokens beyond
capacity are dropped.  The expert buffers are (E, C, d) einsums — E shards
over the `model` mesh axis (expert parallelism); the scatter/gather at the
boundary is where GSPMD inserts the all_to_all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, init_mlp, mlp

# set by ep_sharding() below: mesh enabling the shard_map EP dispatch path
_EP_MESH = None


def init_moe(cfg: ModelConfig, key, dtype):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), d, jnp.float32),  # fp32 router
        "wi": _dense_init(ks[1], (E, d, f), d, dtype),
        "wg": _dense_init(ks[2], (E, d, f), d, dtype),
        "wo": _dense_init(ks[3], (E, f, d), f, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], dtype,
                               d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def moe_ffn(cfg: ModelConfig, p, x, shard=None):
    """x: (B, S, d) -> (out, aux_loss).  Capacity per expert is
    ceil(T * k / E * capacity_factor); dropped tokens pass through the
    shared expert (and residual) only.

    ``shard`` (the model-wide constraint callback) pins the dispatch
    buffers to P('model', data, None).  NOTE: GSPMD cannot partition the
    data-dependent dispatch scatter either way (see _moe_ffn_ep below,
    which is the production path whenever ``ep_sharding`` is active)."""
    # EP pays off when there is real token volume; at decode (T ~ batch)
    # the per-step FSDP weight gather dominates (measured 8x WORSE on
    # deepseek decode_32k), so small-T calls stay on the XLA path.
    if _EP_MESH is not None and cfg.num_experts % \
            _EP_MESH.shape.get("model", 1) == 0 \
            and x.shape[0] * x.shape[1] >= 4096:
        return _moe_ffn_ep(cfg, p, x, _EP_MESH)
    if shard is None:
        shard = lambda t, _n: t
    B, S, d = x.shape
    T = B * S
    k = cfg.experts_per_token
    E = cfg.num_experts
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                        # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # --- aux load-balance loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                # (E,)
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    # --- dispatch (choice-major priority)
    C = int(np.ceil(T * k / E * cfg.moe_capacity_factor))
    C = max(4, -(-C // 4) * 4)
    flat_e = idx.T.reshape(-1)                                  # (k*T,)
    flat_t = jnp.tile(jnp.arange(T, dtype=jnp.int32), k)
    flat_g = idx_gates = gates.T.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_s = flat_e[order]
    t_s = flat_t[order]
    g_s = flat_g[order]
    start = jnp.searchsorted(e_s, jnp.arange(E, dtype=jnp.int32),
                             side="left")
    pos = jnp.arange(k * T, dtype=jnp.int32) - start[e_s]
    keep = pos < C
    slot = jnp.where(keep, e_s * C + pos, E * C)                # drop -> off

    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[t_s], 0),
                           mode="drop")
    h = buf.reshape(E, C, d)

    # --- expert FFN (E sharded over `model` = EP; C over data)
    if cfg.mlp_act == "swiglu":
        z = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["wg"])) \
            * jnp.einsum("ecd,edf->ecf", h, p["wi"])
    else:
        z = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", h, p["wi"]))
    y = jnp.einsum("ecf,efd->ecd", z, p["wo"]).reshape(E * C, d)

    # --- combine
    back = jnp.where(keep[:, None], y[jnp.clip(slot, 0, E * C - 1)], 0)
    out = jnp.zeros((T, d), x.dtype)
    out = out.at[t_s].add(back * g_s[:, None].astype(x.dtype), mode="drop")

    if cfg.num_shared_experts:
        out = out + mlp(cfg, p["shared"], xt)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Explicit expert-parallel dispatch (shard_map) — the production path.
#
# GSPMD cannot partition the data-dependent dispatch scatter: it replicates
# the (E*C, d) buffers per device (~930 GB/dev on deepseek-v3 train_4k),
# and sharding constraints only add reshard copies (measured worse, see
# EXPERIMENTS.md §Perf iteration F).  The fix is structural: inside
# shard_map each model-axis shard owns E/tp experts and sees its data-row's
# tokens (already replicated over the model axis), scatters LOCALLY into an
# (E_local, C_local, d) buffer, runs its experts, and contributes a partial
# combine; one psum over the model axis completes the output.  No global
# scatter ever exists.  Enabled via ``ep_sharding(mesh)``.
# ---------------------------------------------------------------------------
class ep_sharding:
    """Context manager enabling the shard_map EP path during tracing."""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        global _EP_MESH
        self._saved = _EP_MESH
        _EP_MESH = self.mesh
        return self

    def __exit__(self, *exc):
        global _EP_MESH
        _EP_MESH = self._saved
        return False


def _moe_ffn_ep(cfg: ModelConfig, p, x, mesh):
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import data_axes

    d_axes = data_axes(mesh)
    m_size = mesh.shape["model"]
    dp = int(np.prod([mesh.shape[a] for a in d_axes])) if d_axes else 1
    E, k, d, f = (cfg.num_experts, cfg.experts_per_token, cfg.d_model,
                  cfg.moe_d_ff)
    E_local = E // m_size
    B, S, _ = x.shape

    def local_fn(x_loc, router, wi, wg, wo):
        # weights arrive (E_local, d/dp, f): FSDP-gather the d dim
        wi = lax.all_gather(wi, d_axes, axis=1, tiled=True)
        wg = lax.all_gather(wg, d_axes, axis=1, tiled=True) \
            if wg is not None else None
        wo = lax.all_gather(wo, d_axes, axis=2, tiled=True)
        Bl, S_, _ = x_loc.shape
        T = Bl * S_
        xt = x_loc.reshape(T, d)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32),
                      axis=0)
        aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

        tp = lax.axis_index("model")
        e0 = tp * E_local
        flat_e = idx.T.reshape(-1)
        flat_t = jnp.tile(jnp.arange(T, dtype=jnp.int32), k)
        local = (flat_e >= e0) & (flat_e < e0 + E_local)
        le = jnp.where(local, flat_e - e0, E_local)       # E_local = trash
        order = jnp.argsort(le, stable=True)
        le_s, t_s = le[order], flat_t[order]
        start = jnp.searchsorted(le_s, jnp.arange(E_local + 1,
                                                  dtype=jnp.int32))
        C = int(np.ceil(T * k / E * cfg.moe_capacity_factor))
        C = max(4, -(-C // 4) * 4)
        pos = jnp.arange(k * T, dtype=jnp.int32) - start[jnp.clip(
            le_s, 0, E_local)]
        keep = (le_s < E_local) & (pos < C)
        slot_sorted = jnp.where(keep, le_s * C + pos, E_local * C)
        # un-sort slots back to (choice-major) flat order, then dispatch
        # PER CHOICE: k scatters whose source is xt itself — the (k*T, d)
        # gathered copy (15 GB fp32 in backward at deepseek scale) never
        # exists (§Perf iteration F5).
        slot_flat = jnp.zeros((k * T,), jnp.int32).at[order].set(
            slot_sorted)
        buf = jnp.zeros((E_local * C + 1, d), x_loc.dtype)
        for j in range(k):
            sl = jnp.minimum(slot_flat[j * T:(j + 1) * T], E_local * C)
            buf = buf.at[sl].add(xt)
        buf = buf[:-1]                        # trash row collects drops
        h = buf.reshape(E_local, C, d)
        if cfg.mlp_act == "swiglu":
            z = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg)) \
                * jnp.einsum("ecd,edf->ecf", h, wi)
        else:
            z = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", h, wi))
        y = jnp.einsum("ecf,efd->ecd", z, wo).reshape(E_local * C, d)
        out = jnp.zeros((T, d), x_loc.dtype)
        for j in range(k):
            sl = slot_flat[j * T:(j + 1) * T]
            ok_j = sl < E_local * C
            contrib = jnp.where(ok_j[:, None],
                                y[jnp.clip(sl, 0, E_local * C - 1)], 0)
            out = out + contrib * gates[:, j:j + 1].astype(x_loc.dtype)
        out = lax.psum(out, "model")          # partial combines -> full
        return out.reshape(Bl, S_, d), jnp.full((1,), aux)

    in_specs = (P(d_axes, None, None), P(),
                P("model", d_axes, None), P("model", d_axes, None),
                P("model", None, d_axes))
    out_specs = (P(d_axes, None, None), P(d_axes))
    fn = compat.shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    out, aux = fn(x, p["router"], p["wi"],
                  p.get("wg"), p["wo"])
    total = out
    if cfg.num_shared_experts:
        total = total + mlp(cfg, p["shared"], x.reshape(-1, d)
                            ).reshape(B, S, d)
    return total, jnp.mean(aux)

"""Architecture config schema for every assigned model family.

One frozen dataclass covers dense/GQA, MLA, MoE, SSM (Mamba2 SSD), hybrid
(Jamba), audio-backbone and VLM-backbone variants.  ``reduced()`` derives
the CPU smoke-test config of the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention flavour
    attn_type: str = "gqa"           # gqa | mla | none
    rope_theta: float = 10000.0
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5
    mlp_act: str = "swiglu"          # swiglu | gelu

    # MLA (deepseek-v3 / kimi-k2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek: first 3 layers dense
    moe_every: int = 1               # jamba: MoE every 2nd layer
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # hybrid / SSM
    attn_every: int = 0              # jamba: 1 attention layer per 8
    attn_offset: int = 4             # which slot in the period is attention
    ssm_state: int = 0               # mamba2 N
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # multi-token prediction (deepseek-v3)
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3

    # modality frontend (STUB per spec: precomputed embeddings)
    frontend: str = "none"           # none | audio_stub | vlm_stub
    num_patches: int = 0             # vlm: vision tokens prepended

    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    max_query_len: int = 0           # unused by LMs; SA engine configs only

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))
        if self.attn_type == "mla":
            if self.v_head_dim == 0:
                object.__setattr__(self, "v_head_dim", self.head_dim)

    # ---- derived -----------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.attn_type == "none"

    @property
    def is_hybrid(self) -> bool:
        return self.attn_every > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for the mixer of layer i."""
        if self.is_ssm_only:
            return "ssm"
        if self.is_hybrid:
            return "attn" if i % self.attn_every == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if not self.is_moe:
            return False
        if i < self.first_dense_layers:
            return False
        return i % self.moe_every == (self.moe_every - 1)

    @property
    def period(self) -> int:
        """Length of the repeating layer pattern (scan unit)."""
        import math
        p = 1
        if self.is_hybrid:
            p = self.attn_every
        if self.is_moe and self.moe_every > 1:
            p = p * self.moe_every // math.gcd(p, self.moe_every)
        return p

    def param_count(self) -> int:
        """Approximate total parameter count (used for 6ND roofline)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        total = V * d * (1 if self.tie_embeddings else 2)
        for i in range(L):
            kind = self.layer_kind(i)
            if kind == "attn":
                if self.attn_type == "mla":
                    qh = self.head_dim + self.rope_head_dim
                    q = (d * self.q_lora_rank
                         + self.q_lora_rank * self.num_heads * qh
                         ) if self.q_lora_rank else d * self.num_heads * qh
                    kv = (d * (self.kv_lora_rank + self.rope_head_dim)
                          + self.kv_lora_rank * self.num_heads
                          * (self.head_dim + self.v_head_dim))
                    o = self.num_heads * self.v_head_dim * d
                    total += q + kv + o
                else:
                    total += d * self.num_heads * self.head_dim  # q
                    total += 2 * d * self.num_kv_heads * self.head_dim
                    total += self.num_heads * self.head_dim * d  # o
            else:
                di, N = self.d_inner, self.ssm_state
                total += d * (2 * di + 2 * N + self.ssm_heads)  # in_proj
                total += di * d                                  # out_proj
                total += (di + 2 * N) * self.ssm_conv            # conv
            # FFN: MoE, dense, or absent (pure-SSM blocks have none)
            n_mults = 3 if self.mlp_act == "swiglu" else 2
            if self.layer_is_moe(i):
                total += (self.num_experts + self.num_shared_experts) \
                    * n_mults * d * self.moe_d_ff
                total += d * self.num_experts                    # router
            elif f > 0:
                total += n_mults * d * f
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed-in experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        n_mults = 3 if self.mlp_act == "swiglu" else 2
        per_expert = n_mults * self.d_model * self.moe_d_ff
        n_moe_layers = sum(self.layer_is_moe(i)
                           for i in range(self.num_layers))
        inactive = n_moe_layers * per_expert * \
            (self.num_experts - self.experts_per_token)
        return full - inactive

    # ---- smoke-test reduction -----------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family/code paths, laptop-sized."""
        changes = dict(
            num_layers=min(self.num_layers, self.period * 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            num_patches=min(self.num_patches, 8),
        )
        if self.attn_type == "mla":
            changes.update(q_lora_rank=64 if self.q_lora_rank else 0,
                           kv_lora_rank=32, rope_head_dim=16, v_head_dim=32)
        if self.is_moe:
            changes.update(num_experts=8, experts_per_token=2, moe_d_ff=64,
                           first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
        if self.mtp_depth:
            changes.update(mtp_depth=1)
        return dataclasses.replace(self, **changes)

"""Mamba-2 block via the SSD (state-space duality) algorithm
[arXiv:2405.21060], JAX port of the paper's minimal chunked formulation.

Train/prefill: chunked SSD — intra-chunk quadratic (attention-like) term +
inter-chunk recurrent state passed through a cumulative-decay scan.
Decode: O(1) recurrent state update (the SSM superpower; this is why
mamba2/jamba run the long_500k cell while full-attention archs skip it).

Shapes follow the paper: d_inner = expand*d_model, heads = d_inner/headdim,
single B/C group (G=1), scalar-per-head A.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, init_rmsnorm, rmsnorm


def init_ssm(cfg: ModelConfig, key, dtype):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 5)
    return {
        # fused input projection: [z (di), xBC (di+2N), dt (H)]
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * N + H), d, dtype),
        "conv_w": _dense_init(ks[1], (cfg.ssm_conv, conv_ch),
                              cfg.ssm_conv, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log)
        "dt_bias": jnp.full((H,), np.log(np.e - 1), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": _dense_init(ks[2], (di, d), di, dtype),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv, kernel K (static small): u (B,S,C), w (K,C)."""
    K = w.shape[0]
    out = jnp.zeros_like(u)
    for i in range(K):
        shift = K - 1 - i
        if shift == 0:
            out = out + u * w[i]
        else:
            out = out + jnp.pad(u, ((0, 0), (shift, 0), (0, 0))
                                )[:, :-shift] * w[i]
    return out + b


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums:
    out[i, j] = sum_{j < s <= i} a[s], -inf above diagonal."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, shard=None):
    """SSD forward.  x: (b, s, h, p); dt: (b, s, h) (discretization step,
    post-softplus); A: (h,) negative; B, C: (b, s, n).
    Returns (y (b,s,h,p), final_state (b,h,p,n)).

    ``shard`` is accepted for API parity; constraint experiments on the
    SSD intermediates measured NEGATIVE (reshard copies, EXPERIMENTS.md
    §Perf iteration G) so none are applied."""
    if shard is None:
        shard = lambda t, _n: t
    b, s, h, pdim = x.shape
    n = B.shape[-1]
    s_orig = s
    if s % chunk:
        # pad with dt=0 steps: decay=1, zero input -> state untouched
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    # chunked views
    xc = x.reshape(b, nc, chunk, h, pdim)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    dA = dtc * A[None, None, None, :]                    # (b,nc,Q,h) log decay
    dA = jnp.moveaxis(dA, -1, 2)                         # (b,nc,h,Q)
    xbar = xc * dtc[..., None]                           # dt-weighted input

    # ---- intra-chunk (quadratic attention-like term)
    L = jnp.exp(_segsum(dA))                             # (b,nc,h,Q,Q)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)       # (b,nc,Q,Q)
    y_intra = jnp.einsum("bcls,bchls,bcshp->bclhp",
                         scores, L, xbar)

    # ---- chunk final states (decay from step s+1 .. chunk end)
    cums = jnp.cumsum(dA, axis=-1)
    decay_to_end = jnp.exp(cums[..., -1:] - cums)        # (b,nc,h,Q)
    states = jnp.einsum("bcsn,bchs,bcshp->bchpn",
                        Bc, decay_to_end, xbar)          # (b,nc,h,p,n)

    # ---- inter-chunk scan over nc
    chunk_decay = jnp.exp(cums[..., -1])                 # (b,nc,h)

    def scan_fn(prev, inp):
        st, dec = inp                                    # (b,h,p,n), (b,h)
        new = prev * dec[..., None, None] + st
        return new, prev                                 # emit state BEFORE

    init = jnp.zeros((b, h, pdim, n), x.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b,nc,h,p,n)

    # ---- inter-chunk contribution
    decay_from_start = jnp.exp(cums)                     # (b,nc,h,Q)
    y_inter = jnp.einsum("bcln,bchl,bchpn->bclhp",
                         Cc, decay_from_start, prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    return y[:, :s_orig], final


def ssm_block(cfg: ModelConfig, p, x, *, state=None, shard=None):
    """Full Mamba-2 mixer.  Train/prefill: state None.
    Decode: state = {"conv": (B, K-1, C_ch), "ssm": (B, H, P, N), ...}."""
    B_, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    proj = x @ p["in_proj"]                               # (B,S,2di+2N+H)
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                  # (B,S,H)
    A = -jnp.exp(p["A_log"])                              # (H,)

    if state is None:
        xBC_raw = xBC                        # conv cache stores PRE-conv taps
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        xs, Bmat, Cmat = jnp.split(xBC, [di, di + N], axis=-1)
        xh = xs.reshape(B_, S, H, P)
        y, final = ssd_chunked(xh.astype(jnp.float32), dt,
                               A, Bmat.astype(jnp.float32),
                               Cmat.astype(jnp.float32), cfg.ssm_chunk,
                               shard=shard)
        y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
        new_state = {"ssm": final,
                     "conv": xBC_raw[:, -(cfg.ssm_conv - 1):, :]}
    else:
        # decode: S == 1
        conv_in = jnp.concatenate([state["conv"], xBC], axis=1)
        xBC = jax.nn.silu(
            jnp.sum(conv_in * p["conv_w"], axis=1, keepdims=True)
            + p["conv_b"])
        xs, Bmat, Cmat = jnp.split(xBC, [di, di + N], axis=-1)
        xh = xs.reshape(B_, 1, H, P).astype(jnp.float32)
        dA = jnp.exp(dt[:, 0] * A[None, :])               # (B,H)
        xbar = xh[:, 0] * dt[:, 0, :, None]               # (B,H,P)
        st = state["ssm"] * dA[..., None, None] \
            + jnp.einsum("bhp,bn->bhpn", xbar, Bmat[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cmat[:, 0].astype(jnp.float32), st)
        y = (y + xh[:, 0] * p["D"][None, :, None])[:, None]
        new_state = {"ssm": st, "conv": conv_in[:, 1:, :]}

    y = y.reshape(B_, S, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"], new_state

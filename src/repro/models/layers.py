"""Shared transformer layers: RMSNorm, RoPE, GQA/MHA attention, MLA, MLPs.

Functional style: params are nested dicts of jnp arrays; ``init_*`` builds
them, ``apply``-style functions consume them.  Everything is jit/pjit
friendly and dtype-polymorphic (params may be fp32 or bf16; softmax and
norms accumulate in fp32).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2 / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, dh) with dh even; positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta))                    # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MHA)
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key, dtype):
    d, H, KV, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, dh), d, dtype),
        "wk": _dense_init(ks[1], (d, KV, dh), d, dtype),
        "wv": _dense_init(ks[2], (d, KV, dh), d, dtype),
        "wo": _dense_init(ks[3], (H, dh, d), H * dh, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, dh), dtype)
        p["bk"] = jnp.zeros((KV, dh), dtype)
        p["bv"] = jnp.zeros((KV, dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, dtype)
        p["k_norm"] = init_rmsnorm(dh, dtype)
    return p


# KV-chunked online-softmax attention kicks in above this sequence length:
# never materialize (Sq, Sk) score tensors for long prefill (DESIGN.md §5).
ATTN_CHUNK_THRESHOLD = 8192
ATTN_KV_CHUNK = 2048


class attn_chunking:
    """Context manager overriding the chunking policy (perf experiments):
    ``with attn_chunking(threshold=4096, chunk=1024): ...``"""

    def __init__(self, threshold: int, chunk: int):
        self.t, self.c = threshold, chunk

    def __enter__(self):
        global ATTN_CHUNK_THRESHOLD, ATTN_KV_CHUNK
        self._saved = (ATTN_CHUNK_THRESHOLD, ATTN_KV_CHUNK)
        ATTN_CHUNK_THRESHOLD, ATTN_KV_CHUNK = self.t, self.c
        return self

    def __exit__(self, *exc):
        global ATTN_CHUNK_THRESHOLD, ATTN_KV_CHUNK
        ATTN_CHUNK_THRESHOLD, ATTN_KV_CHUNK = self._saved
        return False


def _sdpa(q, k, v, *, causal: bool, q_offset=0, kv_len_mask=None):
    """q: (B, Sq, H, dh), k/v: (B, Sk, KV, dv) with H % KV == 0.
    fp32 softmax; returns (B, Sq, H, dv).  For Sk above the chunking
    threshold the KV axis is processed in online-softmax chunks (flash-
    attention recurrence) so peak memory is O(Sq x chunk), not O(Sq x Sk).
    """
    Sk = k.shape[1]
    Sq = q.shape[1]
    # Chunking pays only when the (Sq, Sk) score tensor is the problem.
    # Decode (Sq == 1) scores are (B, H, Sk) — small; the chunk scan's
    # reshape/moveaxis of the cache costs more than it saves (measured:
    # the decode_32k memory term dropped ~10x switching to dense, see
    # EXPERIMENTS.md §Perf).
    if (Sq > 1 and Sq * Sk >= ATTN_CHUNK_THRESHOLD ** 2
            and Sk % ATTN_KV_CHUNK == 0):
        return _sdpa_chunked(q, k, v, causal=causal, q_offset=q_offset,
                             kv_len_mask=kv_len_mask,
                             chunk=ATTN_KV_CHUNK)
    return _sdpa_dense(q, k, v, causal=causal, q_offset=q_offset,
                       kv_len_mask=kv_len_mask)


def _sdpa_dense(q, k, v, *, causal: bool, q_offset=0, kv_len_mask=None):
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    dv = v.shape[3]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, dh)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(dh)
    Sk = k.shape[1]
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos                                   # (Sq, Sk)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len_mask is not None:                               # (B, Sk) valid
        logits = jnp.where(kv_len_mask[:, None, None, None, :],
                           logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


def _sdpa_chunked(q, k, v, *, causal: bool, q_offset=0, kv_len_mask=None,
                  chunk: int = ATTN_KV_CHUNK):
    """Online-softmax over KV chunks (the flash-attention recurrence in
    pure lax.scan form — the TPU-native replacement for a CUDA kernel)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[3]
    rep = H // KV
    nc = Sk // chunk
    qg = q.reshape(B, Sq, KV, rep, dh).astype(jnp.float32) / np.sqrt(dh)

    kc = jnp.moveaxis(k.reshape(B, nc, chunk, KV, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, KV, dv), 1, 0)
    starts = jnp.arange(nc, dtype=jnp.int32) * chunk
    if kv_len_mask is not None:
        mc = jnp.moveaxis(kv_len_mask.reshape(B, nc, chunk), 1, 0)
    else:
        mc = jnp.ones((nc, B, chunk), bool)
    qpos = q_offset + jnp.arange(Sq, dtype=jnp.int32)

    def step(carry, xs):
        m, l, acc = carry                    # (B,KV,rep,Sq), ..., (..., dv)
        kb, vb, k0, mb = xs
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg,
                            kb.astype(jnp.float32))
        kpos = k0 + jnp.arange(chunk, dtype=jnp.int32)
        valid = mb[:, None, None, None, :]
        if causal:
            valid = valid & (kpos[None, None, None, None, :]
                             <= qpos[None, None, None, :, None])
        logits = jnp.where(valid, logits, -1e30)
        m_blk = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, rep, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, rep, Sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, starts, mc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, dv)
    return out.astype(q.dtype)


def attention(cfg: ModelConfig, p, x, positions, *, kv_cache=None,
              kv_len_mask=None):
    """Causal self-attention.  Training/prefill: kv_cache None -> full seq.
    Decode: kv_cache = dict(k (B,S,KV,dh), v, length scalar) -> one step;
    returns (out, new_cache)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        out = _sdpa(q, k, v, causal=True)
        new_cache = {"k": k, "v": v}
    else:
        length = kv_cache["length"]                 # tokens already cached
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, length, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, length, 1)
        S = ck.shape[1]
        valid = jnp.arange(S)[None, :] < (length + q.shape[1])
        out = _sdpa(q, ck, cv, causal=True, q_offset=length,
                    kv_len_mask=jnp.broadcast_to(valid, (x.shape[0], S)))
        new_cache = {"k": ck, "v": cv, "length": length + q.shape[1]}
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 family): low-rank Q/KV with decoupled RoPE, compressed
# KV cache, absorbed decode path.
# ---------------------------------------------------------------------------
def init_mla(cfg: ModelConfig, key, dtype):
    d, H = cfg.d_model, cfg.num_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if r_q:
        p["wq_a"] = _dense_init(ks[0], (d, r_q), d, dtype)
        p["q_a_norm"] = init_rmsnorm(r_q, dtype)
        p["wq_b"] = _dense_init(ks[1], (r_q, H, dn + dr), r_q, dtype)
    else:
        p["wq"] = _dense_init(ks[1], (d, H, dn + dr), d, dtype)
    p["wkv_a"] = _dense_init(ks[2], (d, r_kv + dr), d, dtype)
    p["kv_a_norm"] = init_rmsnorm(r_kv, dtype)
    p["wk_b"] = _dense_init(ks[3], (r_kv, H, dn), r_kv, dtype)
    p["wv_b"] = _dense_init(ks[4], (r_kv, H, dv), r_kv, dtype)
    p["wo"] = _dense_init(ks[5], (H, dv, d), H * dv, dtype)
    return p


def _mla_q(cfg, p, x):
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
        cq = rmsnorm(p["q_a_norm"], cq, cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    return jnp.split(q, [cfg.head_dim], axis=-1)   # q_nope, q_rope


def mla_attention(cfg: ModelConfig, p, x, positions, *, kv_cache=None):
    """Prefill/train path: materialized K/V (cache stays compressed).
    Decode path (kv_cache given): absorbed attention over latent cache.
    Cache layout: {"ckv": (B, S, r_kv), "krope": (B, S, dr), "length"}."""
    B, S, _ = x.shape
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    ckv = rmsnorm(p["kv_a_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]

    if kv_cache is None:
        # materialized: k = [W_uk ckv ; k_rope], v = W_uv ckv
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_b"])
        v = jnp.einsum("bsr,rhv->bshv", ckv, p["wv_b"])
        H = cfg.num_heads
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        # pad v to head_dim of q/k for _sdpa reuse? keep separate einsum:
        out = _sdpa_mla(q, k, v)
        new_cache = {"ckv": ckv, "krope": k_rope}
    else:
        length = kv_cache["length"]
        cc = jax.lax.dynamic_update_slice_in_dim(kv_cache["ckv"], ckv,
                                                 length, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(kv_cache["krope"], k_rope,
                                                 length, 1)
        # absorbed: q_lat = q_nope @ W_uk  (B,S,H,r)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
        Sc = cc.shape[1]
        logits = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                             cc.astype(jnp.float32))
                  + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                               cr.astype(jnp.float32)))
        logits = logits / np.sqrt(dn + dr)
        qpos = length + jnp.arange(S)[:, None]
        valid = (jnp.arange(Sc)[None, :] <= qpos)               # causal+len
        logits = jnp.where(valid[None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        lat_out = jnp.einsum("bhst,btr->bshr", w, cc.astype(jnp.float32))
        out = jnp.einsum("bshr,rhv->bshv", lat_out.astype(x.dtype),
                         p["wv_b"])
        new_cache = {"ckv": cc, "krope": cr, "length": length + S}
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return out, new_cache


def _sdpa_mla(q, k, v):
    """MHA with distinct q/k dim vs v dim (MLA materialized path) — routed
    through the shared (chunk-capable) attention with KV == H, rep == 1."""
    return _sdpa(q, k, v, causal=True)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {"wi": _dense_init(ks[0], (d, f), d, dtype),
                "wg": _dense_init(ks[1], (d, f), d, dtype),
                "wo": _dense_init(ks[2], (f, d), f, dtype)}
    return {"wi": _dense_init(ks[0], (d, f), d, dtype),
            "wo": _dense_init(ks[2], (f, d), f, dtype)}


def mlp(cfg: ModelConfig, p, x):
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"]

from repro.models import config, layers, moe, ssm, transformer
from repro.models.config import ModelConfig
from repro.models.transformer import (decode_step, forward_train,
                                      init_decode_caches, init_params,
                                      prefill)

__all__ = ["ModelConfig", "config", "decode_step", "forward_train",
           "init_decode_caches", "init_params", "layers", "moe", "prefill",
           "ssm", "transformer"]

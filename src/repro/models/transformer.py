"""Decoder stacks for all assigned architectures.

Layer layout: an optional *prefix* of unrolled layers (DeepSeek's first
dense layers) followed by a ``lax.scan`` over *periods* — the repeating
structural unit (1 for homogeneous stacks, 8 for Jamba's [7 mamba + 1 attn]
interleave with alternating MoE).  Stacked params keep the HLO compact at
61-layer/671B scale, which is what makes the 512-device dry-run compile.

Three entry points per model: ``forward_train`` (full-seq logits/loss),
``prefill`` (logits + caches), ``decode_step`` (one token against caches).
Sharding is injected via an optional ``shard`` callback (logical-name ->
with_sharding_constraint), keeping model code mesh-agnostic.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as Ls
from repro.models import moe as Moe
from repro.models import ssm as Ssm
from repro.models.config import ModelConfig

ShardFn = Callable[[jnp.ndarray, str], jnp.ndarray]


def _noshard(x, _name):
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, i: int, key, dtype):
    kind = cfg.layer_kind(i)
    ks = jax.random.split(key, 3)
    p: dict[str, Any] = {"ln1": Ls.init_rmsnorm(cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.attn_type == "mla":
            p["attn"] = Ls.init_mla(cfg, ks[0], dtype)
        else:
            p["attn"] = Ls.init_attention(cfg, ks[0], dtype)
    else:
        p["ssm"] = Ssm.init_ssm(cfg, ks[0], dtype)
    if cfg.layer_is_moe(i):
        p["ln2"] = Ls.init_rmsnorm(cfg.d_model, dtype)
        p["moe"] = Moe.init_moe(cfg, ks[1], dtype)
    elif cfg.d_ff > 0:
        p["ln2"] = Ls.init_rmsnorm(cfg.d_model, dtype)
        p["mlp"] = Ls.init_mlp(cfg, ks[1], dtype)
    return p


def _stack_info(cfg: ModelConfig):
    prefix = cfg.first_dense_layers
    period = cfg.period
    rest = cfg.num_layers - prefix
    assert rest % period == 0, (cfg.name, rest, period)
    return prefix, period, rest // period


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    prefix, period, n_periods = _stack_info(cfg)
    keys = jax.random.split(key, 4 + prefix + period * n_periods)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dtype),
        "ln_f": Ls.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = Ls._dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), cfg.d_model, dtype)
    params["prefix"] = [
        _init_layer(cfg, i, keys[4 + i], dtype) for i in range(prefix)]
    # stacked periods: for each position in the period, stack n_periods inits
    stack = []
    for pos in range(period):
        per = [_init_layer(cfg, prefix + c * period + pos,
                           keys[4 + prefix + c * period + pos], dtype)
               for c in range(n_periods)]
        stack.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    params["stack"] = stack
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": Ls._dense_init(keys[2], (2 * cfg.d_model, cfg.d_model),
                                   2 * cfg.d_model, dtype),
            "ln": Ls.init_rmsnorm(cfg.d_model, dtype),
            "layer": _init_layer(cfg, cfg.num_layers - 1, keys[3], dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------
def _apply_layer(cfg: ModelConfig, layer_idx_kindinfo, p, x, positions,
                 cache, shard: ShardFn):
    """cache: None (train) | dict (prefill collects / decode consumes)."""
    kind, is_moe = layer_idx_kindinfo
    h = Ls.rmsnorm(p["ln1"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        if cfg.attn_type == "mla":
            mix, new_cache = Ls.mla_attention(cfg, p["attn"], h, positions,
                                              kv_cache=cache)
        else:
            mix, new_cache = Ls.attention(cfg, p["attn"], h, positions,
                                          kv_cache=cache)
    else:
        mix, new_cache = Ssm.ssm_block(cfg, p["ssm"], h, state=cache,
                                       shard=shard)
    x = x + mix
    x = shard(x, "act")
    if "moe" in p:
        h2 = Ls.rmsnorm(p["ln2"], x, cfg.norm_eps)
        f, aux = Moe.moe_ffn(cfg, p["moe"], h2, shard=shard)
        x = x + f
    elif "mlp" in p:
        h2 = Ls.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + Ls.mlp(cfg, p["mlp"], h2)
    x = shard(x, "act")
    return x, new_cache, aux


def _period_kinds(cfg: ModelConfig):
    prefix, period, _ = _stack_info(cfg)
    return [(cfg.layer_kind(prefix + pos),
             cfg.layer_is_moe(prefix + pos)) for pos in range(period)]


def _run_stack(cfg: ModelConfig, params, x, positions, caches,
               shard: ShardFn, collect_cache: bool, remat: bool = False,
               scan_unroll: int | bool = 1):
    """Prefix layers unrolled, then scan over periods.

    Modes: train (caches=None, collect_cache=False), prefill (caches=None,
    collect_cache=True -> caches emitted), decode (caches given -> updated).
    ``caches``: {"prefix": [per-layer], "stack": [stacked per period-pos]}.
    """
    prefix, period, n_periods = _stack_info(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix_caches = []
    for i, p in enumerate(params["prefix"]):
        c = caches["prefix"][i] if caches else None
        x, nc, aux = _apply_layer(
            cfg, (cfg.layer_kind(i), cfg.layer_is_moe(i)), p, x,
            positions, c, shard)
        aux_total += aux
        new_prefix_caches.append(nc)

    kinds = _period_kinds(cfg)

    def period_body(h, auxc, stacked_p, stacked_c, layer_remat=False):
        new_cs = []
        for pos in range(period):
            c = stacked_c[pos] if stacked_c is not None else None
            if layer_remat and c is None:
                # nested per-layer remat: without it the backward of a
                # period-8 hybrid block holds 7 Mamba layers' SSD
                # intermediates at once (measured 350 GB/dev on jamba
                # train_4k; ~20x less with this).
                def one(p_, h_, _pos=pos):
                    y, _, aux = _apply_layer(cfg, kinds[_pos], p_, h_,
                                             positions, None, shard)
                    return y, aux
                h, aux = jax.checkpoint(one, prevent_cse=False)(
                    stacked_p[pos], h)
                nc = None
            else:
                h, nc, aux = _apply_layer(cfg, kinds[pos], stacked_p[pos], h,
                                          positions, c, shard)
            auxc = auxc + aux
            new_cs.append(nc)
        return h, auxc, new_cs

    new_stack_caches = None
    if n_periods:
        if caches is None and not collect_cache:        # --- train
            def body(carry, p_):
                h, auxc, _ = period_body(*carry, p_, None,
                                         layer_remat=remat and period > 1)
                return (h, auxc), None
            if remat:
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), params["stack"],
                unroll=scan_unroll)
        elif caches is None:                            # --- prefill
            def body(carry, p_):
                h, auxc, cs = period_body(*carry, p_, None)
                return (h, auxc), cs
            (x, aux_total), new_stack_caches = jax.lax.scan(
                body, (x, aux_total), params["stack"],
                unroll=scan_unroll)
        else:                                           # --- decode
            def body(carry, pc):
                p_, c_ = pc
                h, auxc, cs = period_body(*carry, p_, c_)
                return (h, auxc), cs
            (x, aux_total), new_stack_caches = jax.lax.scan(
                body, (x, aux_total), (params["stack"], caches["stack"]),
                unroll=scan_unroll)

    new_caches = ({"prefix": new_prefix_caches, "stack": new_stack_caches}
                  if (collect_cache or caches) else None)
    return x, aux_total, new_caches


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params, batch, shard: ShardFn):
    """Returns (x (B,S,d), label_mask (B,S) or None).

    Frontend stubs per spec: audio_stub consumes precomputed frame
    embeddings; vlm_stub prepends precomputed patch embeddings to the
    embedded text tokens (labels masked over the patch positions)."""
    if cfg.frontend == "audio_stub":
        x = batch["embeds"]
        return x, None
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vlm_stub":
        patches = batch["patches"]                      # (B, P, d)
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(patches.shape[:2], bool),
             jnp.ones(tokens.shape, bool)], axis=1)
        return x, mask
    return x, None


def _logits(cfg: ModelConfig, params, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"])


def softmax_xent(logits, labels, mask=None):
    """fp32 cross-entropy, mean over valid positions."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def xent_from_hidden(cfg: ModelConfig, params, h, labels, *,
                     chunk: "int | None" = None):
    """Cross-entropy from pre-logits hidden states.

    ``chunk``: sequence-chunked streaming loss — only (B, chunk, V) logits
    are ever live (scan over seq chunks) instead of the full (B, S, V)
    fp32 tensor.  Memory-hillclimb loss (EXPERIMENTS.md §Perf);
    chunk=None is the baseline dense path."""
    if chunk is None or h.shape[1] <= chunk:
        return softmax_xent(_logits(cfg, params, h), labels)
    B, S, d = h.shape
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    nc = h.shape[1] // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    valid_c = jnp.broadcast_to(
        jnp.moveaxis((jnp.arange(h.shape[1]) < S).reshape(1, nc, chunk),
                     1, 0), (nc, B, chunk))

    def body(acc, xs):
        hb, lb, vb = xs
        logits = _logits(cfg, params, hb).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll = jnp.where(vb, logz - gold, 0.0)
        return acc + jnp.sum(nll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (hc, lc, valid_c))
    return total / (B * S)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------
def forward_train(cfg: ModelConfig, params, batch, *, shard: ShardFn = _noshard,
                  remat: bool = True, scan_unroll: int | bool = 1,
                  loss_chunk: "int | None" = None):
    """batch: tokens/embeds (+patches) and labels.  Returns (loss, metrics).
    Next-token LM loss; labels = inputs shifted by caller OR derived here
    when batch has only tokens (teacher forcing on tokens[1:])."""
    x, vis_mask = _embed_inputs(cfg, params, batch, shard)
    x = shard(x, "act")
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x, aux, _ = _run_stack(cfg, params, x, positions, None, shard,
                           collect_cache=False, remat=remat,
                           scan_unroll=scan_unroll)
    x = Ls.rmsnorm(params["ln_f"], x, cfg.norm_eps)

    if "labels" in batch:
        labels = batch["labels"]
    else:
        labels = batch["tokens"]
    if cfg.frontend == "vlm_stub":
        # text tokens start after the patches; predict next text token
        text_len = labels.shape[1]
        hx = x[:, -text_len:-1]
        loss = xent_from_hidden(cfg, params, hx, labels[:, 1:],
                                chunk=loss_chunk)
    else:
        loss = xent_from_hidden(cfg, params, x[:, :-1], labels[:, 1:],
                                chunk=loss_chunk)

    metrics = {"xent": loss, "aux": aux}
    total = loss + aux
    if cfg.mtp_depth:
        mtp_loss = _mtp_loss(cfg, params, x, batch, positions, shard)
        metrics["mtp"] = mtp_loss
        total = total + cfg.mtp_loss_weight * mtp_loss
    metrics["loss"] = total
    return total, metrics


def _mtp_loss(cfg: ModelConfig, params, h_final, batch, positions,
              shard: ShardFn):
    """DeepSeek-V3 multi-token prediction (depth 1): combine the trunk's
    hidden state at t with the embedding of token t+1 to predict t+2."""
    tokens = batch.get("labels", batch.get("tokens"))
    if tokens is None or cfg.frontend != "none":
        return jnp.zeros((), jnp.float32)
    p = params["mtp"]
    emb_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)  # t+1 emb
    h = h_final[:, :-1]
    comb = jnp.concatenate(
        [Ls.rmsnorm(p["ln"], h, cfg.norm_eps), emb_next], axis=-1)
    x = comb @ p["proj"]
    kind = (cfg.layer_kind(cfg.num_layers - 1),
            cfg.layer_is_moe(cfg.num_layers - 1))
    x, _, _ = _apply_layer(cfg, kind, p["layer"], x, positions[:, :-1],
                           None, shard)
    logits = _logits(cfg, params, x[:, :-1])
    return softmax_xent(logits, tokens[:, 2:])


def prefill(cfg: ModelConfig, params, batch, *, max_len: int | None = None,
            shard: ShardFn = _noshard, scan_unroll: int | bool = 1):
    """Full-sequence forward that also returns decode caches.
    ``max_len``: cache capacity (>= S); caches are padded to it."""
    x, _ = _embed_inputs(cfg, params, batch, shard)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x, _, caches = _run_stack(cfg, params, x, positions, None, shard,
                              collect_cache=True, scan_unroll=scan_unroll)
    x = Ls.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _logits(cfg, params, x[:, -1:])
    caches = _pad_caches(cfg, caches, S, max_len or S)
    return logits, caches


def _pad_caches(cfg: ModelConfig, caches, cur_len: int, max_len: int):
    """Grow KV/latent caches to capacity and attach lengths."""
    def pad_leaf(leaf_name, c):
        def pad(x):
            if x.ndim >= 2 and x.shape[1] == cur_len:
                widths = [(0, 0)] * x.ndim
                widths[1] = (0, max_len - cur_len)
                return jnp.pad(x, widths)
            return x
        return jax.tree.map(pad, c)

    def attach(c):
        if c is None:
            return None
        c = dict(c)
        if "k" in c or "ckv" in c:          # attention-style cache
            c = pad_leaf("kv", c)
            c["length"] = jnp.int32(cur_len) if "length" not in c \
                else c["length"]
        return c

    out = {"prefix": [attach(c) for c in caches["prefix"]],
           "stack": []}
    for c in caches["stack"]:
        if c is None:
            out["stack"].append(None)
            continue
        cc = dict(c)
        if "k" in cc or "ckv" in cc:
            def pad(x):
                if x.ndim >= 3 and x.shape[2] == cur_len:
                    widths = [(0, 0)] * x.ndim
                    widths[2] = (0, max_len - cur_len)
                    return jnp.pad(x, widths)
                return x
            cc = jax.tree.map(pad, cc)
            n_periods = _stack_info(cfg)[2]
            cc["length"] = jnp.full((n_periods,), cur_len, jnp.int32)
        out["stack"].append(cc)
    return out


def decode_step(cfg: ModelConfig, params, tokens, caches, *,
                shard: ShardFn = _noshard, embeds=None,
                scan_unroll: int | bool = 1):
    """One decode step.  tokens: (B, 1) int32 (or embeds (B,1,d) for
    audio_stub).  Returns (logits (B,1,V), new_caches)."""
    if cfg.frontend == "audio_stub":
        x = embeds
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "act")
    length = _cache_length(caches)
    positions = length + jnp.zeros(x.shape[:2], jnp.int32)
    x, _, new_caches = _run_stack(cfg, params, x, positions, caches, shard,
                                  collect_cache=False,
                                  scan_unroll=scan_unroll)
    x = Ls.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return _logits(cfg, params, x), new_caches


def _cache_length(caches):
    for c in caches["prefix"]:
        if c is not None and "length" in c:
            return c["length"]
    for c in caches["stack"]:
        if c is not None and "length" in c:
            return c["length"][0]
    return jnp.int32(0)


def init_decode_caches(cfg: ModelConfig, batch_size: int, max_len: int,
                       dtype=jnp.float32):
    """Fresh empty caches for decode-only dry-runs (decode_32k/long_500k):
    capacity max_len, length tracks filled prefix (set to max_len - 1 by
    the dry-run to model a full context)."""
    prefix, period, n_periods = _stack_info(cfg)

    def attn_cache(stacked: bool):
        if cfg.attn_type == "mla":
            c = {"ckv": jnp.zeros((batch_size, max_len, cfg.kv_lora_rank),
                                  dtype),
                 "krope": jnp.zeros((batch_size, max_len, cfg.rope_head_dim),
                                    dtype)}
        else:
            c = {"k": jnp.zeros((batch_size, max_len, cfg.num_kv_heads,
                                 cfg.head_dim), dtype),
                 "v": jnp.zeros((batch_size, max_len, cfg.num_kv_heads,
                                 cfg.head_dim), dtype)}
        return c

    def ssm_cache():
        return {"ssm": jnp.zeros((batch_size, cfg.ssm_heads,
                                  cfg.ssm_headdim, cfg.ssm_state),
                                 jnp.float32),
                "conv": jnp.zeros((batch_size, cfg.ssm_conv - 1,
                                   cfg.d_inner + 2 * cfg.ssm_state), dtype)}

    caches = {"prefix": [], "stack": []}
    for i in range(prefix):
        c = attn_cache(False) if cfg.layer_kind(i) == "attn" else ssm_cache()
        if "k" in c or "ckv" in c:
            c["length"] = jnp.int32(0)
        caches["prefix"].append(c)
    for pos in range(period):
        kind = cfg.layer_kind(prefix + pos)
        c = attn_cache(True) if kind == "attn" else ssm_cache()
        c = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), c)
        if "k" in c or "ckv" in c:
            c["length"] = jnp.zeros((n_periods,), jnp.int32)
        caches["stack"].append(c)
    return caches

"""Training launcher: mesh setup, sharded state, checkpoint/auto-resume.

CPU-scale example (what CI runs):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On a real pod the same entry point runs with --mesh single|multi and the
full config; the dry-run (launch/dryrun.py) proves those compile.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.distributed import sharding as shd
from repro.training import OptConfig, make_train_step, train_state_init


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_mesh_for(args):
    n = len(jax.devices())
    if args.mesh == "single":
        from repro.launch.mesh import make_production_mesh
        return make_production_mesh(multi_pod=False)
    if args.mesh == "multi":
        from repro.launch.mesh import make_production_mesh
        return make_production_mesh(multi_pod=True)
    # auto: small local mesh (data x model), model axis 1 or 2
    model = 2 if n % 2 == 0 and n > 1 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", choices=["auto", "single", "multi"],
                    default="auto")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=5,
                        total_steps=max(args.steps, 10))
    data_cfg = DataConfig(seed=args.seed, global_batch=args.batch,
                          seq_len=args.seq)
    mesh = make_mesh_for(args)

    state = train_state_init(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
    pspecs = shd.param_specs(state.params, mesh)
    ospecs = shd.opt_state_specs(opt_cfg, state.params, pspecs)
    sspecs = type(state)(params=pspecs, opt_state=ospecs, step=P())
    state = jax.device_put(state, _ns(mesh, sspecs))

    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None:
        got = mgr.restore_latest(state, _ns(mesh, sspecs))
        if got is not None:
            start_step, state, extra = got
            print(f"[resume] from checkpoint step {start_step}")

    batch0 = synthetic_batch(cfg, data_cfg, 0)
    bspecs = shd.batch_spec_tree(batch0, mesh)
    step_fn = make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                              shard=shd.make_shard_fn(mesh))
    # production default: explicit expert-parallel MoE dispatch
    # (EXPERIMENTS.md §Perf F3) whenever the mesh has a model axis
    import contextlib
    from repro.models.moe import ep_sharding
    ep_ctx = (ep_sharding(mesh) if cfg.is_moe
              and "model" in mesh.axis_names
              and cfg.num_experts % mesh.shape["model"] == 0
              else contextlib.nullcontext())
    with ep_ctx:
        jstep = jax.jit(step_fn,
                        in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs)),
                        out_shardings=(_ns(mesh, sspecs), None),
                        donate_argnums=(0,))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = jax.device_put(synthetic_batch(cfg, data_cfg, step),
                               _ns(mesh, bspecs))
        state, metrics = jstep(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, extra={"data_step": step + 1})
    dt = time.time() - t0
    print(f"[done] {args.steps - start_step} steps in {dt:.1f}s "
          f"({(args.steps - start_step) / max(dt, 1e-9):.2f} it/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()

"""Serving launcher — the paper's workload end-to-end, through the client.

Opens a ``repro.api.Database`` handle (one catalog root, many named
tables) and serves batched random-pattern scans through
``HedgedScanService`` — now a replica/hedging policy riding the typed
client frontend: every batch is a ``Query`` routed by table name,
coalesced by the shared ``QueryScheduler``, and executed as one
bucket-padded jitted planner invocation.  Prints the paper's Table
III/IV statistics with and without hedged reads, then demonstrates the
beyond-paper client surface:

* **multi-table serving from one root** — a second table is created (or
  re-opened) next to the first and queries from simulated concurrent
  callers to BOTH tables are submitted through the one scheduler;
  ``--coalesce-window`` is its micro-batch window in ms;
* **paged result streaming** — a hot pattern's full occurrence list is
  streamed in bounded ``ReadSession`` pages with a resumable cursor;
* **the write path** — append, merged-read, minor compaction (seal to a
  run), major compaction (merge-fold, version bump);
* **the serving plane** (``--tablets N``) — the table is range-split
  into N tablets, served by separate worker processes (×
  ``--plane-replicas``), and the same typed queries are answered
  bit-identically through the multi-process router
  (docs/serving_plane.md);
* the table's documented ``stats()`` schema, printed at the end.

    PYTHONPATH=src python -m repro.launch.serve --text-len 200000 \
        --queries 10000 --batch 512 --coalesce-window 2.0

Pass ``--root DIR`` to persist: the first run creates ``--table`` under
DIR, later runs re-open it (no rebuild) on any device count.

Launch tuning happens BEFORE the jax import (jax reads the environment
exactly once): ``--host-devices N`` forces N host platform devices via
``XLA_FLAGS``, ``--tuned`` applies the production env preset
(TF_CPP_MIN_LOG_LEVEL=4, tcmalloc report threshold; ``launch/run.sh``
adds the LD_PRELOAD half) — so heavy imports live inside :func:`main`,
not at module top.

``--metrics-interval`` streams the served table's full ``stats()``
tree into ``root/<table>/metrics.jsonl`` — the same feed tablet
workers and routers append to.  ``--dump-stats`` is the ``/varz``
path: it aggregates that feed and exits without ever importing jax
(docs/observability.md).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _dump_stats(args) -> None:
    """The /varz snapshot: aggregate root/<table>/metrics.jsonl and
    print fleet totals + the latest line per emitter (jax-free)."""
    from repro.serving.metrics import aggregate_metrics
    if args.root is None:
        print("[varz  ] --dump-stats needs --root (metrics.jsonl lives "
              "in the table's catalog dir)")
        return
    path = os.path.join(args.root, args.table, "metrics.jsonl")
    agg = aggregate_metrics(path)
    s = agg["summary"]
    print(f"[varz  ] table={args.table} emitters={s['emitters']} "
          f"workers={s['workers']} tablets={s['tablets']} "
          f"tables={s['tables']}")
    print(f"[varz  ] queries={s['queries']} rpcs={s['rpcs']} "
          f"shed_worker={s['shed_worker']} shed_quota={s['shed_quota']} "
          f"hedge_fired={s['hedge_fired']} hedge_wins={s['hedge_wins']} "
          f"failovers={s['failovers']} "
          f"wal_replayed={s['wal_records_replayed']}")
    print(f"[varz  ] queue_depth={s['queue_depth']} "
          f"p50_ms_median={s['p50_ms_median']} "
          f"p95_ms_max={s['p95_ms_max']}")
    for rec in agg["latest"]:
        role = rec.get("role", "worker")
        if role == "worker":
            print(f"[varz  ] worker t{rec.get('tablet')}r"
                  f"{rec.get('replica')} pid={rec.get('pid')} "
                  f"queries={rec.get('queries')} shed={rec.get('shed')} "
                  f"p50={rec.get('p50_ms')} p95={rec.get('p95_ms')} "
                  f"crc={rec.get('text_crc')}")
        elif role == "table":
            # in-process emitter (SuffixTable.start_metrics): same row
            # schema, full stats() tree under "stats"
            tiers = (rec.get("stats") or {}).get("tiers") or {}
            print(f"[varz  ] table-proc {rec.get('table')} "
                  f"pid={rec.get('pid')} queries={rec.get('queries')} "
                  f"p50={rec.get('p50_ms')} p95={rec.get('p95_ms')} "
                  f"p99={rec.get('p99_ms')} "
                  f"base={tiers.get('base_rows')} "
                  f"runs={tiers.get('run_count')} "
                  f"frozen={tiers.get('frozen')}")
        else:
            print(f"[varz  ] router pid={rec.get('pid')} "
                  f"rpcs={rec.get('rpcs')} "
                  f"hedge={rec.get('hedge_fired')}/"
                  f"{rec.get('hedge_wins')} "
                  f"failovers={rec.get('failovers')} "
                  f"quota_shed={rec.get('quota_shed')}")


def _malloc_in_use() -> str:
    """Which allocator this process actually mapped ("tcmalloc" /
    "libc" / "unknown") — LD_PRELOAD can lie; /proc/self/maps cannot."""
    try:
        with open("/proc/self/maps") as f:
            return "tcmalloc" if "tcmalloc" in f.read() else "libc"
    except OSError:
        return "unknown"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--max-pattern", type=int, default=100)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--capacity-factor", type=float, default=2.0)
    ap.add_argument("--top-k", type=int, default=5,
                    help="positions per query in the locate demo")
    ap.add_argument("--coalesce-window", type=float, default=2.0,
                    help="QueryScheduler micro-batch window in ms "
                         "(0 disables waiting, not coalescing)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="ReadSession page size in the streaming demo")
    ap.add_argument("--memtable-limit", type=int, default=None,
                    help="seal the memtable into an immutable run (minor "
                         "compaction) once it reaches this many symbols")
    ap.add_argument("--max-runs", type=int, default=None,
                    help="fold runs into the base (major compaction, "
                         "merge-based) once this many are live")
    ap.add_argument("--fm-threshold", type=int, default=None,
                    help="freeze the base tier onto the compressed "
                         "FM-index once it reaches this many symbols "
                         "(docs/storage_tiers.md); major compactions "
                         "re-freeze automatically")
    ap.add_argument("--freeze", action="store_true",
                    help="freeze the main table explicitly right after "
                         "build/open (one-shot --fm-threshold)")
    ap.add_argument("--wal", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="write-ahead commit log for persistent tables: "
                         "appends are CRC-framed and fsync'd before the "
                         "ack, and reopen replays the log tail "
                         "(--no-wal restores the volatile pre-log path)")
    ap.add_argument("--group-commit-ms", type=float, default=0.0,
                    help="group-commit window: concurrent client appends "
                         "arriving within this many ms share ONE fsync "
                         "before acking (0 = fsync per append)")
    ap.add_argument("--max-device-bytes", type=int, default=None,
                    help="per-device build budget in bytes: create runs "
                         "the staged out-of-core pipeline (docs/"
                         "build_pipeline.md) with chunk_rows = budget/24 "
                         "instead of one in-memory sort (needs --root)")
    ap.add_argument("--spill-dir", default=None,
                    help="spill the staged build's working arrays to "
                         "files under this dir instead of host RAM "
                         "(implies the staged pipeline; needs --root)")
    ap.add_argument("--root", default=None,
                    help="catalog root dir; omit for an in-memory table")
    ap.add_argument("--table", default="dna_serve",
                    help="table name under --root")
    ap.add_argument("--aux-table", default="dna_aux",
                    help="second table for the multi-table demo")
    ap.add_argument("--tuned", action="store_true",
                    help="production env preset, applied BEFORE the jax "
                         "import (docs/observability.md): fully quiet TF/"
                         "XLA logging (TF_CPP_MIN_LOG_LEVEL=4), a high "
                         "tcmalloc large-alloc report threshold, and a "
                         "report of the malloc actually linked "
                         "(LD_PRELOADing tcmalloc itself is a launch-"
                         "time knob — use launch/run.sh)")
    ap.add_argument("--metrics-interval", type=float, default=1.0,
                    help="stream the table's full stats() tree into "
                         "root/<table>/metrics.jsonl every this many "
                         "seconds — the same feed plane workers write, "
                         "aggregated by --dump-stats (0 = one final row "
                         "on close, negative = no feed; needs --root)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force this many XLA host-platform devices "
                         "(sets XLA_FLAGS before the jax import; a "
                         "CPU-only box then runs the multi-device scan "
                         "paths for real)")
    ap.add_argument("--dump-stats", action="store_true",
                    help="print the /varz aggregation of the table's "
                         "metrics.jsonl serving feed and exit (no jax "
                         "import, no table open)")
    ap.add_argument("--tablets", type=int, default=0,
                    help="after the write demo, range-split the table "
                         "into this many tablets and serve them from "
                         "separate worker processes (needs --root)")
    ap.add_argument("--plane-replicas", type=int, default=1,
                    help="worker processes per tablet in the plane demo "
                         "(2+ enables real hedged reads + failover)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.dump_stats:
        return _dump_stats(args)

    # tuned launch path: jax reads the environment ONCE at import, so
    # these must land before any jax import in this process
    os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL",
                          "4" if args.tuned else "2")
    if args.tuned:
        if "jax" in sys.modules:
            print("[tune  ] warning: jax already imported — the --tuned "
                  "env preset cannot take effect in this process "
                  "(launch through launch/run.sh instead)")
        os.environ.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                              "60000000000")
        malloc = _malloc_in_use()
        print(f"[tune  ] preset: TF_CPP_MIN_LOG_LEVEL="
              f"{os.environ['TF_CPP_MIN_LOG_LEVEL']} "
              f"TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="
              f"{os.environ['TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD']} "
              f"malloc={malloc}")
        if malloc != "tcmalloc":
            print("[tune  ] note: tcmalloc is not linked — LD_PRELOAD "
                  "is a launch-time knob the interpreter cannot apply "
                  "to itself; start via launch/run.sh to get it")
    if args.host_devices is not None:
        if "jax" in sys.modules:
            print(f"[tune  ] warning: jax already imported — "
                  f"--host-devices {args.host_devices} cannot take "
                  f"effect in this process (set XLA_FLAGS before "
                  f"launch instead)")
        else:
            flag = (f"--xla_force_host_platform_device_count="
                    f"{args.host_devices}")
            prev = os.environ.get("XLA_FLAGS", "")
            os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()
            print(f"[tune  ] XLA_FLAGS += {flag}")

    import jax
    import numpy as np

    from repro.api import Database, Query, SuffixTable
    from repro.core.codec import decode_dna, random_dna
    from repro.serving import HedgedScanService

    n_dev = len(jax.devices())
    lsm = {"memtable_limit": args.memtable_limit, "max_runs": args.max_runs,
           "fm_threshold": args.fm_threshold}
    # durability knobs only make sense with a root (in-memory tables have
    # no log); open_kw reach every table this handle opens from disk — the
    # reopen path must honor --capacity-factor just like create does
    wal_kw = {"wal": args.wal, "group_commit_ms": args.group_commit_ms}
    open_kw = dict(lsm, capacity_factor=args.capacity_factor, **wal_kw)
    db = Database(args.root, coalesce_window_ms=args.coalesce_window,
                  **(open_kw if args.root is not None else {}))

    t0 = time.time()
    if args.root is not None and args.table in db:
        print(f"[open ] table {args.table!r} from {args.root} "
              f"({n_dev} device(s)) ...", flush=True)
        table = db.table(args.table)
        print(f"[open ] v{table.version}, {len(table)} bases "
              f"({len(table.runs)} run(s)) in {time.time() - t0:.1f}s "
              f"(no rebuild, cf={table.capacity_factor})")
        rec = table.stats()["wal"]["recovery"]
        if rec is not None and (rec["records_replayed"]
                                or rec["torn_bytes"]):
            print(f"[wal  ] recovered: replayed="
                  f"{rec['records_replayed']} skipped="
                  f"{rec['records_skipped']} torn_bytes="
                  f"{rec['torn_bytes']} ({rec['reason']})")
    else:
        print(f"[build] suffix array over {args.text_len} bases "
              f"({n_dev} device(s)) ...", flush=True)
        codes = random_dna(args.text_len, seed=args.seed)
        # build-only knobs: they go to create_table ONLY — never into the
        # Database open_kw, which reach every later open() of the table
        build_kw = {}
        if args.max_device_bytes is not None:
            build_kw["max_device_bytes"] = args.max_device_bytes
        if args.spill_dir is not None:
            build_kw["spill_dir"] = args.spill_dir
        if args.root is None:
            if build_kw:
                print("[clamp ] --max-device-bytes/--spill-dir need "
                      "--root (staged builds persist shard-at-a-time); "
                      "building in-memory")
            table = db.attach(args.table, SuffixTable.from_codes(
                codes, is_dna=True, capacity_factor=args.capacity_factor,
                **lsm))
        else:
            table = db.create_table(
                args.table, codes, is_dna=True,
                capacity_factor=args.capacity_factor, **build_kw,
                **lsm, **wal_kw)
        dt = time.time() - t0
        print(f"[build] done in {dt:.1f}s "
              f"({args.text_len / max(dt, 1e-9) / 1e6:.2f} Mbase/s)")

    if args.freeze and not table.is_frozen:
        t1 = time.time()
        db.freeze(args.table)
        rb = table.stats()["tiers"]["resident_bytes"]
        print(f"[freeze] base tier -> FM-index in {time.time() - t1:.1f}s "
              f"(fm={rb['fm']}B, base_sa={rb['base_sa']}B)")

    # stream the in-process stats() tree into the SAME metrics.jsonl
    # feed plane workers use: --dump-stats (and check_regression.py
    # --from-feed) then aggregate one schema for every serving mode
    if args.root is not None and args.metrics_interval >= 0:
        mpath = os.path.join(args.root, args.table, "metrics.jsonl")
        table.start_metrics(mpath, interval_s=args.metrics_interval)
        print(f"[feed  ] stats() -> {mpath} "
              f"every {args.metrics_interval}s")

    # clamp to the table's pattern cap: run_workload validates up front
    max_pattern = min(args.max_pattern, table.max_query_len)
    if max_pattern < args.max_pattern:
        print(f"[clamp ] --max-pattern {args.max_pattern} -> {max_pattern} "
              f"(table max_query_len)")
    svc = HedgedScanService(table, replicas=args.replicas, database=db)
    for hedged in (False, True):
        stats = svc.run_workload(args.queries, batch=args.batch,
                                 max_len=max_pattern, hedged=hedged,
                                 seed=args.seed)
        mode = "hedged" if hedged else "single"
        print(f"[{mode:6s}] n={stats['n']} mean={stats['mean_ms']:.3f}ms "
              f"sd={stats['sd_ms']:.3f} min={stats['min_ms']:.2f} "
              f"max={stats['max_ms']:.1f} p99={stats['p99_ms']:.2f} "
              f"hit={stats['hit_rate']:.3f} "
              f"corr(len,t)={stats['corr_len_time']:.3f} "
              f"corr(len,hit)={stats['corr_len_outcome']:.3f}")

    # multi-table serving from one root: a second table next to the first,
    # and interleaved queries from simulated concurrent callers to BOTH
    # submitted through the one scheduler (cross-caller, cross-table
    # coalescing — each wave costs one dispatch per table, not one per
    # caller)
    if args.aux_table in db:
        aux = db.table(args.aux_table)
    elif args.root is not None:
        aux = db.create_table(args.aux_table,
                              random_dna(args.text_len // 4,
                                         seed=args.seed + 17), is_dna=True,
                              **wal_kw)
    else:
        aux = db.attach(args.aux_table, SuffixTable.from_codes(
            random_dna(args.text_len // 4, seed=args.seed + 17),
            is_dna=True))
    hot = ["ACGT", "GATTACA", "TTTT", "CCCCGGGG"]
    before = db.scheduler.stats.batches
    futs = [db.submit(Query.count(name, [p]))
            for p in hot for name in (args.table, args.aux_table)]
    waves = [f.result(timeout=30.0) for f in futs]
    s = db.scheduler.stats
    print(f"[client] {len(futs)} concurrent single-pattern callers over "
          f"2 tables -> {s.batches - before} dispatch(es) "
          f"(scheduler: submitted={s.submitted} coalesced="
          f"{s.coalesced_queries} max_batch={s.max_batch_patterns})")
    del aux, waves

    # match enumeration through typed queries + paged streaming
    if args.top_k > 0:
        out = db.query(Query.scan(args.table, hot[:3], top_k=args.top_k))
        for p, c, row in zip(hot[:3], out.count, out.positions):
            shown = [int(x) for x in row if x >= 0]
            print(f"[locate] {p!r}: count={int(c)} "
                  f"first_{args.top_k}={shown}")
    sess = db.read_rows(args.table, "ACGT", page_size=args.page_size)
    n_pages = n_pos = 0
    for page in sess.pages():
        n_pages += 1
        n_pos += int(page.positions.size)
    want = int(db.query(Query.count(args.table, ["ACGT"])).count[0])
    print(f"[stream] ReadRows('ACGT'): {n_pos} positions in {n_pages} "
          f"page(s) of <= {args.page_size} (one-shot count {want})")

    # the write path: append, merged read, minor compaction (seal to an
    # immutable run), then major compaction (merge-fold into the base)
    planted = "GATTACA" * 3
    before = int(table.count([planted])[0])
    table.append(planted + decode_dna(random_dna(993, seed=args.seed + 1)))
    after = int(table.count([planted])[0])
    n_runs = table.minor_compact()
    sealed = int(table.count([planted])[0])
    v = table.compact()
    print(f"[write ] append 1000 bases: count({planted[:10]}...) "
          f"{before} -> {after} (merged read); sealed into run "
          f"#{n_runs} (count still {sealed}); major-compacted to v{v}")

    # the serving plane: range-split into tablets, serve from separate
    # worker processes, answer the same typed queries bit-identically
    # through the router (docs/serving_plane.md)
    if args.tablets > 0:
        if args.root is None:
            print("[clamp ] --tablets needs --root (tablet workers serve "
                  "a persisted snapshot); skipping the plane demo")
        else:
            from repro.serving.plane import ServingPlane
            t2 = time.time()
            with ServingPlane.deploy(args.root, args.table, args.tablets,
                                     replicas=args.plane_replicas,
                                     metrics_interval_s=1.0) as plane:
                alias = args.table + "@plane"
                remote = db.connect_plane(args.table, attach_as=alias)
                probe = hot + [planted, "A", "ACG"]
                local_r = db.query(Query.scan(args.table, probe, top_k=4))
                plane_r = db.query(Query.scan(alias, probe, top_k=4))
                same = (np.array_equal(local_r.count, plane_r.count)
                        and np.array_equal(local_r.first_pos,
                                           plane_r.first_pos)
                        and np.array_equal(local_r.positions,
                                           plane_r.positions))
                print(f"[plane ] {args.tablets} tablet(s) x "
                      f"{args.plane_replicas} replica(s) up in "
                      f"{time.time() - t2:.1f}s: routed scan identical="
                      f"{same} over {len(probe)} probes")
                rs = remote.router.stats()
                print(f"[plane ] router rpcs={rs['rpcs']} "
                      f"hedge_fired={rs['hedge_fired']} "
                      f"hedge_wins={rs['hedge_wins']} "
                      f"failovers={rs['failovers']} "
                      f"p50={rs['p50_ms']}ms p95={rs['p95_ms']}ms")
                del plane

    # the documented stats schema (docs/client_api.md)
    st = table.stats()
    print(f"[table ] {st['name'] or args.table} v{st['version']} "
          f"dna={st['is_dna']} cap={st['max_query_len']}")
    print(f"[tiers ] base={st['tiers']['base_rows']} "
          f"runs={st['tiers']['run_count']} "
          f"run_rows={st['tiers']['run_rows']} "
          f"memtable={st['tiers']['memtable_rows']}")
    b = st["build"]
    if b is not None:
        print(f"[build ] mode={b['mode']} rounds={b['rounds']} "
              f"chunks={b['n_chunks']}x{b['chunk_rows']} "
              f"peak_device_bytes={b['peak_device_bytes']} "
              f"spill_bytes={b['spill_bytes']} "
              f"bases_per_s={b['bases_per_s']:.0f}")
    rb = st["tiers"]["resident_bytes"]
    print(f"[bytes ] frozen={st['tiers']['frozen']} "
          f"base_sa={rb['base_sa']} fm={rb['fm']} "
          f"runs={rb['runs']} memtable={rb['memtable']} "
          f"text_device={rb['text_device']}")
    print(f"[cache ] entries={st['cache']['entries']} "
          f"hits={st['cache']['hits']} misses={st['cache']['misses']} "
          f"generation={st['cache']['generation']}")
    pl = st["planner"]
    print(f"[plan  ] batches={pl['batches']} queries={pl['queries']} "
          f"bucketed_batches={pl['bucketed_batches']} "
          f"pad_slots={pl['pad_slots']} modes={pl['mode_counts']} "
          f"retried={pl['retried_overflow']}/{pl['retried_saturated']}"
          f"/{pl['retried_inexact_rank']}")
    lat = st["latency"]
    if lat:
        spans = " ".join(
            f"{k}={v['p50_ms']}/{v['p95_ms']}/{v['p99_ms']}"
            for k, v in lat.items())
        print(f"[trace ] span p50/p95/p99 ms: {spans}")
    else:
        print("[trace ] no spans recorded")
    w = st["wal"]
    if w["enabled"]:
        print(f"[wal   ] seq={w['seq']} appends={w['log']['appends']} "
              f"fsyncs={w['log']['fsyncs']} seals={w['log']['seals']} "
              f"group_commit_ms={w['log']['group_commit_ms']}")
    else:
        print("[wal   ] disabled (in-memory table or --no-wal)")
    db.close()


if __name__ == "__main__":
    main()

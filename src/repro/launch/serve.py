"""Serving launcher — the paper's workload end-to-end, through the table API.

Creates (or re-opens) a named ``repro.api.SuffixTable`` over a synthetic
DNA corpus — distributed construction when >1 device — then serves batched
random-pattern scans through ``HedgedScanService`` (scan-planner execution
with sentinel retry, plus the table's merged base+memtable reads) and
prints the paper's Table III/IV statistics, with and without hedged reads.
Finishes with the write path: append a planted segment, show the exact
merged count, seal it into an immutable run (minor compaction), then
merge-fold into the base (major compaction) and report the bumped version.
``--memtable-limit`` / ``--max-runs`` make both compactions automatic.

    PYTHONPATH=src python -m repro.launch.serve --text-len 200000 \
        --queries 10000 --batch 512

Pass ``--root DIR`` to persist: the first run creates ``--table`` under
DIR, later runs ``SuffixTable.open`` it (no rebuild) on any device count.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.api import Catalog, SuffixTable
from repro.core.codec import decode_dna, random_dna
from repro.serving import HedgedScanService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--max-pattern", type=int, default=100)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--capacity-factor", type=float, default=2.0)
    ap.add_argument("--top-k", type=int, default=5,
                    help="positions per query in the locate demo")
    ap.add_argument("--memtable-limit", type=int, default=None,
                    help="seal the memtable into an immutable run (minor "
                         "compaction) once it reaches this many symbols")
    ap.add_argument("--max-runs", type=int, default=None,
                    help="fold runs into the base (major compaction, "
                         "merge-based) once this many are live")
    ap.add_argument("--root", default=None,
                    help="catalog root dir; omit for an in-memory table")
    ap.add_argument("--table", default="dna_serve",
                    help="table name under --root")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    lsm = {"memtable_limit": args.memtable_limit, "max_runs": args.max_runs}
    t0 = time.time()
    if args.root is not None and args.table in Catalog(args.root):
        print(f"[open ] table {args.table!r} from {args.root} "
              f"({n_dev} device(s)) ...", flush=True)
        table = SuffixTable.open(args.table, root=args.root,
                                 capacity_factor=args.capacity_factor, **lsm)
        print(f"[open ] v{table.version}, {len(table)} bases "
              f"({len(table.runs)} run(s)) in {time.time() - t0:.1f}s "
              f"(no rebuild)")
    else:
        print(f"[build] suffix array over {args.text_len} bases "
              f"({n_dev} device(s)) ...", flush=True)
        codes = random_dna(args.text_len, seed=args.seed)
        if args.root is None:
            table = SuffixTable.from_codes(
                codes, is_dna=True, capacity_factor=args.capacity_factor,
                **lsm)
        else:
            table = SuffixTable.create(
                args.table, codes, root=args.root, is_dna=True,
                capacity_factor=args.capacity_factor, **lsm)
        dt = time.time() - t0
        print(f"[build] done in {dt:.1f}s "
              f"({args.text_len / max(dt, 1e-9) / 1e6:.2f} Mbase/s)")

    # clamp to the table's pattern cap: run_workload validates up front
    max_pattern = min(args.max_pattern, table.max_query_len)
    if max_pattern < args.max_pattern:
        print(f"[clamp ] --max-pattern {args.max_pattern} -> {max_pattern} "
              f"(table max_query_len)")
    svc = HedgedScanService(table, replicas=args.replicas)
    for hedged in (False, True):
        stats = svc.run_workload(args.queries, batch=args.batch,
                                 max_len=max_pattern, hedged=hedged,
                                 seed=args.seed)
        mode = "hedged" if hedged else "single"
        print(f"[{mode:6s}] n={stats['n']} mean={stats['mean_ms']:.3f}ms "
              f"sd={stats['sd_ms']:.3f} min={stats['min_ms']:.2f} "
              f"max={stats['max_ms']:.1f} p99={stats['p99_ms']:.2f} "
              f"hit={stats['hit_rate']:.3f} "
              f"corr(len,t)={stats['corr_len_time']:.3f} "
              f"corr(len,hit)={stats['corr_len_outcome']:.3f}")

    # match enumeration: top-k occurrence positions for a few hot patterns
    if args.top_k > 0:
        hot = ["ACGT", "GATTACA", "TTTT"]
        out = table.scan(hot, top_k=args.top_k)
        for p, c, row in zip(hot, out.count, out.positions):
            shown = [int(x) for x in row if x >= 0]
            print(f"[locate] {p!r}: count={int(c)} first_{args.top_k}={shown}")

    print(f"[table ] {table.stats()}")

    # the write path: append, merged read, minor compaction (seal to an
    # immutable run), then major compaction (merge-fold into the base —
    # rebuilds the planner, so the workload stats above are printed first)
    planted = "GATTACA" * 3
    before = int(table.count([planted])[0])
    table.append(planted + decode_dna(random_dna(993, seed=args.seed + 1)))
    after = int(table.count([planted])[0])
    n_runs = table.minor_compact()
    sealed = int(table.count([planted])[0])
    v = table.compact()
    print(f"[write ] append 1000 bases: count({planted[:10]}...) "
          f"{before} -> {after} (merged read); sealed into run "
          f"#{n_runs} (count still {sealed}); major-compacted to v{v}")


if __name__ == "__main__":
    main()

"""Serving launcher — the paper's workload end-to-end, through the client.

Opens a ``repro.api.Database`` handle (one catalog root, many named
tables) and serves batched random-pattern scans through
``HedgedScanService`` — now a replica/hedging policy riding the typed
client frontend: every batch is a ``Query`` routed by table name,
coalesced by the shared ``QueryScheduler``, and executed as one
bucket-padded jitted planner invocation.  Prints the paper's Table
III/IV statistics with and without hedged reads, then demonstrates the
beyond-paper client surface:

* **multi-table serving from one root** — a second table is created (or
  re-opened) next to the first and queries from simulated concurrent
  callers to BOTH tables are submitted through the one scheduler;
  ``--coalesce-window`` is its micro-batch window in ms;
* **paged result streaming** — a hot pattern's full occurrence list is
  streamed in bounded ``ReadSession`` pages with a resumable cursor;
* **the write path** — append, merged-read, minor compaction (seal to a
  run), major compaction (merge-fold, version bump);
* the table's documented ``stats()`` schema, printed at the end.

    PYTHONPATH=src python -m repro.launch.serve --text-len 200000 \
        --queries 10000 --batch 512 --coalesce-window 2.0

Pass ``--root DIR`` to persist: the first run creates ``--table`` under
DIR, later runs re-open it (no rebuild) on any device count.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.api import Database, Query, SuffixTable
from repro.core.codec import decode_dna, random_dna
from repro.serving import HedgedScanService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--max-pattern", type=int, default=100)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--capacity-factor", type=float, default=2.0)
    ap.add_argument("--top-k", type=int, default=5,
                    help="positions per query in the locate demo")
    ap.add_argument("--coalesce-window", type=float, default=2.0,
                    help="QueryScheduler micro-batch window in ms "
                         "(0 disables waiting, not coalescing)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="ReadSession page size in the streaming demo")
    ap.add_argument("--memtable-limit", type=int, default=None,
                    help="seal the memtable into an immutable run (minor "
                         "compaction) once it reaches this many symbols")
    ap.add_argument("--max-runs", type=int, default=None,
                    help="fold runs into the base (major compaction, "
                         "merge-based) once this many are live")
    ap.add_argument("--fm-threshold", type=int, default=None,
                    help="freeze the base tier onto the compressed "
                         "FM-index once it reaches this many symbols "
                         "(docs/storage_tiers.md); major compactions "
                         "re-freeze automatically")
    ap.add_argument("--freeze", action="store_true",
                    help="freeze the main table explicitly right after "
                         "build/open (one-shot --fm-threshold)")
    ap.add_argument("--wal", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="write-ahead commit log for persistent tables: "
                         "appends are CRC-framed and fsync'd before the "
                         "ack, and reopen replays the log tail "
                         "(--no-wal restores the volatile pre-log path)")
    ap.add_argument("--group-commit-ms", type=float, default=0.0,
                    help="group-commit window: concurrent client appends "
                         "arriving within this many ms share ONE fsync "
                         "before acking (0 = fsync per append)")
    ap.add_argument("--max-device-bytes", type=int, default=None,
                    help="per-device build budget in bytes: create runs "
                         "the staged out-of-core pipeline (docs/"
                         "build_pipeline.md) with chunk_rows = budget/24 "
                         "instead of one in-memory sort (needs --root)")
    ap.add_argument("--spill-dir", default=None,
                    help="spill the staged build's working arrays to "
                         "files under this dir instead of host RAM "
                         "(implies the staged pipeline; needs --root)")
    ap.add_argument("--root", default=None,
                    help="catalog root dir; omit for an in-memory table")
    ap.add_argument("--table", default="dna_serve",
                    help="table name under --root")
    ap.add_argument("--aux-table", default="dna_aux",
                    help="second table for the multi-table demo")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    lsm = {"memtable_limit": args.memtable_limit, "max_runs": args.max_runs,
           "fm_threshold": args.fm_threshold}
    # durability knobs only make sense with a root (in-memory tables have
    # no log); open_kw reach every table this handle opens from disk — the
    # reopen path must honor --capacity-factor just like create does
    wal_kw = {"wal": args.wal, "group_commit_ms": args.group_commit_ms}
    open_kw = dict(lsm, capacity_factor=args.capacity_factor, **wal_kw)
    db = Database(args.root, coalesce_window_ms=args.coalesce_window,
                  **(open_kw if args.root is not None else {}))

    t0 = time.time()
    if args.root is not None and args.table in db:
        print(f"[open ] table {args.table!r} from {args.root} "
              f"({n_dev} device(s)) ...", flush=True)
        table = db.table(args.table)
        print(f"[open ] v{table.version}, {len(table)} bases "
              f"({len(table.runs)} run(s)) in {time.time() - t0:.1f}s "
              f"(no rebuild, cf={table.capacity_factor})")
        rec = table.stats()["wal"]["recovery"]
        if rec is not None and (rec["records_replayed"]
                                or rec["torn_bytes"]):
            print(f"[wal  ] recovered: replayed="
                  f"{rec['records_replayed']} skipped="
                  f"{rec['records_skipped']} torn_bytes="
                  f"{rec['torn_bytes']} ({rec['reason']})")
    else:
        print(f"[build] suffix array over {args.text_len} bases "
              f"({n_dev} device(s)) ...", flush=True)
        codes = random_dna(args.text_len, seed=args.seed)
        # build-only knobs: they go to create_table ONLY — never into the
        # Database open_kw, which reach every later open() of the table
        build_kw = {}
        if args.max_device_bytes is not None:
            build_kw["max_device_bytes"] = args.max_device_bytes
        if args.spill_dir is not None:
            build_kw["spill_dir"] = args.spill_dir
        if args.root is None:
            if build_kw:
                print("[clamp ] --max-device-bytes/--spill-dir need "
                      "--root (staged builds persist shard-at-a-time); "
                      "building in-memory")
            table = db.attach(args.table, SuffixTable.from_codes(
                codes, is_dna=True, capacity_factor=args.capacity_factor,
                **lsm))
        else:
            table = db.create_table(
                args.table, codes, is_dna=True,
                capacity_factor=args.capacity_factor, **build_kw,
                **lsm, **wal_kw)
        dt = time.time() - t0
        print(f"[build] done in {dt:.1f}s "
              f"({args.text_len / max(dt, 1e-9) / 1e6:.2f} Mbase/s)")

    if args.freeze and not table.is_frozen:
        t1 = time.time()
        db.freeze(args.table)
        rb = table.stats()["tiers"]["resident_bytes"]
        print(f"[freeze] base tier -> FM-index in {time.time() - t1:.1f}s "
              f"(fm={rb['fm']}B, base_sa={rb['base_sa']}B)")

    # clamp to the table's pattern cap: run_workload validates up front
    max_pattern = min(args.max_pattern, table.max_query_len)
    if max_pattern < args.max_pattern:
        print(f"[clamp ] --max-pattern {args.max_pattern} -> {max_pattern} "
              f"(table max_query_len)")
    svc = HedgedScanService(table, replicas=args.replicas, database=db)
    for hedged in (False, True):
        stats = svc.run_workload(args.queries, batch=args.batch,
                                 max_len=max_pattern, hedged=hedged,
                                 seed=args.seed)
        mode = "hedged" if hedged else "single"
        print(f"[{mode:6s}] n={stats['n']} mean={stats['mean_ms']:.3f}ms "
              f"sd={stats['sd_ms']:.3f} min={stats['min_ms']:.2f} "
              f"max={stats['max_ms']:.1f} p99={stats['p99_ms']:.2f} "
              f"hit={stats['hit_rate']:.3f} "
              f"corr(len,t)={stats['corr_len_time']:.3f} "
              f"corr(len,hit)={stats['corr_len_outcome']:.3f}")

    # multi-table serving from one root: a second table next to the first,
    # and interleaved queries from simulated concurrent callers to BOTH
    # submitted through the one scheduler (cross-caller, cross-table
    # coalescing — each wave costs one dispatch per table, not one per
    # caller)
    if args.aux_table in db:
        aux = db.table(args.aux_table)
    elif args.root is not None:
        aux = db.create_table(args.aux_table,
                              random_dna(args.text_len // 4,
                                         seed=args.seed + 17), is_dna=True,
                              **wal_kw)
    else:
        aux = db.attach(args.aux_table, SuffixTable.from_codes(
            random_dna(args.text_len // 4, seed=args.seed + 17),
            is_dna=True))
    hot = ["ACGT", "GATTACA", "TTTT", "CCCCGGGG"]
    before = db.scheduler.stats.batches
    futs = [db.submit(Query.count(name, [p]))
            for p in hot for name in (args.table, args.aux_table)]
    waves = [f.result(timeout=30.0) for f in futs]
    s = db.scheduler.stats
    print(f"[client] {len(futs)} concurrent single-pattern callers over "
          f"2 tables -> {s.batches - before} dispatch(es) "
          f"(scheduler: submitted={s.submitted} coalesced="
          f"{s.coalesced_queries} max_batch={s.max_batch_patterns})")
    del aux, waves

    # match enumeration through typed queries + paged streaming
    if args.top_k > 0:
        out = db.query(Query.scan(args.table, hot[:3], top_k=args.top_k))
        for p, c, row in zip(hot[:3], out.count, out.positions):
            shown = [int(x) for x in row if x >= 0]
            print(f"[locate] {p!r}: count={int(c)} "
                  f"first_{args.top_k}={shown}")
    sess = db.read_rows(args.table, "ACGT", page_size=args.page_size)
    n_pages = n_pos = 0
    for page in sess.pages():
        n_pages += 1
        n_pos += int(page.positions.size)
    want = int(db.query(Query.count(args.table, ["ACGT"])).count[0])
    print(f"[stream] ReadRows('ACGT'): {n_pos} positions in {n_pages} "
          f"page(s) of <= {args.page_size} (one-shot count {want})")

    # the write path: append, merged read, minor compaction (seal to an
    # immutable run), then major compaction (merge-fold into the base)
    planted = "GATTACA" * 3
    before = int(table.count([planted])[0])
    table.append(planted + decode_dna(random_dna(993, seed=args.seed + 1)))
    after = int(table.count([planted])[0])
    n_runs = table.minor_compact()
    sealed = int(table.count([planted])[0])
    v = table.compact()
    print(f"[write ] append 1000 bases: count({planted[:10]}...) "
          f"{before} -> {after} (merged read); sealed into run "
          f"#{n_runs} (count still {sealed}); major-compacted to v{v}")

    # the documented stats schema (docs/client_api.md)
    st = table.stats()
    print(f"[table ] {st['name'] or args.table} v{st['version']} "
          f"dna={st['is_dna']} cap={st['max_query_len']}")
    print(f"[tiers ] base={st['tiers']['base_rows']} "
          f"runs={st['tiers']['run_count']} "
          f"run_rows={st['tiers']['run_rows']} "
          f"memtable={st['tiers']['memtable_rows']}")
    b = st["build"]
    if b is not None:
        print(f"[build ] mode={b['mode']} rounds={b['rounds']} "
              f"chunks={b['n_chunks']}x{b['chunk_rows']} "
              f"peak_device_bytes={b['peak_device_bytes']} "
              f"spill_bytes={b['spill_bytes']} "
              f"bases_per_s={b['bases_per_s']:.0f}")
    rb = st["tiers"]["resident_bytes"]
    print(f"[bytes ] frozen={st['tiers']['frozen']} "
          f"base_sa={rb['base_sa']} fm={rb['fm']} "
          f"runs={rb['runs']} memtable={rb['memtable']} "
          f"text_device={rb['text_device']}")
    print(f"[cache ] entries={st['cache']['entries']} "
          f"hits={st['cache']['hits']} misses={st['cache']['misses']} "
          f"generation={st['cache']['generation']}")
    pl = st["planner"]
    print(f"[plan  ] batches={pl['batches']} queries={pl['queries']} "
          f"bucketed_batches={pl['bucketed_batches']} "
          f"pad_slots={pl['pad_slots']} modes={pl['mode_counts']} "
          f"retried={pl['retried_overflow']}/{pl['retried_saturated']}"
          f"/{pl['retried_inexact_rank']}")
    w = st["wal"]
    if w["enabled"]:
        print(f"[wal   ] seq={w['seq']} appends={w['log']['appends']} "
              f"fsyncs={w['log']['fsyncs']} seals={w['log']['seals']} "
              f"group_commit_ms={w['log']['group_commit_ms']}")
    else:
        print("[wal   ] disabled (in-memory table or --no-wal)")
    db.close()


if __name__ == "__main__":
    main()

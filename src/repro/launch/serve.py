"""Serving launcher — the paper's workload end-to-end.

Builds a tablet store over a synthetic DNA corpus (distributed construction
when >1 device), then serves batched random-pattern scans through the scan
planner (single / broadcast / routed+retry selection) and prints the
paper's Table III/IV statistics, with and without hedged reads.

    PYTHONPATH=src python -m repro.launch.serve --text-len 200000 \
        --queries 10000 --batch 512
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core.codec import random_dna
from repro.core.planner import ScanPlanner
from repro.core.tablet import build_tablet_store
from repro.launch.mesh import make_tablet_mesh
from repro.serving import HedgedScanService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--max-pattern", type=int, default=100)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--capacity-factor", type=float, default=2.0)
    ap.add_argument("--top-k", type=int, default=5,
                    help="positions per query in the locate demo")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    print(f"[build] suffix array over {args.text_len} bases "
          f"({n_dev} device(s)) ...", flush=True)
    t0 = time.time()
    codes = random_dna(args.text_len, seed=args.seed)
    store = build_tablet_store(codes, is_dna=True, num_tablets=n_dev)
    jax.block_until_ready(store.sa)
    print(f"[build] done in {time.time() - t0:.1f}s "
          f"({args.text_len / max(time.time() - t0, 1e-9) / 1e6:.2f} Mbase/s)")

    mesh = make_tablet_mesh(n_dev) if n_dev > 1 else None
    planner = ScanPlanner(store, mesh=mesh,
                          capacity_factor=args.capacity_factor)
    svc = HedgedScanService(store, replicas=args.replicas, planner=planner)
    for hedged in (False, True):
        stats = svc.run_workload(args.queries, batch=args.batch,
                                 max_len=args.max_pattern, hedged=hedged,
                                 seed=args.seed)
        mode = "hedged" if hedged else "single"
        print(f"[{mode:6s}] n={stats['n']} mean={stats['mean_ms']:.3f}ms "
              f"sd={stats['sd_ms']:.3f} min={stats['min_ms']:.2f} "
              f"max={stats['max_ms']:.1f} p99={stats['p99_ms']:.2f} "
              f"hit={stats['hit_rate']:.3f} "
              f"corr(len,t)={stats['corr_len_time']:.3f} "
              f"corr(len,hit)={stats['corr_len_outcome']:.3f}")

    # match enumeration: top-k occurrence positions for a few hot patterns
    if args.top_k > 0:
        hot = ["ACGT", "GATTACA", "TTTT"]
        out = planner.scan(hot, top_k=args.top_k)
        for p, c, row in zip(hot, out.count, out.positions):
            shown = [int(x) for x in row if x >= 0]
            print(f"[locate] {p!r}: count={int(c)} first_{args.top_k}={shown}")
    print(f"[planner] {planner.stats.as_dict()}")


if __name__ == "__main__":
    main()

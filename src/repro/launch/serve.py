"""Serving launcher — the paper's workload end-to-end.

Builds a tablet store over a synthetic DNA corpus (distributed construction
when >1 device), then serves batched random-pattern scans and prints the
paper's Table III/IV statistics, with and without hedged reads.

    PYTHONPATH=src python -m repro.launch.serve --text-len 200000 \
        --queries 10000 --batch 512
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.codec import random_dna
from repro.core.tablet import build_tablet_store
from repro.serving import HedgedScanService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--text-len", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--max-pattern", type=int, default=100)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    print(f"[build] suffix array over {args.text_len} bases ...", flush=True)
    t0 = time.time()
    codes = random_dna(args.text_len, seed=args.seed)
    store = build_tablet_store(codes, is_dna=True)
    jax.block_until_ready(store.sa)
    print(f"[build] done in {time.time() - t0:.1f}s "
          f"({args.text_len / max(time.time() - t0, 1e-9) / 1e6:.2f} Mbase/s)")

    svc = HedgedScanService(store, replicas=args.replicas)
    for hedged in (False, True):
        stats = svc.run_workload(args.queries, batch=args.batch,
                                 max_len=args.max_pattern, hedged=hedged,
                                 seed=args.seed)
        mode = "hedged" if hedged else "single"
        print(f"[{mode:6s}] n={stats['n']} mean={stats['mean_ms']:.3f}ms "
              f"sd={stats['sd_ms']:.3f} min={stats['min_ms']:.2f} "
              f"max={stats['max_ms']:.1f} p99={stats['p99_ms']:.2f} "
              f"hit={stats['hit_rate']:.3f} "
              f"corr(len,t)={stats['corr_len_time']:.3f} "
              f"corr(len,hit)={stats['corr_len_outcome']:.3f}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (note: no `from __future__ import annotations` here — the XLA_FLAGS env
# set MUST be the first statements, before any jax import, since jax locks
# the device count on first init.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) and extracts the roofline
inputs: ``compiled.cost_analysis()`` (FLOPs / HBM bytes),
``compiled.memory_analysis()`` (per-device residency) and the collective
bytes parsed from the optimized HLO (launch/hlo_analysis.py).

Results are cached incrementally under experiments/dryrun/<cell>.json so
the 84-cell matrix can be filled across multiple invocations:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed import sharding as shd
from repro.launch import specs as SP
from repro.launch.hlo_analysis import (HBM_BW, analytic_memory_floor,
                                       collective_bytes, roofline_terms)
from repro.launch.mesh import make_production_mesh, make_tablet_mesh
from repro.models import decode_step, init_decode_caches, prefill
from repro.models.config import ModelConfig
from repro.training import OptConfig, make_train_step, train_state_init

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
PARAM_DTYPE = jnp.bfloat16


def _opt_for(cfg: ModelConfig) -> OptConfig:
    big = cfg.param_count() > 3e11
    return OptConfig(kind="adafactor" if big else "adamw",
                     b1=0.0 if big else 0.9,
                     state_dtype=jnp.bfloat16 if cfg.param_count() > 5e10
                     else jnp.float32)


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _sds_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def lower_train(cfg: ModelConfig, mesh, shape_name: str,
                microbatches: int = 1, seq_shard: bool = True,
                unroll: bool = False, loss_chunk=None):
    opt_cfg = _opt_for(cfg)
    state_shapes = jax.eval_shape(
        lambda: train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0),
                                 dtype=PARAM_DTYPE))
    pspecs = shd.param_specs(state_shapes.params, mesh)
    ospecs = shd.opt_state_specs(opt_cfg, state_shapes.params, pspecs)
    sspecs = type(state_shapes)(params=pspecs, opt_state=ospecs, step=P())
    batch = SP.batch_specs(cfg, shape_name)
    bspecs = shd.batch_spec_tree(batch, mesh)
    shard_fn = shd.make_shard_fn(mesh, seq_shard=seq_shard)
    step_fn = make_train_step(cfg, opt_cfg, microbatches=microbatches,
                              shard=shard_fn, scan_unroll=unroll,
                              loss_chunk=loss_chunk)
    jitted = jax.jit(step_fn,
                     in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs)),
                     out_shardings=(_ns(mesh, sspecs), None),
                     donate_argnums=(0,))
    with jax.set_mesh(mesh):
        return jitted.lower(state_shapes, batch)


def lower_prefill(cfg: ModelConfig, mesh, shape_name: str,
                  seq_shard: bool = True, unroll: bool = False):
    state_shapes = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["init_params"])
        .init_params(cfg, jax.random.PRNGKey(0), PARAM_DTYPE))
    pspecs = shd.param_specs(state_shapes, mesh)
    batch = SP.batch_specs(cfg, shape_name)
    bspecs = shd.batch_spec_tree(batch, mesh)
    shard_fn = shd.make_shard_fn(mesh, seq_shard=seq_shard)
    info = SP.SHAPES[shape_name]

    def fn(params, b):
        return prefill(cfg, params, b, max_len=info["seq_len"],
                       shard=shard_fn, scan_unroll=unroll)

    jitted = jax.jit(fn, in_shardings=(_ns(mesh, pspecs),
                                       _ns(mesh, bspecs)))
    with jax.set_mesh(mesh):
        return jitted.lower(state_shapes, batch)


def lower_decode(cfg: ModelConfig, mesh, shape_name: str,
                 unroll: bool = False):
    from repro.models import init_params
    info = SP.SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    param_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), PARAM_DTYPE))
    pspecs = shd.param_specs(param_shapes, mesh)
    cache_shapes = SP.decode_cache_shapes(cfg, shape_name, PARAM_DTYPE)
    cspecs = shd.cache_specs(cache_shapes, mesh, B)
    batch = SP.batch_specs(cfg, shape_name)
    bspecs = shd.batch_spec_tree(batch, mesh)
    shard_fn = shd.make_shard_fn(mesh, seq_shard=False)

    def fn(params, tokens, caches, embeds):
        return decode_step(cfg, params, tokens, caches, shard=shard_fn,
                           embeds=embeds, scan_unroll=unroll)

    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    tspec = bspecs.get("tokens")
    espec = bspecs.get("embeds")
    jitted = jax.jit(fn, in_shardings=(
        _ns(mesh, pspecs),
        _ns(mesh, tspec) if tspec is not None else None,
        _ns(mesh, cspecs),
        _ns(mesh, espec) if espec is not None else None),
        out_shardings=(None, _ns(mesh, cspecs)),
        donate_argnums=(2,))
    with jax.set_mesh(mesh):
        return jitted.lower(param_shapes, tokens, cache_shapes, embeds)


def lower_sa_serve(mesh, routed: bool = False):
    """The paper's own workload: distributed tablet scan on the production
    mesh (flattened to 1-D tablets).  ``routed``: the beyond-paper
    owner-routing path (queries sharded, all_to_all dispatch) instead of
    the paper-faithful broadcast fan-out."""
    import functools
    from repro.configs.dna_suffix import CONFIG as SA
    from repro.core import query as Q
    from repro.core.tablet import TabletStore

    n_dev = int(np.prod(list(mesh.shape.values())))
    n_pad = ((SA.text_len + n_dev - 1) // n_dev) * n_dev
    W = SA.max_query_len // 16
    store_meta = TabletStore(
        text_packed=jax.ShapeDtypeStruct(((SA.text_len + 15) // 16,),
                                         jnp.uint32),
        text_codes=None, sa=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        n_real=SA.text_len, n_pad=n_pad, is_dna=True,
        max_query_len=SA.max_query_len)
    tmesh = make_tablet_mesh(n_dev)
    B = 1024

    if routed:
        @functools.partial(compat.shard_map, mesh=tmesh,
                           in_specs=(P("tablets"), None, P("tablets"),
                                     P("tablets")),
                           out_specs=P("tablets"))
        def serve(sa_local, meta, patt, plen):
            return Q.query_routed(sa_local, meta, patt, plen, "tablets")
    else:
        @functools.partial(compat.shard_map, mesh=tmesh,
                           in_specs=(P("tablets"), None, P(), P()),
                           out_specs=P())
        def serve(sa_local, meta, patt, plen):
            return Q.query_sharded(sa_local, meta, patt, plen, "tablets")

    jitted = jax.jit(serve)
    with jax.set_mesh(tmesh):
        return jitted.lower(
            store_meta.sa, store_meta,
            jax.ShapeDtypeStruct((B, W), jnp.uint32),
            jax.ShapeDtypeStruct((B,), jnp.int32))


def lower_sa_build(mesh, method="bitonic"):
    """One prefix-doubling construction step, tablet-sharded."""
    import functools
    from repro.configs.dna_suffix import CONFIG as SA
    from repro.core.dsa import build_suffix_array_sharded

    n_dev = int(np.prod(list(mesh.shape.values())))
    tmesh = make_tablet_mesh(n_dev)
    m = ((SA.text_len + n_dev - 1) // n_dev)
    n_pad = m * n_dev

    @functools.partial(compat.shard_map, mesh=tmesh, in_specs=(P("tablets"),),
                       out_specs=(P("tablets"), P("tablets")))
    def build(codes_local):
        return build_suffix_array_sharded(
            codes_local, n_real=SA.text_len, axis_name="tablets",
            method=method, num_steps=1)

    jitted = jax.jit(build)
    with jax.set_mesh(tmesh):
        return jitted.lower(jax.ShapeDtypeStruct((n_pad,), jnp.int32))


# ---------------------------------------------------------------------------
def _compile_stats(lowered) -> dict:
    """Compile and pull raw per-partition stats.

    NOTE: XLA's cost_analysis on a GSPMD-partitioned module reports
    PER-PARTITION flops/bytes and counts while-loop bodies ONCE.  The
    collective parser weights loop bodies by trip count itself; flops/bytes
    of scanned layer stacks are recovered by the layer-count probes in
    ``run_cell`` (linear extrapolation over n_periods — exact for
    homogeneous periods)."""
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    return {
        "compile_s": round(compile_s, 1),
        "flops_dev": float(cost.get("flops", 0.0)),
        "hbm_dev": float(cost.get("bytes accessed", 0.0)),
        "collective": collective_bytes(hlo),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes_estimate": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)),
        },
    }


def _probe_cfg(cfg: ModelConfig, n_periods: int) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.first_dense_layers + n_periods * cfg.period,
        mtp_depth=0)


def _lower_for(cfg, mesh, shape_name, kind, opts, unroll=False):
    if kind == "train":
        return lower_train(cfg, mesh, shape_name,
                           microbatches=opts.get("microbatches", 1),
                           seq_shard=opts.get("seq_shard", True),
                           unroll=unroll,
                           loss_chunk=opts.get("loss_chunk"))
    if kind == "prefill":
        return lower_prefill(cfg, mesh, shape_name,
                             seq_shard=opts.get("seq_shard", True),
                             unroll=unroll)
    return lower_decode(cfg, mesh, shape_name, unroll=unroll)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: dict | None = None) -> dict:
    opts = opts or {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    label = f"{arch}:{shape_name}:{'2x16x16' if multi_pod else '16x16'}"

    if arch == "dna-suffix":
        if shape_name == "serve":
            lowered = lower_sa_serve(mesh, routed=opts.get("routed", False))
        else:
            lowered = lower_sa_build(mesh, method=opts.get("sort", "bitonic"))
        st = _compile_stats(lowered)
        flops = st["flops_dev"] * chips
        hbm = st["hbm_dev"] * chips
        res = {"label": label, "chips": chips, "kind": shape_name,
               "compile_s": st["compile_s"], "hlo_flops": flops,
               "hbm_bytes": hbm, "collective": st["collective"],
               "memory": st["memory"],
               "roofline": roofline_terms(flops, hbm,
                                          st["collective"]["bytes"] * chips,
                                          chips)}
        return res

    cfg = get_config(arch)
    ok, why = SP.cell_runnable(cfg, shape_name)
    if not ok:
        return {"label": label, "skipped": why}
    kind = SP.SHAPES[shape_name]["kind"]

    import contextlib
    from repro.models import layers as _L
    from repro.models import moe as _M
    chunk_ctx = (
        _L.attn_chunking(opts["attn_threshold"],
                         opts.get("attn_chunk", 1024))
        if opts.get("attn_threshold") else contextlib.nullcontext())
    ep_ctx = (_M.ep_sharding(mesh) if opts.get("ep") and cfg.is_moe
              else contextlib.nullcontext())

    # ---- main compile: the production artifact (memory + collectives)
    with chunk_ctx, ep_ctx:
        lowered = _lower_for(cfg, mesh, shape_name, kind, opts)
    st = _compile_stats(lowered)

    # ---- layer-count probes: recover true flops/bytes of the scanned stack
    prefix, period, n_periods = (cfg.first_dense_layers, cfg.period,
                                 (cfg.num_layers - cfg.first_dense_layers)
                                 // cfg.period)
    probes = {}
    if n_periods > 1 and not opts.get("no_probes"):
        for k in (1, 2):
            pcfg = _probe_cfg(cfg, k)
            with chunk_ctx, ep_ctx:
                pl = _lower_for(pcfg, mesh, shape_name, kind,
                                dict(opts, microbatches=1), unroll=True)
            pst = _compile_stats(pl)
            probes[k] = pst
        per_period_f = probes[2]["flops_dev"] - probes[1]["flops_dev"]
        per_period_b = probes[2]["hbm_dev"] - probes[1]["hbm_dev"]
        # mtp (stripped from probes) contributes ~1 period of train flops
        mtp_f = per_period_f * (1.0 if (cfg.mtp_depth and kind == "train")
                                else 0.0) / max(period, 1)
        flops_dev = (probes[1]["flops_dev"]
                     + (n_periods - 1) * per_period_f + mtp_f)
        hbm_dev = probes[1]["hbm_dev"] + (n_periods - 1) * per_period_b
        mb = opts.get("microbatches", 1)
        if kind == "train" and mb > 1:
            # probes ran mb=1 over the full batch: same total flops; bytes
            # scale mildly with re-reads of params per microbatch
            hbm_dev = hbm_dev  # conservative: keep probe value
    else:
        flops_dev = st["flops_dev"]
        hbm_dev = st["hbm_dev"]

    flops = flops_dev * chips
    hbm = hbm_dev * chips
    coll_global = st["collective"]["bytes"] * chips
    res = {
        "label": label, "chips": chips, "kind": kind,
        "compile_s": st["compile_s"],
        "hlo_flops": flops, "hbm_bytes": hbm,
        "hlo_flops_per_dev": flops_dev, "hbm_bytes_per_dev": hbm_dev,
        "collective": st["collective"], "memory": st["memory"],
        "roofline": roofline_terms(flops, hbm, coll_global, chips),
        "probe_compile_s": [probes[k]["compile_s"] for k in sorted(probes)],
    }
    # useful-FLOPs ratio (6ND / 2ND model)
    info = SP.SHAPES[shape_name]
    tokens = info["global_batch"] * (info["seq_len"] if kind != "decode"
                                     else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if kind == "train" else 2) * n_active * tokens
    res["model_flops"] = model_flops
    res["useful_ratio"] = model_flops / max(flops, 1)
    floor = analytic_memory_floor(cfg, info, kind, chips)
    res["memory_floor_bytes_per_dev"] = floor
    res["memory_floor_s"] = floor / HBM_BW
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--attn-threshold", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--routed", action="store_true")
    ap.add_argument("--ep", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--sort", default="bitonic")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    archs = list_archs() + ["dna-suffix"] if args.all else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        shapes = (["serve", "build"] if arch == "dna-suffix"
                  else list(SP.SHAPES))
        if args.shape:
            shapes = [args.shape]
        for shape in shapes:
            for mp in meshes:
                cell = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.tag:
                    cell += f"__{args.tag}"
                path = os.path.join(OUT_DIR, cell + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {cell}")
                    continue
                print(f"[lower+compile] {cell} ...", flush=True)
                t0 = time.time()
                try:
                    res = run_cell(arch, shape, mp, {
                        "microbatches": args.microbatches,
                        "seq_shard": not args.no_seq_shard,
                        "sort": args.sort,
                        "loss_chunk": args.loss_chunk,
                        "attn_threshold": args.attn_threshold,
                        "attn_chunk": args.attn_chunk,
                        "routed": args.routed,
                        "ep": args.ep,
                    })
                    res["wall_s"] = round(time.time() - t0, 1)
                except Exception as e:  # noqa: BLE001 — record failures too
                    res = {"label": cell, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1, default=str)
                status = ("SKIP" if res.get("skipped")
                          else "FAIL" if res.get("error") else "ok")
                print(f"[{status}] {cell} ({time.time() - t0:.0f}s)",
                      flush=True)


if __name__ == "__main__":
    main()

"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation ever happens here — these drive ``jit(...).lower()``.
Shape semantics (assignment):
  train_4k    : train_step,  tokens (256, 4096)
  prefill_32k : prefill,     tokens (32, 32768)
  decode_32k  : serve_step,  1 new token, batch 128, KV cache of 32768
  long_500k   : serve_step,  1 new token, batch 1,   cache of 524288
                (sub-quadratic archs only: mamba2, jamba)
VLM cells: seq_len counts patches + text (text = seq_len - num_patches).
Audio cells: precomputed frame embeddings replace tokens (frontend stub).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# archs that run the long_500k cell (sub-quadratic sequence mixing)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def cell_runnable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False, ("skipped: pure full-attention arch at 512k context "
                       "(quadratic prefill / unbounded KV) per assignment")
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape_name: str,
                act_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for the model inputs of this cell."""
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    if kind in ("train", "prefill"):
        if cfg.frontend == "audio_stub":
            return {"embeds": sds((B, S, cfg.d_model), act_dtype),
                    "labels": sds((B, S), jnp.int32)}
        batch = {}
        if cfg.frontend == "vlm_stub":
            text = S - cfg.num_patches
            batch["tokens"] = sds((B, text), jnp.int32)
            batch["patches"] = sds((B, cfg.num_patches, cfg.d_model),
                                   act_dtype)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        return batch
    # decode: one token
    if cfg.frontend == "audio_stub":
        return {"embeds": sds((B, 1, cfg.d_model), act_dtype)}
    return {"tokens": sds((B, 1), jnp.int32)}


def decode_cache_shapes(cfg: ModelConfig, shape_name: str,
                        dtype=jnp.bfloat16):
    from repro.models import init_decode_caches
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    return jax.eval_shape(
        lambda: init_decode_caches(cfg, B, S, dtype=dtype))

"""Production meshes.  Functions, never module-level constants — importing
this module must not touch jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tablet_mesh(num_devices: int | None = None):
    """1-D mesh over all devices for the TabletSA store (the serving
    deployment's own mesh over the same chips)."""
    n = num_devices if num_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), ("tablets",))


def make_pipeline_mesh():
    """Multi-pod mesh with the pod axis used as pipeline stages."""
    return jax.make_mesh((2, 16, 16), ("pod", "data", "model"))

"""Post-compile HLO analysis: collective bytes + roofline terms.

``collective_bytes`` parses optimized HLO text, sums operand bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
and multiplies collectives inside ``while`` bodies by the loop trip count
(recovered from the loop-condition constant — exact for counted lax.scan /
fori_loop loops, which is all this codebase emits).  ``conditional``
branches contribute their worst-case branch.

Roofline (TPU v5e targets): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every typed shape literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Computation:
    name: str
    coll_bytes: int = 0                  # direct collective operand bytes
    coll_count: int = 0
    calls: list = dataclasses.field(default_factory=list)
    # (callee_name, multiplier_kind): 'call' | 'while_body' | 'cond_branch'
    while_bounds: dict = dataclasses.field(default_factory=dict)
    max_constant: int = 1                # for when it's used as a while cond


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{", line)
        if m and not line.lstrip().startswith("ROOT"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def collective_bytes(hlo: str) -> dict:
    """Returns {'bytes': int, 'count': int, 'by_kind': {...}} with while-loop
    trip-count weighting."""
    comps = _split_computations(hlo)
    info: dict[str, _Computation] = {}
    by_kind: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}

    for name, lines in comps.items():
        c = _Computation(name)
        for ln in lines:
            # largest integer constant (trip-count recovery for conds)
            for const in re.findall(r"constant\((\d+)\)", ln):
                c.max_constant = max(c.max_constant, int(const))
            opm = re.search(
                r"=\s*\(?([\w\[\],{}\s/#*]+?)\)?\s+"
                r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                r"collective-permute)(?:-start)?\((.*)$", ln)
            if opm and "-done" not in ln:
                operand_text = opm.group(3)
                b = _shape_bytes(operand_text)
                if b == 0:           # operands given as %refs only: use result
                    b = _shape_bytes(opm.group(1))
                c.coll_bytes += b
                c.coll_count += 1
                c._kind_tmp = opm.group(2)
                by_kind[opm.group(2)] += b   # raw (unweighted) tally
            wm = re.search(r"while\(.*\).*condition=%?([\w\.\-]+),"
                           r"\s*body=%?([\w\.\-]+)", ln)
            if wm:
                c.calls.append((wm.group(2), "while", wm.group(1)))
            cm = re.search(r"conditional\(", ln)
            if cm:
                for branch in re.findall(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations=\{)[=%]*([\w\.\-, %]+)", ln):
                    for b_ in branch.replace("%", "").split(","):
                        b_ = b_.strip().rstrip("}")
                        if b_:
                            c.calls.append((b_, "cond", None))
            for callee in re.findall(r"(?:call|fusion)\([^)]*\).*?to_apply=%?"
                                     r"([\w\.\-]+)", ln):
                c.calls.append((callee, "call", None))
        info[name] = c

    def weighted(name: str, seen: frozenset) -> int:
        if name not in info or name in seen:
            return 0
        c = info[name]
        total = c.coll_bytes
        cond_best = 0
        for callee, kind, cond in c.calls:
            sub = weighted(callee, seen | {name})
            if kind == "while":
                trip = info[cond].max_constant if cond in info else 1
                total += sub * trip
            elif kind == "cond":
                cond_best = max(cond_best, sub)
            else:
                total += sub
        return total + cond_best

    entry = None
    for name in comps:
        if re.search(r"\bmain\b|entry", name, re.I):
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    total = weighted(entry, frozenset()) if entry else 0
    count = sum(c.coll_count for c in info.values())
    return {"bytes": int(total), "count": int(count),
            "by_kind": {k: int(v) for k, v in by_kind.items() if v}}


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> dict:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "bound_step_s": total,
    }


def analytic_memory_floor(cfg, shape_info, kind: str, chips: int,
                          param_bytes: int = 2) -> float:
    """Lower-bound HBM bytes per device per step (perfect fusion):
    params traffic + one write+read of each layer's residual stream +
    logits traffic + KV-cache traffic for decode.  The HLO 'bytes accessed'
    number is the no-fusion UPPER bound; truth on TPU lies between."""
    L, d, V = cfg.num_layers, cfg.d_model, cfg.vocab_size
    B = shape_info["global_batch"]
    S = shape_info["seq_len"] if kind != "decode" else 1
    tokens = B * S
    n_params = cfg.param_count()
    act_bytes = 2
    if kind == "train":
        p_traffic = 4 * n_params * param_bytes      # fwd + bwd reads, upd rw
        a_traffic = 4 * L * tokens * d * act_bytes  # residual save + remat
        logits = 3 * tokens * V * act_bytes
    elif kind == "prefill":
        p_traffic = n_params * param_bytes
        a_traffic = 2 * L * tokens * d * act_bytes
        logits = B * V * act_bytes
    else:
        n_active = cfg.active_param_count()
        p_traffic = n_active * param_bytes
        a_traffic = 2 * L * tokens * d * act_bytes
        logits = tokens * V * act_bytes
        # KV/state cache read per step
        Sc = shape_info["seq_len"]
        if cfg.attn_type == "mla":
            kvb = Sc * (cfg.kv_lora_rank + cfg.rope_head_dim)
        elif cfg.attn_type == "none":
            kvb = cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 2
        else:
            kvb = Sc * cfg.num_kv_heads * cfg.head_dim * 2
        n_attn = sum(cfg.layer_kind(i) == "attn" for i in range(L))
        n_ssm = L - n_attn
        cache = B * (n_attn * (Sc * cfg.num_kv_heads * cfg.head_dim * 2
                               if cfg.attn_type != "mla" else
                               Sc * (cfg.kv_lora_rank + cfg.rope_head_dim))
                     + n_ssm * cfg.ssm_heads * cfg.ssm_headdim
                     * cfg.ssm_state * 2) * param_bytes
        a_traffic += cache
    total = p_traffic + a_traffic + logits
    return total / chips

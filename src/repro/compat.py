"""JAX version-compatibility shims.

The repo targets the modern API (``jax.shard_map``, varying-mesh-axis
tracking via ``lax.pcast``), but must also run on older jax releases where
``shard_map`` still lives in ``jax.experimental.shard_map`` and VMA
tracking does not exist.  Import from here instead of feature-detecting at
call sites:

    from repro.compat import shard_map, pcast_varying
"""
from __future__ import annotations

import jax
from jax import lax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
        # old API tracks replication instead of varying-ness; its rep
        # checker predates the collectives idioms used here, so disable it
        return _shard_map_old(f, mesh, in_specs, out_specs, check_rep=False)


def pcast_varying(x, axis_name):
    """Mark ``x`` device-varying over ``axis_name`` (VMA tracking).  On old
    jax there is no VMA system and the value is returned unchanged."""
    pcast = getattr(lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axis_name, to="varying")

"""Fault-tolerant checkpointing (DESIGN.md §5).

* Atomic: write to ``step_XXXX.tmp`` then ``os.rename`` — a preempted save
  never corrupts the latest checkpoint.
* Versioned + keep_n GC; ``latest_step()`` drives auto-resume.
* Elastic: arrays are saved UNSHARDED (host-gathered) with their spec tree
  alongside, so a restore may target a different mesh/device-count than the
  save (tested 1 <-> 8 devices).  On a multi-host deployment this becomes
  per-host shard files + a reshard-on-load pass; single-process here.
* Data-iterator state (just the step for our deterministic pipeline) rides
  in the metadata.
* Shard-streaming saves (:meth:`CheckpointManager.stage_sharded`): large
  arrays may be streamed into the staged ``step_XXXX.tmp`` dir one shard
  file at a time and published with the same single ``os.rename`` — the
  out-of-core table build (docs/build_pipeline.md) emits suffix-array
  shards as rounds finish without ever holding the whole array.  A crash
  mid-stream leaves only a ``.tmp`` dir, which ``all_steps()`` ignores and
  ``Catalog.reconcile`` garbage-collects.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in flat], treedef


class ShardedSave:
    """One in-flight shard-streaming save: register -> stream shards ->
    publish atomically.

    Created by :meth:`CheckpointManager.stage_sharded`.  Shards of a named
    array are appended in order with :meth:`add_shard`; :meth:`commit`
    writes the remaining (small) state tree plus metadata and publishes
    the whole step with one rename.  Until then nothing is visible:
    ``all_steps()`` skips ``.tmp`` dirs, so a kill at ANY shard boundary
    leaves the previous published version untouched and the partial
    stream reclaimable (``Catalog.reconcile``)."""

    def __init__(self, manager: "CheckpointManager", step: int):
        self.manager = manager
        self.step = int(step)
        self.final = os.path.join(manager.dir, f"step_{step:010d}")
        self.tmp = self.final + ".tmp"
        if os.path.exists(self.tmp):
            shutil.rmtree(self.tmp)
        os.makedirs(self.tmp)
        self._shards: dict[str, dict] = {}
        self._done = False

    def add_shard(self, name: str, i: int, arr) -> str:
        """Stream shard ``i`` of array ``name`` (must arrive in order)."""
        if self._done:
            raise RuntimeError("ShardedSave already committed/aborted")
        ent = self._shards.setdefault(name, {"count": 0, "dtype": None})
        if i != ent["count"]:
            raise ValueError(f"shard {i} of {name!r} out of order "
                             f"(expected {ent['count']})")
        arr = np.asarray(jax.device_get(arr))
        np.save(os.path.join(self.tmp, f"shard_{name}_{i:06d}.npy"), arr)
        ent["count"] += 1
        ent["dtype"] = arr.dtype.name
        return f"shard_{name}_{i:06d}.npy"

    def commit(self, state: Any, extra: Optional[dict] = None) -> str:
        """Write the non-sharded state + metadata and publish the step.
        Sharded arrays come back from ``restore_arrays`` stitched under
        their plain name, exactly like ``save``'d leaves."""
        flat, _ = _flatten(state)
        arrays = {f"a{i}": np.asarray(jax.device_get(x))
                  for i, (_, x) in enumerate(flat)}
        meta = {"step": self.step,
                "paths": [p for p, _ in flat],
                "shards": self._shards,
                "extra": extra or {}}
        np.savez(os.path.join(self.tmp, "arrays.npz"), **arrays)
        with open(os.path.join(self.tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(self.final):
            shutil.rmtree(self.final)
        os.rename(self.tmp, self.final)          # atomic publish
        self._done = True
        self.manager._gc()
        return self.final

    def abort(self) -> None:
        """Discard the staged shards (graceful-failure path; a hard kill
        leaves the same end state via reconcile)."""
        self._done = True
        shutil.rmtree(self.tmp, ignore_errors=True)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)

    def stage_sharded(self, step: int) -> ShardedSave:
        """Open a shard-streaming save of ``step`` (see ShardedSave)."""
        return ShardedSave(self, step)

    # -------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        flat, _ = _flatten(state)
        arrays = {f"a{i}": np.asarray(jax.device_get(x))
                  for i, (_, x) in enumerate(flat)}
        meta = {"step": int(step),
                "paths": [p for p, _ in flat],
                "extra": extra or {}}
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                    # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_arrays(self, step: int):
        """Raw restore: ``({path: np.ndarray}, extra)`` with no ``like``
        tree — for callers (``repro.api.SuffixTable``) whose array shapes
        are only known from the checkpoint itself."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        arrays = {p: data[f"a{i}"] for i, p in enumerate(meta["paths"])}
        for name, ent in meta.get("shards", {}).items():
            parts = [np.load(os.path.join(path, f"shard_{name}_{i:06d}.npy"))
                     for i in range(ent["count"])]
            arrays[f"['{name}']"] = (
                np.concatenate(parts) if parts
                else np.zeros((0,), np.dtype(ent["dtype"] or "int32")))
        return arrays, meta["extra"]

    def restore(self, step: int, like: Any, shardings: Any = None):
        """Restore into the structure of ``like``; optionally device_put
        with ``shardings`` (tree of NamedSharding) — this is the elastic
        reshard-on-load path."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = _flatten(like)
        saved = {p: data[f"a{i}"] for i, p in enumerate(meta["paths"])}
        leaves = []
        for p, x in flat:
            if p not in saved:
                raise KeyError(f"checkpoint missing leaf {p}")
            a = saved[p]
            if tuple(a.shape) != tuple(x.shape):
                raise ValueError(f"shape mismatch at {p}: "
                                 f"{a.shape} vs {x.shape}")
            leaves.append(a.astype(x.dtype))
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, meta["extra"]

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, shardings)
        return step, tree, extra

"""Fault-tolerant checkpointing (DESIGN.md §5).

* Atomic: write to ``step_XXXX.tmp`` then ``os.rename`` — a preempted save
  never corrupts the latest checkpoint.
* Versioned + keep_n GC; ``latest_step()`` drives auto-resume.
* Elastic: arrays are saved UNSHARDED (host-gathered) with their spec tree
  alongside, so a restore may target a different mesh/device-count than the
  save (tested 1 <-> 8 devices).  On a multi-host deployment this becomes
  per-host shard files + a reshard-on-load pass; single-process here.
* Data-iterator state (just the step for our deterministic pipeline) rides
  in the metadata.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)

    # -------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        flat, _ = _flatten(state)
        arrays = {f"a{i}": np.asarray(jax.device_get(x))
                  for i, (_, x) in enumerate(flat)}
        meta = {"step": int(step),
                "paths": [p for p, _ in flat],
                "extra": extra or {}}
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                    # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_arrays(self, step: int):
        """Raw restore: ``({path: np.ndarray}, extra)`` with no ``like``
        tree — for callers (``repro.api.SuffixTable``) whose array shapes
        are only known from the checkpoint itself."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        arrays = {p: data[f"a{i}"] for i, p in enumerate(meta["paths"])}
        return arrays, meta["extra"]

    def restore(self, step: int, like: Any, shardings: Any = None):
        """Restore into the structure of ``like``; optionally device_put
        with ``shardings`` (tree of NamedSharding) — this is the elastic
        reshard-on-load path."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = _flatten(like)
        saved = {p: data[f"a{i}"] for i, p in enumerate(meta["paths"])}
        leaves = []
        for p, x in flat:
            if p not in saved:
                raise KeyError(f"checkpoint missing leaf {p}")
            a = saved[p]
            if tuple(a.shape) != tuple(x.shape):
                raise ValueError(f"shape mismatch at {p}: "
                                 f"{a.shape} vs {x.shape}")
            leaves.append(a.astype(x.dtype))
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, meta["extra"]

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, shardings)
        return step, tree, extra

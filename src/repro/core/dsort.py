"""Distributed sorts over a mesh axis (Accumulo-ingest analogue, DESIGN.md §2).

Two algorithms, both running *inside* ``shard_map`` (each device holds an
equal-length local block):

* ``bitonic_sort_sharded`` — block-bitonic merge network: log2(p)*(log2(p)+1)/2
  rounds of pairwise ``ppermute`` + local merge-split.  Deterministic, always
  correct, O(m log^2 p) exchanged bytes.  This is the BASELINE construction
  path (paper-faithful: Accumulo's LSM merge is also a merge network).
* ``sample_sort_sharded`` — one splitter round + one ``all_to_all``:
  O(m) exchanged bytes (~log^2 p fewer than bitonic) but requires a capacity
  factor because ``all_to_all`` chunks are fixed-size.  Returns an overflow
  flag; callers fall back to bitonic on overflow.  This is the BEYOND-PAPER
  optimization measured in EXPERIMENTS.md §Perf.

Keys are int32; values ride along.  Local blocks come back globally sorted
across the device axis (device d holds global ranks [d*m, (d+1)*m)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def _axis_size(axis_name) -> int:
    return lax.psum(1, axis_name)


def _merge_split(ops_a, ops_b, num_keys: int, keep_low, i_am_lower):
    """Merge two sorted blocks, keep low or high half (traced ``keep_low``).

    Both partners MUST materialize the identical merged array or tied keys
    split inconsistently (duplicating/dropping rows).  We therefore order the
    concatenation canonically: the lower-ranked device's block first.
    """
    first = tuple(jnp.where(i_am_lower, a, b) for a, b in zip(ops_a, ops_b))
    second = tuple(jnp.where(i_am_lower, b, a) for a, b in zip(ops_a, ops_b))
    cat = tuple(jnp.concatenate([f, s]) for f, s in zip(first, second))
    merged = lax.sort(cat, dimension=0, num_keys=num_keys, is_stable=True)
    m = ops_a[0].shape[0]
    lows = tuple(x[:m] for x in merged)
    highs = tuple(x[m:] for x in merged)
    return tuple(jnp.where(keep_low, lo, hi) for lo, hi in zip(lows, highs))


def bitonic_sort_sharded(operands, *, num_keys: int, axis_name):
    """Block-bitonic sort of equal-size local blocks across ``axis_name``.

    ``operands``: tuple of 1-D arrays (first ``num_keys`` are sort keys).
    Must be called inside shard_map.  p (axis size) must be a power of two.
    """
    operands = tuple(operands)
    p = _axis_size(axis_name)
    # p is static inside shard_map (mesh shape), so Python control flow is ok.
    log_p = int(np.log2(p))
    assert 1 << log_p == p, f"axis size {p} must be a power of two"
    d = lax.axis_index(axis_name)

    # 1. local sort
    operands = lax.sort(operands, dimension=0, num_keys=num_keys, is_stable=True)
    if p == 1:
        return operands

    # 2. bitonic network on blocks
    for stage in range(1, log_p + 1):
        k = 1 << stage  # ascending-run length being built (in blocks)
        for sub in range(stage - 1, -1, -1):
            j = 1 << sub
            perm = [(r, r ^ j) for r in range(p)]
            partner_ops = tuple(
                lax.ppermute(x, axis_name, perm) for x in operands
            )
            # keep_low iff direction(asc) == (I am the lower index of the pair)
            i_am_lower = (d & j) == 0
            keep_low = ((d & k) == 0) == i_am_lower
            operands = _merge_split(operands, partner_ops, num_keys,
                                    keep_low, i_am_lower)
    return operands


def sample_sort_sharded(operands, *, num_keys: int, axis_name,
                        capacity_factor: float = 2.0, oversample: int = 64):
    """One-shot sample sort: splitter selection + single all_to_all.

    Returns (sorted_operands, overflow: bool scalar).  On overflow the output
    is NOT a valid sort — callers must fall back (see sort_sharded_auto).
    Keys must be int32; composite keys are combined by the caller or passed
    as multiple key operands (only the FIRST key is used for splitting, which
    is correct because lax.sort finishes the job locally).
    """
    operands = tuple(operands)
    key = operands[0]
    p = _axis_size(axis_name)
    m = key.shape[0]
    d = lax.axis_index(axis_name)

    # --- splitters: regular sampling (PSRS-style), s per device -> p-1 cuts
    s = min(oversample, m)
    take = jnp.linspace(0, m - 1, s).astype(jnp.int32)
    local_sample = jnp.sort(key)[take]
    samples = lax.all_gather(local_sample, axis_name).reshape(-1)  # (p*s,)
    samples = jnp.sort(samples)
    cuts = samples[jnp.arange(1, p, dtype=jnp.int32) * s]          # (p-1,)

    # --- bucket assignment + fixed-capacity layout
    dest = jnp.searchsorted(cuts, key, side="right").astype(jnp.int32)  # (m,)
    cap = int(np.ceil(m / p * capacity_factor))
    # rank of each element within its bucket
    order = jnp.argsort(dest, stable=True)
    dest_sorted = dest[order]
    # position within bucket = index - start_of_bucket
    bucket_start = jnp.searchsorted(dest_sorted, jnp.arange(p, dtype=jnp.int32),
                                    side="left")
    within = jnp.arange(m, dtype=jnp.int32) - bucket_start[dest_sorted]
    overflow = jnp.any(within >= cap)
    slot = jnp.clip(within, 0, cap - 1)

    # scatter into (p, cap) send buffers; EMPTY = key sentinel INT32_MAX
    sentinel = jnp.int32(np.iinfo(np.int32).max)

    def to_buckets(x, fill):
        buf = jnp.full((p, cap), fill, x.dtype)
        return buf.at[dest_sorted, slot].set(x[order], mode="drop")

    send = tuple(
        to_buckets(x, sentinel if i < num_keys else jnp.zeros((), x.dtype))
        for i, x in enumerate(operands)
    )
    recv = tuple(
        lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)
        for x in send
    )  # (p, cap) each: rows from every device
    flat = tuple(x.reshape(-1) for x in recv)  # (p*cap,)

    # --- local sort; sentinels sink to the end
    flat = lax.sort(flat, dimension=0, num_keys=num_keys, is_stable=True)

    # --- re-balance to exactly m per device.  Data is now globally sorted but
    # ragged; element with global rank g belongs on device g // m.  Because
    # the distribution is sorted, owners are contiguous and (for good
    # splitters) near-diagonal: spill goes only to immediate neighbours via
    # two ppermutes of a fixed spill window H (no second all_to_all).
    # Spill window: bounded by the splitter-induced offset error, which for
    # regular sampling with s samples/device is O(m/s) per device, O(p*m/s)
    # cumulative in the worst case; size generously and keep the flag.
    H = min(p * cap, cap + max(1, m // 4))
    n_real_local = jnp.sum((flat[0] != sentinel).astype(jnp.int32))
    counts = lax.all_gather(n_real_local, axis_name)               # (p,)
    my_offset = jnp.sum(jnp.where(jnp.arange(p) < d, counts, 0))
    gidx = my_offset + jnp.arange(p * cap, dtype=jnp.int32)        # global rank
    valid = flat[0] != sentinel
    grank = jnp.where(valid, gidx, -1)
    owner = jnp.where(valid, gidx // m, -1)
    # anything spilling beyond immediate neighbours => splitters too bad
    overflow = overflow | jnp.any(valid & (jnp.abs(owner - d) > 1))
    flat = flat + (grank,)

    lo, hi = d * m, (d + 1) * m

    def spill(direction):
        """Fixed-H buffer of rows destined to neighbour d+direction."""
        if direction < 0:
            sel = valid & (gidx < lo)
            slot_ = gidx - my_offset                 # first n_left rows
        else:
            sel = valid & (gidx >= hi)
            slot_ = gidx - hi                        # rank within right spill
        slot_ = jnp.where(sel, slot_, p * cap)
        nonlocal overflow
        overflow = overflow | jnp.any(sel & (slot_ >= H))
        bufs = []
        for x in flat:
            fill = jnp.array(sentinel if x.dtype == jnp.int32 else 0, x.dtype)
            buf = jnp.full((H,), -1 if x is flat[-1] else fill, x.dtype)
            bufs.append(buf.at[slot_].set(jnp.where(sel, x, buf[0]), mode="drop"))
        return tuple(bufs)

    left_spill = spill(-1)   # rows whose owner is d-1 (or worse -> flagged)
    right_spill = spill(+1)
    perm_r = [(r, (r + 1) % p) for r in range(p)]   # send to right neighbour
    perm_l = [(r, (r - 1) % p) for r in range(p)]   # send to left neighbour
    from_left = tuple(lax.ppermute(x, axis_name, perm_r) for x in right_spill)
    from_right = tuple(lax.ppermute(x, axis_name, perm_l) for x in left_spill)

    out = []
    for i, x in enumerate(flat[:-1]):
        buf = jnp.zeros((m,), x.dtype)
        g_mine = flat[-1]
        buf = buf.at[jnp.where((g_mine >= lo) & (g_mine < hi), g_mine - lo, m)
                     ].set(x, mode="drop")
        g_l = from_left[-1]
        buf = buf.at[jnp.where((g_l >= lo) & (g_l < hi), g_l - lo, m)
                     ].set(from_left[i], mode="drop")
        g_r = from_right[-1]
        buf = buf.at[jnp.where((g_r >= lo) & (g_r < hi), g_r - lo, m)
                     ].set(from_right[i], mode="drop")
        out.append(buf)

    overflow = lax.psum(overflow.astype(jnp.int32), axis_name) > 0
    return tuple(out), overflow


class _RunCursor:
    """Merge-side view of one sorted run: a cursor plus one cached block
    so threshold peeks and takes never re-read spilled bytes."""

    __slots__ = ("run", "n", "cur", "_blo", "_key", "_idx")

    def __init__(self, run):
        self.run = run
        self.n = int(run.n)
        self.cur = 0
        self._blo = -1
        self._key = self._idx = None

    def _ensure(self, block_rows: int):
        if self._blo <= self.cur and self._key is not None \
                and self.cur < self._blo + self._key.shape[0]:
            return
        self._blo = self.cur
        self._key, self._idx = self.run.read_block(
            self.cur, min(self.cur + block_rows, self.n))

    def block(self, block_rows: int):
        """The (key, idx) rows [cur, min(cur+block_rows, n))."""
        self._ensure(block_rows)
        s = self.cur - self._blo
        return self._key[s:], self._idx[s:]

    def block_end(self, block_rows: int):
        """(key, idx) of the last row of the current block — the run's
        contribution to the merge threshold."""
        k, i = self.block(block_rows)
        return int(k[-1]), int(i[-1])


def merge_sorted_runs(runs, *, block_rows: int = 1 << 15):
    """Streaming k-way merge of sorted ``(key, idx)`` runs — the host
    half of the staged external sort (``repro.core.build_pipeline``).

    Each run exposes ``n`` and ``read_block(lo, hi) -> (key int64,
    idx int32)`` and is sorted ascending by ``(key, idx)`` with idx
    globally unique.  Yields ``(key, idx)`` blocks that concatenate to
    the full merge, using O(len(runs) * block_rows) host memory — never
    more than one block per run is resident, so spilled runs merge
    without being materialized.

    Per iteration the threshold ``T`` is the lexicographic minimum of
    every run's current block-end ``(key, idx)`` pair; because idx makes
    pairs unique, each run holds at most ``block_rows`` rows ``<= T``
    (they all sit inside its current block), so one iteration moves at
    least ``block_rows`` rows (the argmin run drains its whole block)
    while gathering at most ``block_rows`` per run."""
    live = [_RunCursor(r) for r in runs if int(r.n) > 0]
    if len(live) == 1:
        # single-run fast path: the run IS the merge (chunk_rows >= n)
        c = live[0]
        while c.cur < c.n:
            k, i = c.block(block_rows)
            c.cur += k.shape[0]
            yield k, i
        return
    while live:
        t_key, t_idx = min(c.block_end(block_rows) for c in live)
        parts_k, parts_i = [], []
        for c in live:
            kblk, iblk = c.block(block_rows)
            take = int(np.searchsorted(kblk, t_key, side="left"))
            hi = int(np.searchsorted(kblk, t_key, side="right"))
            if hi > take:       # ties on key: idx breaks them exactly
                take += int(np.searchsorted(iblk[take:hi], t_idx,
                                            side="right"))
            if take:
                parts_k.append(kblk[:take])
                parts_i.append(iblk[:take])
                c.cur += take
        live = [c for c in live if c.cur < c.n]
        if len(parts_k) == 1:
            yield parts_k[0], parts_i[0]
            continue
        key = np.concatenate(parts_k)
        idx = np.concatenate(parts_i)
        order = np.lexsort((idx, key))
        yield key[order], idx[order]


def sort_sharded_auto(operands, *, num_keys: int, axis_name,
                      capacity_factor: float = 2.0, oversample: int = 64):
    """Sample sort with a bitonic fallback when splitters overflow capacity.

    The overflow flag is psum-reduced, hence uniform across devices, so the
    ``lax.cond`` branch choice is consistent and the collectives inside both
    branches stay SPMD-coherent.  Fast path: O(m) bytes on the wire; fallback:
    O(m log^2 p).  Dup-heavy keys (early prefix-doubling rounds) take the
    fallback; near-unique keys (late rounds, scatter-by-position) stay fast.
    """
    operands = tuple(operands)
    fast, overflow = sample_sort_sharded(
        operands, num_keys=num_keys, axis_name=axis_name,
        capacity_factor=capacity_factor, oversample=oversample)

    def use_fast(_):
        return fast

    def use_bitonic(_):
        return bitonic_sort_sharded(operands, num_keys=num_keys,
                                    axis_name=axis_name)

    return lax.cond(overflow, use_bitonic, use_fast, None)

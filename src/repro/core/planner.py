"""Scan planner — the single entry point for all pattern lookups.

The store exposes three scan implementations (`repro.core.query`):

* ``query``          — single-device batched binary search;
* ``query_sharded``  — broadcast fan-out: every tablet searches its local
  rows for every query, bounds are psum'd (paper-faithful Accumulo scan);
* ``query_routed``   — each query travels to its owner tablet through a
  fixed-capacity all_to_all (MoE-dispatch shape).  Cheaper per device but
  *partial*: it returns sentinel counts that callers must handle.

Sentinel semantics (``MatchResult.count`` from the routed path):

====== =====================================================================
value  meaning
====== =====================================================================
``>0``   exact occurrence count
``0``    exact: no match
``-1``   dispatch overflow — a hot tablet received more queries than its
         capacity slots; the query was never executed.  ``found`` is False
         but unreliable.
``-2``   saturated run — the match run spans more than two tablets (very
         short pattern); ``found``/``first_pos`` are exact, the count is not.
====== =====================================================================

The planner makes those sentinels invisible: any query coming back with a
negative count is transparently re-executed through an exact path
(broadcast when a mesh is live, single-device otherwise), so **callers
always get exact counts**.  This is the retry guarantee tested against
``brute_force_count`` in ``tests/test_planner.py`` and
``tests/test_distributed.py``.

On top of the exact scan the planner adds:

* :meth:`ScanPlanner.locate` — match *enumeration*: up to ``top_k``
  occurrence positions per query, gathered from the SA slice ``[lb, ub)``
  (previously only ``first_pos`` was exposed);
* an LRU result cache for repeated hot patterns (string-level API);
* :meth:`ScanPlanner.plan` — mode selection from mesh shape and batch
  size, overridable per call for benchmarking.

See ``docs/scan_planner.md`` for the full contract.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core import codec
from repro.core import query as Q
from repro.core.query import MatchResult
from repro.core.tablet import TabletStore
from repro.serving.trace import Tracer

MODE_SINGLE = "single"
MODE_BROADCAST = "broadcast"
MODE_ROUTED = "routed"
MODE_FM = "fm"            # frozen tier: FM-index backward search


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """One planning decision: which executor a batch will run through."""
    mode: str      # MODE_SINGLE | MODE_BROADCAST | MODE_ROUTED | MODE_FM
    reason: str
    batch: int


@dataclasses.dataclass
class PlannerStats:
    """Counters for observability; reset with :meth:`ScanPlanner.reset_stats`."""
    batches: int = 0
    queries: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    retried_overflow: int = 0     # -1 sentinels re-executed
    retried_saturated: int = 0    # -2 sentinels re-executed
    retried_inexact_rank: int = 0  # found but first_rank < 0 (defensive)
    # batch-slot accounting for the client's bucket-padded batches: a
    # batch submitted with n_real carries B - n_real padding slots
    # (shape bucketing); ``queries`` above counts only the real ones.
    # (True cross-caller coalescing is counted by SchedulerStats in
    # repro.api.client — these count slot usage per dispatch.)
    bucketed_batches: int = 0
    bucketed_queries: int = 0
    pad_slots: int = 0
    mode_counts: dict = dataclasses.field(
        default_factory=lambda: {MODE_SINGLE: 0, MODE_BROADCAST: 0,
                                 MODE_ROUTED: 0, MODE_FM: 0})
    # fused read-path counters (docs/read_path.md): ``fused_batches``
    # crossed the device boundary ONCE for base + all delta tiers;
    # ``base_only_batches`` took the no-delta fast path.  ``tier_reads``
    # counts logical tier visits per kind — under the old fan-out each
    # visit was its own dispatch, so (runs + memtable) / fused_batches
    # is the dispatch count a batch no longer pays.
    fused_batches: int = 0
    base_only_batches: int = 0
    tier_reads: dict = dataclasses.field(
        default_factory=lambda: {"base": 0, "runs": 0, "memtable": 0})

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mode_counts"] = dict(self.mode_counts)
        d["tier_reads"] = dict(self.tier_reads)
        return d


@dataclasses.dataclass(frozen=True)
class TierScanResult:
    """The fused tier scan's per-tier outputs ((T, B) int32 each; tier
    order = the TierSet's).  ``less``/``matches`` delimit each tier's
    raw prefix-match run in its own suffix array — enough for the table
    to enumerate owned positions by pure host slicing.  Fields are
    still-async device handles; count-only callers never force the
    sync, enumeration converts with ``np.asarray`` when it slices."""
    count: "np.ndarray"    # occurrences the tier owns (bounds applied)
    less: "np.ndarray"     # rows strictly before the pattern (slice lb)
    matches: "np.ndarray"  # raw prefix-match run length (no bounds)
    first_g: "np.ndarray"  # min owned global position (2**30 if none)


class TopKCache:
    """LRU over pattern strings, top_k-aware and generation-stamped.

    One entry per pattern holds ``(generation, count, first_pos,
    k_stored, row)``.  An entry cached with ``k_stored`` positions
    serves ANY request with ``top_k <= k_stored`` by slicing, and any
    ``top_k`` at all when the cached position set is complete
    (``count <= k_stored``) — instead of storing duplicate entries per
    ``(pattern, top_k)`` key.  A request needing more positions than
    stored is a miss and its result overwrites the entry (never with
    fewer positions than it had).

    Every entry is stamped with the cache's ``generation`` at put time;
    :meth:`bump` advances the generation, lazily invalidating every
    older entry in O(1) — the write path (``append`` /
    ``minor_compact`` / ``compact``) bumps instead of serving counts
    from before the logical text changed.  Shared by
    :class:`ScanPlanner` and ``repro.api.SuffixTable``.
    """

    def __init__(self, size: int):
        self.size = int(size)
        self.generation = 0
        self.hits = 0
        self.misses = 0
        self._d: OrderedDict[str, tuple] = OrderedDict()
        # the client's scheduler worker and inline callers share this
        # cache across threads; every mutating path is check-then-act on
        # the OrderedDict, so each method holds the lock
        self._lock = threading.Lock()

    def get(self, pattern: str, top_k: int):
        """(count, first_pos, positions (top_k,) | None) or None on miss."""
        if self.size <= 0:
            return None
        with self._lock:
            ent = self._d.get(pattern)
            if ent is not None and ent[0] != self.generation:
                del self._d[pattern]         # stamped before the last write
                ent = None
            if ent is None:
                self.misses += 1
                return None
            _gen, count, first_pos, k_stored, row = ent
            if top_k > 0 and k_stored < top_k and count > k_stored:
                self.misses += 1
                return None        # not enough positions cached
            self._d.move_to_end(pattern)
            self.hits += 1
        if top_k <= 0:
            return count, first_pos, None
        out = np.full(top_k, -1, np.int64)
        if row is not None:
            take = np.asarray(row)[:top_k]
            out[:take.shape[0]] = take
        return count, first_pos, out

    def put(self, pattern: str, count: int, first_pos: int,
            k_stored: int, row) -> None:
        if self.size <= 0:
            return
        with self._lock:
            old = self._d.get(pattern)
            if (old is not None and old[0] == self.generation
                    and old[3] > k_stored):
                self._d.move_to_end(pattern)  # keep the richer live entry
                return
            self._d[pattern] = (self.generation, int(count), int(first_pos),
                                int(k_stored),
                                None if row is None else np.asarray(row))
            self._d.move_to_end(pattern)
            while len(self._d) > self.size:
                self._d.popitem(last=False)

    def bump(self) -> int:
        """Invalidate every current entry (O(1)): stale entries are
        dropped lazily on their next lookup.  Returns the new
        generation — ``repro.api.SuffixTable`` stamps this into its
        :meth:`~repro.api.SuffixTable.stats` so staleness is observable."""
        with self._lock:
            self.generation += 1
            return self.generation

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)


@dataclasses.dataclass(frozen=True)
class ScanOutcome:
    """Host-side result of a string-level scan: exact counts always.

    ``positions`` is present when ``top_k > 0``: shape (B, top_k) int64,
    row i holding up to ``min(count[i], top_k)`` occurrence positions in
    suffix-rank order (lexicographically smallest matching suffix first),
    padded with -1.  (``SuffixTable.scan`` fills the same shape in
    text order instead — smallest positions first.)
    """
    found: np.ndarray        # (B,)  bool
    count: np.ndarray        # (B,)  int64
    first_pos: np.ndarray    # (B,)  int64
    positions: Optional[np.ndarray] = None   # (B, top_k) int64 | None


class ScanPlanner:
    """Plans, executes, retries, and caches pattern scans over a store.

    Parameters
    ----------
    store:
        The tablet store (full replicated SA + text).
    mesh, axis_name:
        Optional 1-D jax mesh over tablets.  When absent (or 1 device),
        every scan runs the single-device path.
    capacity_factor:
        Dispatch capacity for the routed path (MoE-style); lower values
        save bandwidth but overflow hot tablets more often — overflow is
        corrected by the retry pass, trading latency for exactness.
    routed_min_batch:
        Batches at least this large prefer the routed path (per-device
        work O(B/p log m) instead of O(B log m)); smaller batches
        broadcast.  The routed path also requires a DNA store and a batch
        divisible into the mesh (the planner pads internally).
    cache_size:
        LRU entries for the string-level API (0 disables caching).
    """

    def __init__(self, store: TabletStore, *, mesh=None,
                 axis_name: str = "tablets", capacity_factor: float = 2.0,
                 routed_min_batch: int = 64, cache_size: int = 4096,
                 max_pattern_len: Optional[int] = None, fm=None,
                 tracer: Optional[Tracer] = None):
        self.store = store
        self.mesh = mesh if fm is None else None   # frozen = single-replica
        self.fm = fm
        mesh = self.mesh
        self.axis_name = axis_name
        if mesh is not None:
            p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
            if store.n_pad % p != 0:
                raise ValueError(
                    f"store.n_pad={store.n_pad} is not divisible by the "
                    f"mesh's {p} tablets — rebuild the store with "
                    f"num_tablets={p} (build_tablet_store)")
        self.capacity_factor = float(capacity_factor)
        self.routed_min_batch = int(routed_min_batch)
        self.cache_size = int(cache_size)
        self.max_pattern_len = int(max_pattern_len or store.max_query_len)
        self.stats = PlannerStats()
        # shared with the owning table so span histograms survive
        # rebind/recreation across freeze and compaction
        self.tracer = tracer if tracer is not None else Tracer()
        self._cache = TopKCache(self.cache_size)
        self._sa_host: Optional[np.ndarray] = None
        # executors are built lazily and injectable for tests: each maps
        # (patt, plen) -> MatchResult
        self._executors: dict[str, Callable] = {}

    def rebind(self, store: TabletStore, *, fm=None) -> None:
        """Swap the underlying store in place (major compaction publishes
        a new base).  Captured planner references — the serving engine
        holds one — keep serving the NEW text instead of going silently
        stale: jitted executors are rebuilt lazily against the new store,
        the host SA copy is dropped, and the string-result cache is
        generation-bumped.  Accumulated stats survive the rebind.

        ``fm`` swaps the table onto (or off) the frozen tier: base reads
        route through the FM-index instead of ``store.sa``.  Frozen
        tables serve single-replica, so a live mesh is dropped (the
        store's divisibility constraint goes with it)."""
        self.fm = fm
        if fm is not None:
            self.mesh = None
        if self.mesh is not None:
            p = self.num_tablets
            if store.n_pad % p != 0:
                raise ValueError(
                    f"store.n_pad={store.n_pad} is not divisible by the "
                    f"mesh's {p} tablets — rebuild the store with "
                    f"num_tablets={p} (build_tablet_store)")
        self.store = store
        self.max_pattern_len = int(store.max_query_len)
        self._executors.clear()
        self._sa_host = None
        self._cache.bump()

    def invalidate_cache(self) -> int:
        """Generation-bump the string-result cache: every cached
        count/top-k from before this call becomes unservable.  The table
        write path calls this on ``append`` / ``minor_compact`` /
        ``compact`` so no read can observe pre-write results."""
        return self._cache.bump()

    # -- planning -----------------------------------------------------------
    @property
    def num_tablets(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.mesh.axis_names]))

    def plan(self, batch: int) -> ScanPlan:
        """Pick the executor for a batch of ``batch`` queries."""
        if self.fm is not None:
            return ScanPlan(MODE_FM,
                            "frozen table: FM backward search", batch)
        p = self.num_tablets
        if p <= 1:
            return ScanPlan(MODE_SINGLE, "no mesh / single device", batch)
        if (self.store.is_dna and batch >= max(self.routed_min_batch, p)):
            return ScanPlan(
                MODE_ROUTED,
                f"batch {batch} >= {self.routed_min_batch} on {p} tablets: "
                f"route queries to owners", batch)
        return ScanPlan(MODE_BROADCAST,
                        f"small batch ({batch}) or non-DNA store: "
                        f"broadcast to all {p} tablets", batch)

    # -- executors ----------------------------------------------------------
    def _executor(self, mode: str) -> Callable:
        fn = self._executors.get(mode)
        if fn is None:
            fn = self._build_executor(mode)
            self._executors[mode] = fn
        return fn

    def _build_executor(self, mode: str) -> Callable:
        store = self.store
        if mode == MODE_SINGLE:
            return jax.jit(lambda patt, plen: Q.query(store, patt, plen))
        if mode == MODE_FM:
            if self.fm is None:
                raise ValueError("mode 'fm' requires a frozen table "
                                 "(planner has no FM-index bound)")
            from repro.kernels import ops
            fmarr = self.fm.arrays
            return lambda patt, plen: ops.fm_search(fmarr, patt, plen)

        from jax.sharding import PartitionSpec as P
        ax = self.axis_name
        if mode == MODE_BROADCAST:
            @jax.jit
            @partial(shard_map, mesh=self.mesh,
                     in_specs=(P(ax), None, P(), P()), out_specs=P())
            def broadcast(sa_local, meta, patt, plen):
                return Q.query_sharded(sa_local, meta, patt, plen, ax)

            return lambda patt, plen: broadcast(store.sa, store, patt, plen)

        if mode == MODE_ROUTED:
            cf = self.capacity_factor

            @jax.jit
            @partial(shard_map, mesh=self.mesh,
                     in_specs=(P(ax), None, P(ax), P(ax)), out_specs=P(ax))
            def routed(sa_local, meta, patt, plen):
                return Q.query_routed(sa_local, meta, patt, plen, ax,
                                      capacity_factor=cf)

            def run(patt, plen):
                # routed shards the query batch: pad B to a multiple of p
                p = self.num_tablets
                B = patt.shape[0]
                pad = (-B) % p
                if pad:
                    patt = jnp.concatenate(
                        [patt, jnp.zeros((pad,) + patt.shape[1:],
                                         patt.dtype)])
                    plen = jnp.concatenate(
                        [plen, jnp.ones((pad,), plen.dtype)])
                res = routed(store.sa, store, patt, plen)
                if pad:
                    res = MatchResult(found=res.found[:B],
                                      count=res.count[:B],
                                      first_rank=res.first_rank[:B],
                                      first_pos=res.first_pos[:B])
                return res

            return run

        raise ValueError(f"unknown scan mode {mode!r}")

    def _exact_mode(self) -> str:
        return MODE_SINGLE if self.num_tablets <= 1 else MODE_BROADCAST

    # -- encoded-batch API --------------------------------------------------
    def _check_plen(self, plen, B: int,
                    n_real: Optional[int] = None) -> None:
        if n_real is not None and not 0 <= n_real <= B:
            raise ValueError(f"n_real={n_real} out of range for batch {B}")
        if B:
            max_plen = int(np.max(np.asarray(plen)))
            if max_plen > self.max_pattern_len:
                raise ValueError(
                    f"pattern length {max_plen} exceeds max_pattern_len="
                    f"{self.max_pattern_len}; compares are depth-capped, so "
                    f"longer patterns would be silently truncated — rebuild "
                    f"the store with a larger max_query_len")

    def _account(self, chosen: str, B: int,
                 n_real: Optional[int]) -> None:
        self.stats.batches += 1
        if n_real is None:
            self.stats.queries += B
        else:
            self.stats.queries += n_real
            self.stats.bucketed_batches += 1
            self.stats.bucketed_queries += n_real
            self.stats.pad_slots += B - n_real
        self.stats.mode_counts[chosen] += 1

    def scan_encoded(self, patt, plen, *, mode: Optional[str] = None,
                     retry: bool = True,
                     n_real: Optional[int] = None) -> MatchResult:
        """Exact scan of an encoded batch (packed uint32 DNA or int32 codes).

        Selects the executor via :meth:`plan` (or ``mode`` when forced),
        then re-executes any query whose routed count came back negative
        (-1 overflow / -2 saturated) through the exact path.  With
        ``retry=False`` the raw sentinels are returned (benchmarks only).

        ``n_real`` is the client's batch-slot accounting: the trailing
        ``B - n_real`` rows are shape-bucketing padding whose results
        the caller discards.  Stats then attribute only the real queries
        to ``queries`` (and record the batch under ``bucketed_batches``
        / ``pad_slots``); execution is unchanged — padding rows still
        run, which is the point of bucketing.
        """
        B = int(patt.shape[0])
        self._check_plen(plen, B, n_real)
        chosen = mode or self.plan(B).mode
        if chosen not in (MODE_SINGLE, MODE_BROADCAST, MODE_ROUTED,
                          MODE_FM):
            raise ValueError(f"unknown scan mode {chosen!r}")
        if (chosen not in (MODE_SINGLE, MODE_FM) and self.mesh is None
                and chosen not in self._executors):  # injected fakes are ok
            raise ValueError(
                f"mode {chosen!r} requires a mesh; this planner has none")
        self._account(chosen, B, n_real)
        self.stats.tier_reads["base"] += 1
        if B == 0:
            z = jnp.zeros((0,), jnp.int32)
            return MatchResult(found=z.astype(bool), count=z,
                               first_rank=z, first_pos=z)
        # NOTE jax dispatch is async: this span measures enqueue + any
        # host work the executor does; device wait is paid (and traced)
        # by whichever downstream span first forces the result
        with self.tracer.span("dispatch_" + chosen):
            res = self._executor(chosen)(patt, plen)
        if chosen != MODE_ROUTED or not retry:
            return res

        count = np.asarray(res.count)
        # retry negative sentinels, plus any row claiming a match without a
        # usable rank (defensive: rank feeds locate()'s SA-slice gather)
        rank_bad = (count > 0) & (np.asarray(res.first_rank) < 0)
        bad = np.flatnonzero((count < 0) | rank_bad)
        if bad.size == 0:
            return res
        self.stats.retried_overflow += int((count[bad] == -1).sum())
        self.stats.retried_saturated += int((count[bad] == -2).sum())
        self.stats.retried_inexact_rank += int(rank_bad.sum())
        # pad the retry batch to a power-of-two bucket: its size varies
        # per batch, and the jitted exact executor recompiles per shape —
        # bucketing bounds that to log2(B) compilations
        n_bad = int(bad.size)
        bucket = 1 << (n_bad - 1).bit_length() if n_bad > 1 else 1
        take = np.concatenate(
            [bad, np.full(bucket - n_bad, bad[0], bad.dtype)])
        sub = self._executor(self._exact_mode())(
            jnp.asarray(np.asarray(patt)[take]),
            jnp.asarray(np.asarray(plen)[take]))
        sub = MatchResult(found=sub.found[:n_bad], count=sub.count[:n_bad],
                          first_rank=sub.first_rank[:n_bad],
                          first_pos=sub.first_pos[:n_bad])
        found = np.asarray(res.found).copy()
        first_rank = np.asarray(res.first_rank).copy()
        first_pos = np.asarray(res.first_pos).copy()
        count = count.copy()
        found[bad] = np.asarray(sub.found)
        count[bad] = np.asarray(sub.count)
        first_rank[bad] = np.asarray(sub.first_rank)
        first_pos[bad] = np.asarray(sub.first_pos)
        return MatchResult(found=jnp.asarray(found), count=jnp.asarray(count),
                           first_rank=jnp.asarray(first_rank),
                           first_pos=jnp.asarray(first_pos))

    # -- fused multi-tier scan ----------------------------------------------
    def scan_tiers(self, tierset, patt, plen, *,
                   mode: Optional[str] = None, retry: bool = True,
                   n_real: Optional[int] = None
                   ) -> tuple[MatchResult, Optional[TierScanResult]]:
        """Merged read over base + every delta tier of ``tierset`` (a
        ``repro.api.runs.TierSet`` or None).  Returns the MERGED
        MatchResult — exact total counts, text-minimum ``first_pos``,
        base-only ``first_rank`` (docs/table_api.md) — plus the per-tier
        :class:`TierScanResult` for enumeration (None when the base-only
        fast path ran).

        Single-device batches fuse end to end: base binary search, all
        tier scans, straddle masks, and the merge ride ONE jitted launch
        (``kernels.ops.fused_single``).  Mesh batches keep their exact
        sharded base dispatch — with its sentinel retries — and add one
        fused launch for all delta tiers.  Either way a batch crosses
        the layer boundary once, not once per tier.
        """
        B = int(patt.shape[0])
        if tierset is None or tierset.num_tiers == 0 or B == 0:
            res = self.scan_encoded(patt, plen, mode=mode, retry=retry,
                                    n_real=n_real)
            self.stats.base_only_batches += 1
            return res, None
        self._check_plen(plen, B, n_real)
        n_runs = sum(1 for k in tierset.kinds if k == "run")
        chosen = mode or self.plan(B).mode
        from repro.kernels import ops

        if chosen == MODE_SINGLE:
            self._account(chosen, B, n_real)
            self.stats.tier_reads["base"] += 1
            with self.tracer.span("dispatch_fused"):
                merged, _base, tiers = ops.fused_single(
                    self.store, tierset.stack, patt, plen)
        else:
            # mesh base scan keeps its own dispatch (and sentinel
            # retries); scan_encoded does the accounting for it
            base = self.scan_encoded(patt, plen, mode=chosen, retry=retry,
                                     n_real=n_real)
            with self.tracer.span("dispatch_fused"):
                tiers = ops.fused_tiers(tierset.stack, patt, plen)
            from repro.kernels.tier_scan import merge_tier_results
            merged = merge_tier_results(
                MatchResult(found=jnp.asarray(base.found),
                            count=jnp.asarray(base.count, jnp.int32),
                            first_rank=jnp.asarray(base.first_rank,
                                                   jnp.int32),
                            first_pos=jnp.asarray(base.first_pos,
                                                  jnp.int32)),
                tiers[0], tiers[3])
        self.stats.fused_batches += 1
        self.stats.tier_reads["runs"] += n_runs
        self.stats.tier_reads["memtable"] += tierset.num_tiers - n_runs
        # handles stay on device: the count-only path (scan_encoded)
        # never pays the host sync; enumeration converts lazily
        tres = TierScanResult(count=tiers[0], less=tiers[1],
                              matches=tiers[2], first_g=tiers[3])
        return merged, tres

    # -- match enumeration --------------------------------------------------
    def _sa(self) -> np.ndarray:
        if self._sa_host is None:
            self._sa_host = np.asarray(self.store.sa)
        return self._sa_host

    def locate_encoded(self, patt, plen, top_k: int = 8,
                       *, mode: Optional[str] = None) -> np.ndarray:
        """Up to ``top_k`` occurrence positions per query, (B, top_k) int.

        Positions come from the SA slice ``[lb, lb + min(count, top_k))``
        — suffix-rank order, so position j is the start of the (j+1)-th
        lexicographically smallest matching suffix.  Rows are padded with
        -1 past ``count``.
        """
        res = self.scan_encoded(patt, plen, mode=mode)
        return self.positions_from_result(res, top_k)

    def positions_from_result(self, res: MatchResult,
                              top_k: int = 8) -> np.ndarray:
        """Enumerate positions for an already-exact MatchResult."""
        count = np.asarray(res.count)
        found = np.asarray(res.found)
        first_rank = np.asarray(res.first_rank)
        if self.fm is not None:
            # frozen tier: no SA to slice — LF-walk the SA$ rows
            # [lo, lo + min(count, top_k)) back to text positions
            k = np.arange(max(int(top_k), 1))[None, :]
            rows = first_rank[:, None] + 1 + k           # SA$ row = rank + 1
            valid = ((found & (first_rank >= 0))[:, None]
                     & (k < count[:, None]))
            rows = np.clip(rows, 1, self.fm.n)
            pos = self.fm.ranks_to_positions(
                rows.reshape(-1)).reshape(rows.shape)
            return np.where(valid, pos, -1)[:, :top_k].astype(np.int64)
        sa = self._sa()
        lb = first_rank + self.store.pad_count        # global SA row of lb
        k = np.arange(max(int(top_k), 1))[None, :]
        idx = lb[:, None] + k
        # a row without a usable rank cannot be enumerated — never emit
        # garbage SA gathers (scan_encoded's retry makes this unreachable
        # for its callers, but the method is public)
        valid = (found & (first_rank >= 0))[:, None] & (k < count[:, None])
        idx = np.clip(idx, 0, sa.shape[0] - 1)
        return np.where(valid, sa[idx], -1)[:, :top_k].astype(np.int64)

    # -- string-level API with LRU cache ------------------------------------
    def encode(self, patterns: list[str]):
        """Encode pattern strings for :meth:`scan_encoded`: (patt, plen).

        Packed uint32 words for DNA stores (word-packing rounds the width
        up to a 16-base multiple), exact-width int32 codes otherwise.
        Raises on any pattern longer than ``max_pattern_len`` — compares
        are depth-capped, so a longer pattern would silently match on its
        truncated prefix.
        """
        for p in patterns:
            if len(p) > self.max_pattern_len:
                raise ValueError(
                    f"pattern of length {len(p)} exceeds max_pattern_len="
                    f"{self.max_pattern_len} ({p[:32]!r}...); compares are "
                    f"depth-capped, so it would be silently truncated")
        if self.store.is_dna:
            width = (codec.packed_length(self.max_pattern_len)
                     * codec.BASES_PER_WORD)
            _codes, packed, lengths = Q.encode_patterns(patterns, width)
            return packed, lengths
        codes, _packed, lengths = Q.encode_patterns(patterns,
                                                    self.max_pattern_len)
        return codes, lengths

    # back-compat alias (pre-api_redesign name)
    _encode = encode

    def scan(self, patterns: list[str], top_k: int = 0) -> ScanOutcome:
        """Scan a batch of pattern strings; exact counts, optional
        enumeration, LRU-cached per pattern (top_k-aware: see
        :class:`TopKCache`)."""
        B = len(patterns)
        count = np.full(B, -1, np.int64)
        first_pos = np.full(B, -1, np.int64)
        positions = (np.full((B, top_k), -1, np.int64) if top_k else None)
        miss_idx: list[int] = []
        for i, pat in enumerate(patterns):
            hit = self._cache.get(pat, top_k)
            if hit is not None:
                count[i], first_pos[i] = hit[0], hit[1]
                if top_k:
                    positions[i] = hit[2]
            else:
                miss_idx.append(i)
        self.stats.cache_hits += B - len(miss_idx)
        self.stats.cache_misses += len(miss_idx)

        if miss_idx:
            patt, plen = self.encode([patterns[i] for i in miss_idx])
            res = self.scan_encoded(patt, plen)
            sub_count = np.asarray(res.count)
            sub_first = np.asarray(res.first_pos)
            sub_pos = (self.positions_from_result(res, top_k)
                       if top_k else None)
            for j, i in enumerate(miss_idx):
                count[i] = sub_count[j]
                first_pos[i] = sub_first[j]
                row = sub_pos[j] if top_k else None
                if top_k:
                    positions[i] = row
                self._cache.put(patterns[i], int(sub_count[j]),
                                int(sub_first[j]), top_k, row)
        return ScanOutcome(found=count > 0, count=count,
                           first_pos=first_pos, positions=positions)

    def locate(self, patterns: list[str], top_k: int = 8) -> np.ndarray:
        """String-level enumeration: (B, top_k) positions, -1 padded."""
        return self.scan(patterns, top_k=top_k).positions

    def clear_cache(self) -> None:
        self._cache.clear()

    def reset_stats(self) -> None:
        self.stats = PlannerStats()

"""repro.core — the paper's contribution: a tablet-sharded suffix-array
engine (construction, storage, scan) in JAX.  See DESIGN.md."""
from repro.core import codec, dedup, dsa, dsort, planner, query, \
    suffix_array, tablet
from repro.core.planner import ScanOutcome, ScanPlan, ScanPlanner, TopKCache
from repro.core.query import MatchResult, encode_patterns, query as scan, \
    query_sharded as scan_sharded, random_patterns
from repro.core.suffix_array import build_suffix_array, suffix_array_naive
from repro.core.tablet import (TabletStore, build_tablet_store,
                               store_from_arrays)

__all__ = [
    "MatchResult", "ScanOutcome", "ScanPlan", "ScanPlanner", "TabletStore",
    "TopKCache", "build_suffix_array", "build_tablet_store", "codec",
    "dedup", "dsa", "dsort", "encode_patterns", "planner", "query",
    "random_patterns", "scan", "scan_sharded", "store_from_arrays",
    "suffix_array", "suffix_array_naive", "tablet",
]

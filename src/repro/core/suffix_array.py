"""Suffix-array construction (paper §III, Algorithm 2).

Three implementations, increasing in scale:

* ``suffix_array_naive``  — python ``sorted`` oracle, O(n^2 log n).  Test-only.
* ``build_suffix_array``  — Manber–Myers prefix doubling in pure JAX:
  ceil(log2 n) rounds, each a stable 2-key sort + rank relabel.  This is the
  TPU-native choice (data-parallel sorts; DC3's recursion is SPMD-hostile) —
  DESIGN.md §2.
* ``build_suffix_array_sharded`` — the same doubling loop with the sort
  replaced by a distributed bitonic merge over the mesh (see ``dsort.py``),
  so each device holds only n/p rows — the Accumulo-tablet analogue for
  *construction* (paper §IV pre-processing phase).
* ``build_suffix_array_staged`` — the out-of-core pipeline
  (``core/build_pipeline.py``): chunked device sorts, host-RAM/disk spill
  between rounds, streaming merge — for corpora whose working set exceeds
  device (or host) memory.  Bit-identical to ``build_suffix_array``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Oracle
# --------------------------------------------------------------------------
def suffix_array_naive(codes: np.ndarray) -> np.ndarray:
    """Reference: sort suffix start positions lexicographically."""
    codes = np.asarray(codes)
    n = len(codes)
    buf = codes.tobytes() if codes.dtype == np.uint8 else codes.astype(">u4").tobytes()
    item = codes.dtype.itemsize if codes.dtype == np.uint8 else 4
    return np.array(
        sorted(range(n), key=lambda i: buf[i * item:]), dtype=np.int32
    )


# --------------------------------------------------------------------------
# Prefix doubling (single device)
# --------------------------------------------------------------------------
def _relabel(rank_sorted_1, rank_sorted_2, sa):
    """Given sort keys in sorted order, assign dense new ranks (ties share)."""
    changed = (rank_sorted_1[1:] != rank_sorted_1[:-1]) | (
        rank_sorted_2[1:] != rank_sorted_2[:-1]
    )
    new_rank_sorted = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(changed.astype(jnp.int32))]
    )
    # Scatter back to text order.
    n = sa.shape[0]
    rank = jnp.zeros((n,), jnp.int32).at[sa].set(new_rank_sorted)
    return rank


def _doubling_step(carry, _, *, n):
    rank, k, _ = carry
    idx = jnp.arange(n, dtype=jnp.int32)
    # rank of the suffix k positions later; -1 (less than everything) past end.
    nxt = jnp.where(idx + k < n, jnp.take(rank, (idx + k) % n), -1).astype(jnp.int32)
    # Stable lexicographic sort by (rank, nxt); carry positions along.
    rank_s, nxt_s, sa = jax.lax.sort((rank, nxt, idx), dimension=0, num_keys=2)
    rank = _relabel(rank_s, nxt_s, sa)
    return (rank, k * 2, sa), None


@functools.partial(jax.jit, static_argnames=("num_steps",))
def _build_jit(codes: jnp.ndarray, num_steps: int):
    n = codes.shape[0]
    # Initial ranks = codes (already ordinal; generic token dtypes welcome).
    rank = codes.astype(jnp.int32)
    # Densify initial ranks so they are < n (needed only for clean relabel).
    idx = jnp.arange(n, dtype=jnp.int32)
    r_s, i_s = jax.lax.sort((rank, idx), dimension=0, num_keys=1)
    rank = _relabel(r_s, r_s, i_s)
    (rank, _, sa), _ = jax.lax.scan(
        functools.partial(_doubling_step, n=n),
        (rank, jnp.int32(1), idx),
        None, length=num_steps,
    )
    return sa, rank


def build_suffix_array(codes) -> jnp.ndarray:
    """Suffix array of ``codes`` (any integer dtype), int32 positions."""
    codes = jnp.asarray(codes)
    n = int(codes.shape[0])
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    if n == 1:
        return jnp.zeros((1,), jnp.int32)
    num_steps = max(1, int(np.ceil(np.log2(n))))
    sa, _ = _build_jit(codes, num_steps)
    return sa


def build_suffix_array_staged(codes, **kw) -> np.ndarray:
    """Out-of-core build (see ``repro.core.build_pipeline``), returning the
    assembled SA.  Accepts ``chunk_rows`` / ``max_device_bytes`` /
    ``spill_dir`` / ``mesh`` etc.; bit-identical to ``build_suffix_array``."""
    from repro.core.build_pipeline import staged_suffix_array
    sa, _ = staged_suffix_array(codes, **kw)
    return sa


def rank_array(sa: jnp.ndarray) -> jnp.ndarray:
    """Inverse permutation: rank[pos] = index of suffix pos in the SA."""
    n = sa.shape[0]
    return jnp.zeros((n,), jnp.int32).at[sa].set(jnp.arange(n, dtype=jnp.int32))


# --------------------------------------------------------------------------
# LCP of adjacent SA rows (blocked compare, depth-capped) — used by dedup.
# --------------------------------------------------------------------------
def adjacent_lcp(codes: jnp.ndarray, sa: jnp.ndarray, max_lcp: int) -> jnp.ndarray:
    """lcp[i] = longest common prefix (capped at max_lcp) of suffixes
    sa[i] and sa[i+1]; shape (n-1,).  O(n * max_lcp) vectorized compare —
    Kasai's O(n) is inherently sequential, this is the SPMD formulation."""
    n = codes.shape[0]
    a, b = sa[:-1], sa[1:]
    offs = jnp.arange(max_lcp, dtype=jnp.int32)
    ia = a[:, None] + offs[None, :]
    ib = b[:, None] + offs[None, :]
    va = jnp.where(ia < n, jnp.take(codes, jnp.clip(ia, 0, n - 1)), -1)
    vb = jnp.where(ib < n, jnp.take(codes, jnp.clip(ib, 0, n - 1)), -2)
    eq = va == vb
    # Length of the leading run of True.
    return jnp.sum(jnp.cumprod(eq.astype(jnp.int32), axis=1), axis=1)

"""2-bit DNA codec — the paper's §II storage layout (3.2 Gbp ~= 800 MB).

The paper assigns T,G,C,A -> 00,01,10,11.  We instead use the *alphabetical*
assignment A,C,G,T -> 0,1,2,3 so that integer order == lexicographic order;
this is required for the sorted-tablet property (DESIGN.md §8) and costs
nothing.  Packing is big-endian within each 32-bit word (first base in the
most-significant bits) so that an unsigned word compare is a lexicographic
compare of 16 bases at once — this is what the Pallas pattern_scan kernel
exploits.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Alphabet ------------------------------------------------------------------
DNA_ALPHABET = "ACGT"
BASES_PER_WORD = 16  # 2 bits/base, 32-bit words
_ASCII_TO_CODE = np.full(256, 255, dtype=np.uint8)
for _i, _c in enumerate(DNA_ALPHABET):
    _ASCII_TO_CODE[ord(_c)] = _i
    _ASCII_TO_CODE[ord(_c.lower())] = _i


def encode_dna(text: str | bytes | np.ndarray) -> np.ndarray:
    """ASCII DNA -> uint8 codes in {0,1,2,3}.  Raises on non-ACGT symbols."""
    if isinstance(text, str):
        text = text.encode("ascii")
    if isinstance(text, (bytes, bytearray)):
        text = np.frombuffer(bytes(text), dtype=np.uint8)
    codes = _ASCII_TO_CODE[text]
    if np.any(codes == 255):
        bad = chr(int(text[np.argmax(codes == 255)]))
        raise ValueError(f"non-DNA symbol {bad!r} in input")
    return codes


def decode_dna(codes: np.ndarray) -> str:
    return "".join(DNA_ALPHABET[int(c)] for c in np.asarray(codes))


def random_dna(n: int, seed: int = 0) -> np.ndarray:
    """Synthetic chromosome stand-in (uniform ACGT), uint8 codes."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 4, size=n, dtype=np.uint8)


# Packing -------------------------------------------------------------------
def packed_length(n_bases: int) -> int:
    return (n_bases + BASES_PER_WORD - 1) // BASES_PER_WORD


def pack_2bit(codes) -> jnp.ndarray:
    """uint8 codes {0..3} -> uint32 words, big-endian: base i of word w sits at
    bit 30-2*i.  Trailing slots are zero-padded (== 'A'; harmless because all
    compares are depth-capped by the caller)."""
    codes = jnp.asarray(codes, dtype=jnp.uint32)
    n = codes.shape[0]
    n_words = packed_length(n)
    pad = n_words * BASES_PER_WORD - n
    codes = jnp.pad(codes, (0, pad))
    lanes = codes.reshape(n_words, BASES_PER_WORD)
    shifts = jnp.arange(BASES_PER_WORD, dtype=jnp.uint32)
    shifts = (30 - 2 * shifts).astype(jnp.uint32)
    return jnp.bitwise_or.reduce(lanes << shifts[None, :], axis=1)


def pack_2bit_batch(codes: np.ndarray) -> np.ndarray:
    """Batched host-side pack: (B, L) uint8/int codes {0..3} -> (B, W)
    uint32 words, same bit layout as :func:`pack_2bit`.  Pure numpy —
    encoding a query batch must not pay one jnp dispatch per pattern."""
    codes = np.asarray(codes)
    B, L = codes.shape
    n_words = packed_length(L)
    pad = n_words * BASES_PER_WORD - L
    if pad:
        codes = np.pad(codes, ((0, 0), (0, pad)))
    lanes = codes.astype(np.uint32).reshape(B, n_words, BASES_PER_WORD)
    shifts = (30 - 2 * np.arange(BASES_PER_WORD)).astype(np.uint32)
    return np.bitwise_or.reduce(
        (lanes << shifts[None, None, :]).astype(np.uint32), axis=2)


def unpack_2bit_batch(words: np.ndarray, n_bases: int) -> np.ndarray:
    """Batched host-side unpack: (B, W) uint32 words -> (B, n_bases) uint8
    codes — the exact inverse of :func:`pack_2bit_batch` (same big-endian
    layout).  Pure numpy: the FM-index Occ builder unpacks every BWT block
    once at freeze time and must not pay a jnp dispatch per block."""
    words = np.asarray(words, dtype=np.uint32)
    B, W = words.shape
    if n_bases > W * BASES_PER_WORD:
        raise ValueError(f"n_bases={n_bases} exceeds the {W} words' "
                         f"{W * BASES_PER_WORD} slots")
    shifts = (30 - 2 * np.arange(BASES_PER_WORD)).astype(np.uint32)
    lanes = (words[:, :, None] >> shifts[None, None, :]) & np.uint32(3)
    return lanes.reshape(B, W * BASES_PER_WORD)[:, :n_bases].astype(np.uint8)


def unpack_2bit(words: jnp.ndarray, n_bases: int) -> jnp.ndarray:
    """Inverse of pack_2bit."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = (30 - 2 * jnp.arange(BASES_PER_WORD, dtype=jnp.uint32)).astype(jnp.uint32)
    lanes = (words[:, None] >> shifts[None, :]) & jnp.uint32(3)
    return lanes.reshape(-1)[:n_bases].astype(jnp.uint8)


def extract_window(packed: jnp.ndarray, pos: jnp.ndarray, n_words: int) -> jnp.ndarray:
    """Extract ``n_words`` packed words of the suffix starting at base ``pos``
    (arbitrary, not word-aligned).  Vectorized over a batch of positions.

    Returns (batch, n_words) uint32.  Bases past the end of the text read as 0
    ('A'); callers must depth-cap compares at text_len - pos themselves when
    exactness at the boundary matters (query.py does).
    """
    pos = jnp.asarray(pos)
    batch_shape = pos.shape
    pos = pos.reshape(-1)
    word_idx = (pos // BASES_PER_WORD).astype(jnp.int32)
    bit_off = (2 * (pos % BASES_PER_WORD)).astype(jnp.uint32)
    # Gather n_words+1 consecutive words, then funnel-shift pairs.
    offs = jnp.arange(n_words + 1, dtype=jnp.int32)
    idx = word_idx[:, None] + offs[None, :]
    idx = jnp.clip(idx, 0, packed.shape[0] - 1)
    in_range = (word_idx[:, None] + offs[None, :]) < packed.shape[0]
    w = jnp.where(in_range, packed[idx], jnp.uint32(0))
    hi = w[:, :-1]
    lo = w[:, 1:]
    sh = bit_off[:, None]
    # When sh == 0 the `lo >> 32` path is UB; guard it.
    out = jnp.where(
        sh == 0,
        hi,
        (hi << sh) | (lo >> (jnp.uint32(32) - sh)),
    )
    return out.reshape(*batch_shape, n_words)

"""TabletStore — the Accumulo table of paper §IV, adapted to a TPU mesh.

Paper layout: one row per suffix (ROWID = start position, TEXT = suffix
chars, truncated to 1000).  Our layout (DESIGN.md §2): the text is stored
ONCE (2-bit packed for DNA, raw int32 codes for token corpora) and the
"table" is the globally sorted suffix array, range-partitioned into
contiguous tablets of m = n_pad / p rows, one per device.  Split keys
(Accumulo's METADATA table) are implicit: tablet d owns sorted rows
[d*m, (d+1)*m).

``max_query_len`` is the paper's 1000-char truncation, reborn as a compare
depth cap (queries in the paper's workload are <= 100 chars).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.suffix_array import build_suffix_array
from repro.core.dsa import build_suffix_array_distributed


@partial(jax.tree_util.register_dataclass,
         data_fields=("text_packed", "text_codes", "sa"),
         meta_fields=("n_real", "n_pad", "is_dna", "max_query_len"))
@dataclasses.dataclass(frozen=True)
class TabletStore:
    """One suffix-array "table".  ``sa`` is the padded, globally sorted
    suffix array; pad rows (positions >= n_real) sort first and are inert
    for every query whose codes are >= 0."""
    text_packed: Optional[jnp.ndarray]  # (n_words,) uint32 | None
    text_codes: Optional[jnp.ndarray]   # (n_pad,)  int32  | None
    sa: jnp.ndarray                     # (n_pad,)  int32
    n_real: int
    n_pad: int
    is_dna: bool
    max_query_len: int

    @property
    def pad_count(self) -> int:
        return self.n_pad - self.n_real

    def tablet_rows(self, num_tablets: int) -> int:
        assert self.n_pad % num_tablets == 0
        return self.n_pad // num_tablets


def _finalize_store(codes: np.ndarray, sa, n_pad: int, *, is_dna: bool,
                    max_query_len: int) -> TabletStore:
    n_real = int(codes.shape[0])
    text_packed = codec.pack_2bit(codes) if is_dna else None
    # generic code array padded with -1 so out-of-range gathers sort low
    text_codes = jnp.asarray(
        np.pad(codes.astype(np.int32), (0, n_pad - n_real),
               constant_values=-1))
    return TabletStore(text_packed=text_packed, text_codes=text_codes,
                       sa=jnp.asarray(sa, jnp.int32), n_real=n_real,
                       n_pad=n_pad, is_dna=bool(is_dna),
                       max_query_len=max_query_len)


def store_from_arrays(codes, sa_real, *, is_dna: bool,
                      max_query_len: int = 128, num_tablets: int = 1,
                      min_rows: int = 0) -> TabletStore:
    """Assemble a store from the text and its (already built) real-row
    suffix array — the restore path of ``repro.api.SuffixTable``: a table
    persisted on one device count is re-padded here for any other.

    Pad rows (positions n_real..n_pad-1) sort before all real rows and
    are inert for queries; their canonical order matches the distributed
    builder's: the pad suffix at position q is a run of (n_pad - q)
    minimal symbols and shorter runs are prefixes, so they sort ascending
    by run length, i.e. positions n_pad-1, n_pad-2, ..., n_real.

    ``min_rows`` raises n_pad beyond the num_tablets multiple.  (The
    memtable/run stores no longer use it — ``n_real`` is a static jit
    field, so they bucket the TEXT itself instead; see
    ``repro.api.runs.padded_segment_store``.)
    """
    codes = np.asarray(codes)
    sa_real = np.asarray(sa_real, np.int32)
    n_real = int(codes.shape[0])
    if sa_real.shape[0] != n_real:
        raise ValueError(f"sa_real has {sa_real.shape[0]} rows for "
                         f"{n_real} text symbols")
    p = num_tablets
    m = int(np.ceil(max(n_real, min_rows, 1) / p))
    n_pad = m * p
    pads = np.arange(n_pad - 1, n_real - 1, -1, dtype=np.int32)
    sa = jnp.asarray(np.concatenate([pads, sa_real]))
    return _finalize_store(codes, sa, n_pad, is_dna=bool(is_dna),
                           max_query_len=max_query_len)


def build_tablet_store(codes, *, is_dna: bool | None = None,
                       max_query_len: int = 128,
                       num_tablets: int = 1,
                       min_rows: int = 0,
                       mesh=None, axis_name: str | None = None,
                       method: str = "bitonic") -> TabletStore:
    """Build the store.  Single-device when mesh is None, otherwise the
    distributed builder (paper's pre-processing phase on the cluster)."""
    codes = np.asarray(codes)
    if is_dna is None:
        is_dna = codes.size > 0 and codes.max() < 4

    if mesh is None:
        sa_real = build_suffix_array(codes.astype(np.int32))
        return store_from_arrays(codes, np.asarray(sa_real),
                                 is_dna=bool(is_dna),
                                 max_query_len=max_query_len,
                                 num_tablets=num_tablets,
                                 min_rows=min_rows)
    assert axis_name is not None
    sa, _pad = build_suffix_array_distributed(codes, mesh, axis_name,
                                              method=method)
    return _finalize_store(codes, sa, int(sa.shape[0]),
                           is_dna=bool(is_dna), max_query_len=max_query_len)

"""TabletStore — the Accumulo table of paper §IV, adapted to a TPU mesh.

Paper layout: one row per suffix (ROWID = start position, TEXT = suffix
chars, truncated to 1000).  Our layout (DESIGN.md §2): the text is stored
ONCE (2-bit packed for DNA, raw int32 codes for token corpora) and the
"table" is the globally sorted suffix array, range-partitioned into
contiguous tablets of m = n_pad / p rows, one per device.  Split keys
(Accumulo's METADATA table) are implicit: tablet d owns sorted rows
[d*m, (d+1)*m).

``max_query_len`` is the paper's 1000-char truncation, reborn as a compare
depth cap (queries in the paper's workload are <= 100 chars).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.suffix_array import build_suffix_array
from repro.core.dsa import build_suffix_array_distributed


@partial(jax.tree_util.register_dataclass,
         data_fields=("text_packed", "text_codes", "sa"),
         meta_fields=("n_real", "n_pad", "is_dna", "max_query_len"))
@dataclasses.dataclass(frozen=True)
class TabletStore:
    """One suffix-array "table".  ``sa`` is the padded, globally sorted
    suffix array; pad rows (positions >= n_real) sort first and are inert
    for every query whose codes are >= 0."""
    text_packed: Optional[jnp.ndarray]  # (n_words,) uint32 | None
    text_codes: Optional[jnp.ndarray]   # (n_pad,)  int32  | None
    sa: jnp.ndarray                     # (n_pad,)  int32
    n_real: int
    n_pad: int
    is_dna: bool
    max_query_len: int

    @property
    def pad_count(self) -> int:
        return self.n_pad - self.n_real

    def tablet_rows(self, num_tablets: int) -> int:
        assert self.n_pad % num_tablets == 0
        return self.n_pad // num_tablets


@partial(jax.tree_util.register_dataclass,
         data_fields=("text_packed", "text_codes", "sa", "n_real", "n_rows",
                      "offset", "lo", "hi", "ov_rank", "hi_rank", "pad_cnt",
                      "rmq"),
         meta_fields=("num_tiers", "rows", "is_dna", "max_query_len"))
@dataclasses.dataclass(frozen=True)
class TierStack:
    """All delta tiers of a table (sealed runs + memtable) stacked into
    one rectangular device view, so a merged read crosses the
    host->device boundary ONCE instead of once per tier.

    Row axis is padded to ``rows`` = max tier n_pad (pow2-bucketed per
    tier already, so restacking happens only when a tier outgrows its
    bucket or the tier COUNT changes — both shape changes).  Everything
    per-tier (``n_real``/``n_rows``/``offset``/``lo``/``hi``) is traced
    int32 DATA of shape (T,): memtable appends within a bucket mutate
    values, not shapes, and reuse the compiled fused scan.

    Semantics per tier t (the straddle rule, docs/table_api.md): local
    row position p maps to global position ``g = p + offset[t]``; the
    tier owns a match iff ``lo[t] < g + plen <= hi[t]``.  A prefix-match
    window [lb, ub) can contain DISOWNED rows of three disjoint kinds —
    overlap-prefix rows (``p + plen <= ov`` where ``ov = lo - offset``),
    end rows (``tl - plen < p < tl`` where ``tl = hi - offset`` is the
    true text length), and bucket-pad rows (``p >= tl``: the pow2 text
    padding of ``padded_segment_store`` is REAL to the store, so its
    symbol-0 suffixes can prefix-match).  The first two sets hold at
    most ``max_query_len - 1`` positions each; the pad set is unbounded
    but static.  Four precomputed host-side structures let the fused
    scan apply the full two-sided rule in O(max_query_len + log rows)
    per query instead of a dense O(rows) mask:

    * ``ov_rank[t, p]`` — SA rank of overlap position ``p`` (BIG when
      ``p >= ov``): the only rows the LOW bound can disown;
    * ``hi_rank[t, q]`` — SA rank of end position ``tl - 1 - q`` (BIG
      when out of range): the only REAL rows the HIGH bound can disown
      (``q <= plen - 2``);
    * ``pad_cnt[t, r]`` — # of rows among SA[0:r) with position
      ``>= tl``, so the pad rows in any window cost two gathers;
    * ``rmq[t, k, i]`` — sparse-table range-minimum over
      ``g = sa + offset`` restricted to rows with ``ov <= p < tl``, so
      the minimum owned position in an SA window costs two gathers
      (guarded by ``min_p <= tl - plen``; if the minimum itself fails
      the high bound, every row in the range does)."""
    text_packed: Optional[jnp.ndarray]  # (T, W_max)  uint32 | None
    text_codes: Optional[jnp.ndarray]   # (T, rows)   int32  | None
    sa: jnp.ndarray                     # (T, rows)   int32, pad rows 0
    n_real: jnp.ndarray                 # (T,) int32  compare depth cap
    n_rows: jnp.ndarray                 # (T,) int32  real sorted rows
    offset: jnp.ndarray                 # (T,) int32  local -> global
    lo: jnp.ndarray                     # (T,) int32  owned range, open
    hi: jnp.ndarray                     # (T,) int32  owned range, closed
    ov_rank: jnp.ndarray                # (T, OV) int32 overlap SA ranks
    hi_rank: jnp.ndarray                # (T, OV) int32 end-pos SA ranks
    pad_cnt: jnp.ndarray                # (T, rows+1) int32 pad-row prefix
    rmq: jnp.ndarray                    # (T, K, rows) int32 range-min g
    num_tiers: int
    rows: int
    is_dna: bool
    max_query_len: int


def stack_tier_stores(stores, *, offsets, bounds) -> TierStack:
    """Stack per-tier segment stores (``padded_segment_store`` outputs)
    into one :class:`TierStack`.  ``offsets[t]`` is the tier's
    local->global position shift; ``bounds[t] = (lo, hi)`` its owned
    global range.  Pad words/codes read as 0/-1 — bit-identical to what
    ``codec.extract_window``/``compare_codes`` return past each tier's
    own array, so stacking never changes a comparison."""
    assert stores, "need at least one tier"
    T = len(stores)
    rows = max(s.n_pad for s in stores)
    is_dna = stores[0].is_dna
    assert all(s.is_dna == is_dna for s in stores)
    sa = np.zeros((T, rows), np.int32)
    packed = None
    codes = None
    if is_dna:
        w_max = codec.packed_length(rows)
        packed = np.zeros((T, w_max), np.uint32)
    codes = np.full((T, rows), -1, np.int32)
    for t, s in enumerate(stores):
        sa[t, :s.n_pad] = np.asarray(s.sa)
        codes[t, :s.n_pad] = np.asarray(s.text_codes)
        if is_dna:
            pk = np.asarray(s.text_packed)
            packed[t, :pk.shape[0]] = pk
    meta = np.zeros((5, T), np.int32)
    meta[0] = [s.n_real for s in stores]
    meta[1] = [s.n_pad for s in stores]
    meta[2] = np.asarray(offsets, np.int32)
    meta[3] = [b[0] for b in bounds]
    meta[4] = [b[1] for b in bounds]
    for t, s in enumerate(stores):
        tl = int(meta[4][t]) - int(meta[2][t])    # true text length
        if not (0 <= int(meta[3][t]) - int(meta[2][t]) < tl <= s.n_real):
            raise ValueError(
                f"tier {t}: bounds ({int(meta[3][t])}, {int(meta[4][t])}) "
                f"inconsistent with offset={int(meta[2][t])}, "
                f"n_real={s.n_real}")
    overlaps = meta[3] - meta[2]                  # lo - offset, per tier
    mq1 = max(s.max_query_len for s in stores) - 1
    edge = max(int(overlaps.max()), mq1, 1)
    OV = 1 << (edge - 1).bit_length()
    K = rows.bit_length()                         # rows is a power of 2
    BIG = np.int32(2**30)
    ov_rank = np.full((T, OV), BIG, np.int32)
    hi_rank = np.full((T, OV), BIG, np.int32)
    pad_cnt = np.zeros((T, rows + 1), np.int32)
    rmq = np.full((T, K, rows), BIG, np.int32)
    for t, s in enumerate(stores):
        sa_t = sa[t, :s.n_pad]
        ov_t = int(overlaps[t])
        tl = int(meta[4][t]) - int(meta[2][t])
        in_ov = np.flatnonzero(sa_t < ov_t)
        ov_rank[t, sa_t[in_ov]] = in_ov
        at_end = np.flatnonzero((sa_t >= max(tl - OV, 0)) & (sa_t < tl))
        hi_rank[t, tl - 1 - sa_t[at_end]] = at_end
        pad_cnt[t, 1:s.n_pad + 1] = np.cumsum(sa_t >= tl)
        pad_cnt[t, s.n_pad + 1:] = pad_cnt[t, s.n_pad]
        rmq[t, 0, :s.n_pad] = np.where(
            (sa_t >= ov_t) & (sa_t < tl),
            sa_t + int(meta[2][t]), BIG)
        for k in range(1, K):
            h = 1 << (k - 1)
            rmq[t, k, :rows - h] = np.minimum(rmq[t, k - 1, :rows - h],
                                              rmq[t, k - 1, h:])
            rmq[t, k, rows - h:] = rmq[t, k - 1, rows - h:]
    return TierStack(
        text_packed=jnp.asarray(packed) if is_dna else None,
        text_codes=jnp.asarray(codes),
        sa=jnp.asarray(sa),
        n_real=jnp.asarray(meta[0]), n_rows=jnp.asarray(meta[1]),
        offset=jnp.asarray(meta[2]), lo=jnp.asarray(meta[3]),
        hi=jnp.asarray(meta[4]),
        ov_rank=jnp.asarray(ov_rank), hi_rank=jnp.asarray(hi_rank),
        pad_cnt=jnp.asarray(pad_cnt), rmq=jnp.asarray(rmq),
        num_tiers=T, rows=rows, is_dna=is_dna,
        max_query_len=min(s.max_query_len for s in stores))


def _finalize_store(codes: np.ndarray, sa, n_pad: int, *, is_dna: bool,
                    max_query_len: int) -> TabletStore:
    n_real = int(codes.shape[0])
    text_packed = codec.pack_2bit(codes) if is_dna else None
    # generic code array padded with -1 so out-of-range gathers sort low
    text_codes = jnp.asarray(
        np.pad(codes.astype(np.int32), (0, n_pad - n_real),
               constant_values=-1))
    return TabletStore(text_packed=text_packed, text_codes=text_codes,
                       sa=jnp.asarray(sa, jnp.int32), n_real=n_real,
                       n_pad=n_pad, is_dna=bool(is_dna),
                       max_query_len=max_query_len)


def store_from_arrays(codes, sa_real, *, is_dna: bool,
                      max_query_len: int = 128, num_tablets: int = 1,
                      min_rows: int = 0) -> TabletStore:
    """Assemble a store from the text and its (already built) real-row
    suffix array — the restore path of ``repro.api.SuffixTable``: a table
    persisted on one device count is re-padded here for any other.

    Pad rows (positions n_real..n_pad-1) sort before all real rows and
    are inert for queries; their canonical order matches the distributed
    builder's: the pad suffix at position q is a run of (n_pad - q)
    minimal symbols and shorter runs are prefixes, so they sort ascending
    by run length, i.e. positions n_pad-1, n_pad-2, ..., n_real.

    ``min_rows`` raises n_pad beyond the num_tablets multiple.  (The
    memtable/run stores no longer use it — ``n_real`` is a static jit
    field, so they bucket the TEXT itself instead; see
    ``repro.api.runs.padded_segment_store``.)
    """
    codes = np.asarray(codes)
    sa_real = np.asarray(sa_real, np.int32)
    n_real = int(codes.shape[0])
    if sa_real.shape[0] != n_real:
        raise ValueError(f"sa_real has {sa_real.shape[0]} rows for "
                         f"{n_real} text symbols")
    p = num_tablets
    m = int(np.ceil(max(n_real, min_rows, 1) / p))
    n_pad = m * p
    pads = np.arange(n_pad - 1, n_real - 1, -1, dtype=np.int32)
    sa = jnp.asarray(np.concatenate([pads, sa_real]))
    return _finalize_store(codes, sa, n_pad, is_dna=bool(is_dna),
                           max_query_len=max_query_len)


def build_tablet_store(codes, *, is_dna: bool | None = None,
                       max_query_len: int = 128,
                       num_tablets: int = 1,
                       min_rows: int = 0,
                       mesh=None, axis_name: str | None = None,
                       method: str = "bitonic") -> TabletStore:
    """Build the store.  Single-device when mesh is None, otherwise the
    distributed builder (paper's pre-processing phase on the cluster)."""
    codes = np.asarray(codes)
    if is_dna is None:
        is_dna = codes.size > 0 and codes.max() < 4

    if mesh is None:
        sa_real = build_suffix_array(codes.astype(np.int32))
        return store_from_arrays(codes, np.asarray(sa_real),
                                 is_dna=bool(is_dna),
                                 max_query_len=max_query_len,
                                 num_tablets=num_tablets,
                                 min_rows=min_rows)
    assert axis_name is not None
    sa, _pad = build_suffix_array_distributed(codes, mesh, axis_name,
                                              method=method)
    return _finalize_store(codes, sa, int(sa.shape[0]),
                           is_dna=bool(is_dna), max_query_len=max_query_len)

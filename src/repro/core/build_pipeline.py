"""Staged out-of-core suffix-array construction (ROADMAP: genome-scale
builds; paper §IV pre-processing at Bigtable scale).

``build_suffix_array`` (core/suffix_array.py) holds the text plus three
working arrays on one device — fine for bench corpora, hopeless for the
multi-GB genomes the paper's precision-medicine pitch implies.  This
module re-runs the exact same Manber–Myers recurrence as an external
algorithm in the MapReduce-SA style (Wu et al., arXiv 1705.04789;
Bingmann et al., arXiv 1610.03007):

  1. **Chunk sort** — each round's rows ``(key, nxt, idx)`` are sorted
     ``chunk_rows`` at a time on device (one jitted ``lax.sort`` per
     chunk, or one ``dsort`` mesh sort per super-chunk of
     ``p * chunk_rows`` rows when a mesh is given), so device residency
     is bounded by ``chunk_rows * BYTES_PER_ROW`` per device regardless
     of corpus size.
  2. **Spill** — sorted runs and the text-order rank array live in a
     :class:`SpillStore`: host RAM by default, ``.npy``/raw files under
     ``spill_dir`` when set, so host residency is bounded too.
  3. **Merge + relabel** — ``dsort.merge_sorted_runs`` streams the
     globally sorted order; dense new ranks are assigned on the fly
     (a key change bumps the rank, first row is rank 0 — exactly
     ``suffix_array._relabel``) and scattered back to text order through
     a :class:`ChunkScatter` shuffle.
  4. **Emit** — when ranks saturate (all distinct) the merged order IS
     the suffix array; it is streamed out in ``shard_rows`` blocks via
     ``emit_shard`` so the full SA never has to exist on one host.

Bit-identity with the in-memory builder (asserted by
tests/test_build_pipeline.py): sorts here use ``idx`` as an explicit
last key, which equals ``lax.sort``'s stable tie-break over text-ordered
rows; the relabel recurrence is identical; and the SA is a permutation
of distinct suffixes, so the early exit on saturation cannot change it.

Memory budget math (docs/build_pipeline.md): a row moving through a sort
is three int32 operands double-buffered = 24 B, so
``chunk_rows = max_device_bytes // 24``.  The merge holds one
``block_rows`` block per run, sized so the cache stays ~one chunk.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Callable, Optional

import jax
import numpy as np
from jax import lax

from repro.core.dsort import merge_sorted_runs
from repro.distributed.sharding import mesh_axis_size

DEFAULT_CHUNK_ROWS = 1 << 16
MIN_CHUNK_ROWS = 256
# 3 int32 sort operands, double-buffered through the device sort.
BYTES_PER_ROW = 24
_I32_MAX = np.int32(np.iinfo(np.int32).max)


def chunk_rows_for_budget(max_device_bytes: Optional[int]) -> int:
    """Rows per device chunk under a byte budget (None -> default)."""
    if max_device_bytes is None:
        return DEFAULT_CHUNK_ROWS
    return max(MIN_CHUNK_ROWS, int(max_device_bytes) // BYTES_PER_ROW)


@dataclasses.dataclass
class BuildStats:
    """Construction telemetry — surfaced as ``SuffixTable.stats()["build"]``."""

    mode: str = "staged"            # "staged" | "in_memory"
    n_bases: int = 0
    rounds: int = 0                 # sort/merge rounds actually run
    n_chunks: int = 0               # device chunks per round
    chunk_rows: int = 0
    peak_device_bytes: int = 0      # per-device sort working set
    spill_bytes: int = 0            # cumulative bytes written to spill_dir
    elapsed_s: float = 0.0
    bases_per_s: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "BuildStats":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


def in_memory_build_stats(n: int, elapsed_s: float) -> BuildStats:
    """The same schema for the legacy single-sort builder."""
    rounds = 1 + max(1, int(np.ceil(np.log2(max(2, n)))))
    return BuildStats(
        mode="in_memory", n_bases=n, rounds=rounds, n_chunks=1,
        chunk_rows=n, peak_device_bytes=n * BYTES_PER_ROW, spill_bytes=0,
        elapsed_s=elapsed_s,
        bases_per_s=(n / elapsed_s) if elapsed_s > 0 else 0.0)


# --------------------------------------------------------------------------
# Spill store: chunked working arrays + sorted runs, RAM or disk.
# --------------------------------------------------------------------------
class SpillStore:
    """Between-round working state, addressed as ``(name, chunk_index)``.

    RAM mode (``spill_dir=None``) keeps plain numpy arrays in a dict.
    Disk mode writes ``.npy`` per chunk and raw ``tofile`` pairs per
    sorted run; reads come back through ``np.load`` / ``np.fromfile``
    block reads (never mmap — mmap counts against RLIMIT_AS, which the
    out-of-core bench caps)."""

    def __init__(self, spill_dir: Optional[str] = None):
        self.spill_dir = spill_dir
        self._ram: dict = {}
        self.spill_bytes = 0
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)

    def _path(self, name: str, i: int, ext: str = "npy") -> str:
        return os.path.join(self.spill_dir, f"{name}_{i:06d}.{ext}")

    def put(self, name: str, i: int, arr: np.ndarray) -> None:
        if self.spill_dir is None:
            self._ram[(name, i)] = arr
            return
        np.save(self._path(name, i), arr)
        self.spill_bytes += arr.nbytes

    def get(self, name: str, i: int) -> np.ndarray:
        if self.spill_dir is None:
            return self._ram[(name, i)]
        return np.load(self._path(name, i))

    def put_run(self, r: int, key: np.ndarray,
                idx: np.ndarray) -> "SortedRun":
        if self.spill_dir is None:
            return SortedRun(len(key), key=key, idx=idx)
        kp = self._path("run", r, "key")
        ip = self._path("run", r, "idx")
        key.tofile(kp)
        idx.tofile(ip)
        self.spill_bytes += key.nbytes + idx.nbytes
        return SortedRun(len(key), key_path=kp, idx_path=ip)

    def drop_runs(self, runs) -> None:
        for run in runs:
            run.drop()

    def append_raw(self, path: str, arr: np.ndarray) -> None:
        with open(os.path.join(self.spill_dir, path), "ab") as f:
            arr.tofile(f)
        self.spill_bytes += arr.nbytes

    def read_raw(self, path: str, dtype) -> np.ndarray:
        full = os.path.join(self.spill_dir, path)
        if not os.path.exists(full):
            return np.zeros((0,), dtype)
        return np.fromfile(full, dtype=dtype)

    def drop_raw(self, path: str) -> None:
        full = os.path.join(self.spill_dir, path)
        if os.path.exists(full):
            os.remove(full)

    def close(self) -> None:
        """Delete every spill artifact (working state is round-local)."""
        self._ram.clear()
        if self.spill_dir is not None and os.path.isdir(self.spill_dir):
            for fn in os.listdir(self.spill_dir):
                if fn.split("_")[0] in ("run", "rank", "sa", "scat"):
                    try:
                        os.remove(os.path.join(self.spill_dir, fn))
                    except OSError:
                        pass


class SortedRun:
    """One sorted ``(key int64, idx int32)`` run, RAM- or file-backed.
    ``read_block(lo, hi)`` is the contract ``merge_sorted_runs`` needs."""

    def __init__(self, n: int, key=None, idx=None,
                 key_path: Optional[str] = None,
                 idx_path: Optional[str] = None):
        self.n = int(n)
        self._key, self._idx = key, idx
        self._key_path, self._idx_path = key_path, idx_path

    def read_block(self, lo: int, hi: int):
        if self._key is not None:
            return self._key[lo:hi], self._idx[lo:hi]
        k = np.fromfile(self._key_path, dtype=np.int64, count=hi - lo,
                        offset=lo * 8)
        i = np.fromfile(self._idx_path, dtype=np.int32, count=hi - lo,
                        offset=lo * 4)
        return k, i

    def drop(self) -> None:
        self._key = self._idx = None
        for p in (self._key_path, self._idx_path):
            if p is not None and os.path.exists(p):
                os.remove(p)


# --------------------------------------------------------------------------
# Scatter-back shuffle: merged (idx, rank) rows -> text-order rank chunks.
# --------------------------------------------------------------------------
class ChunkScatter:
    """MapReduce-style shuffle for the relabel writeback.

    Merged blocks arrive in SA order; rows are bucketed by destination
    chunk ``idx // chunk_rows`` and buffered, spilling each bucket to
    append-only files once it exceeds ``flush_rows`` (disk mode), so the
    resident set stays O(n_chunks * flush_rows) instead of O(n).  Every
    text position is written exactly once per round, so ``finish`` can
    assemble each rank chunk with a plain scatter."""

    def __init__(self, store: SpillStore, n_chunks: int, chunk_rows: int,
                 flush_rows: int = 1 << 14):
        self.store = store
        self.n_chunks = n_chunks
        self.chunk_rows = chunk_rows
        self.flush_rows = flush_rows
        self._buf: list[list] = [[] for _ in range(n_chunks)]
        self._pending = [0] * n_chunks
        self._spilled = [False] * n_chunks

    def add(self, idx: np.ndarray, rank: np.ndarray) -> None:
        dest = idx // self.chunk_rows
        order = np.argsort(dest, kind="stable")
        dsort, isort, rsort = dest[order], idx[order], rank[order]
        bounds = np.searchsorted(dsort, np.arange(self.n_chunks + 1))
        for c in np.unique(dsort):
            lo, hi = bounds[c], bounds[c + 1]
            pos = (isort[lo:hi] - c * self.chunk_rows).astype(np.int32)
            self._buf[c].append((pos, rsort[lo:hi].astype(np.int32)))
            self._pending[c] += hi - lo
            if (self.store.spill_dir is not None
                    and self._pending[c] >= self.flush_rows):
                self._flush(c)

    def _flush(self, c: int) -> None:
        pos = np.concatenate([p for p, _ in self._buf[c]])
        rnk = np.concatenate([r for _, r in self._buf[c]])
        self.store.append_raw(f"scat_{c:06d}.pos", pos)
        self.store.append_raw(f"scat_{c:06d}.rank", rnk)
        self._buf[c] = []
        self._pending[c] = 0
        self._spilled[c] = True

    def finish(self, n: int) -> None:
        """Assemble and store the new text-order rank chunks."""
        for c in range(self.n_chunks):
            size = min(self.chunk_rows, n - c * self.chunk_rows)
            out = np.empty((size,), np.int32)
            if self._spilled[c]:
                pos = self.store.read_raw(f"scat_{c:06d}.pos", np.int32)
                rnk = self.store.read_raw(f"scat_{c:06d}.rank", np.int32)
                out[pos] = rnk
            for pos, rnk in self._buf[c]:
                out[pos] = rnk
            self._buf[c] = []
            self.store.put("rank", c, out)
        self.discard()

    def discard(self) -> None:
        self._buf = [[] for _ in range(self.n_chunks)]
        for c in range(self.n_chunks):
            if self._spilled[c]:
                self.store.drop_raw(f"scat_{c:06d}.pos")
                self.store.drop_raw(f"scat_{c:06d}.rank")
                self._spilled[c] = False


class _ChunkedWriter:
    """Sequential writer of a chunked array into the store."""

    def __init__(self, store: SpillStore, name: str, chunk_rows: int):
        self.store, self.name, self.chunk_rows = store, name, chunk_rows
        self._parts: list = []
        self._have = 0
        self.next_chunk = 0

    def add(self, arr: np.ndarray) -> None:
        self._parts.append(arr)
        self._have += len(arr)
        while self._have >= self.chunk_rows:
            cat = np.concatenate(self._parts)
            self.store.put(self.name, self.next_chunk,
                           cat[:self.chunk_rows])
            self.next_chunk += 1
            self._parts = [cat[self.chunk_rows:]]
            self._have = len(self._parts[0])

    def finish(self) -> None:
        if self._have:
            self.store.put(self.name, self.next_chunk,
                           np.concatenate(self._parts))
            self.next_chunk += 1
        self._parts = []
        self._have = 0


# --------------------------------------------------------------------------
# Device chunk sort
# --------------------------------------------------------------------------
@jax.jit
def _sort_triple(first, second, idx):
    """Ascending by (first, second, idx) — idx last makes ties explicit,
    matching lax.sort's stable behaviour over text-ordered rows."""
    return lax.sort((first, second, idx), dimension=0, num_keys=3)


def _read_rank_range(store: SpillStore, lo: int, hi: int, n: int,
                     chunk_rows: int) -> np.ndarray:
    """rank[lo:hi] from the chunked store, -1 for positions >= n."""
    out = np.full((hi - lo,), -1, np.int32)
    pos = lo
    while pos < min(hi, n):
        c = pos // chunk_rows
        chunk = store.get("rank", c)
        base = c * chunk_rows
        take = min(hi, base + len(chunk)) - pos
        out[pos - lo:pos - lo + take] = chunk[pos - base:pos - base + take]
        pos += take
    return out


def _pack_keys(first: np.ndarray, second: np.ndarray, n: int) -> np.ndarray:
    """Order-preserving int64 packing of the (first, second) sort key:
    first in [0, n), second in [-1, n) -> first*(n+1) + second+1.
    Fits int64 for n up to ~3e9."""
    return first.astype(np.int64) * np.int64(n + 1) \
        + (second.astype(np.int64) + 1)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------
def staged_suffix_array(
    codes,
    *,
    chunk_rows: Optional[int] = None,
    max_device_bytes: Optional[int] = None,
    spill_dir: Optional[str] = None,
    mesh=None,
    axis_name: str = "tablets",
    method: str = "sample",
    shard_rows: Optional[int] = None,
    emit_shard: Optional[Callable[[int, np.ndarray], None]] = None,
    num_steps: Optional[int] = None,
):
    """Out-of-core prefix doubling; bit-identical to ``build_suffix_array``.

    Returns ``(sa, stats)``.  With ``emit_shard`` set the SA is streamed
    as ``emit_shard(shard_index, int32_block)`` calls of ``shard_rows``
    rows (last one partial) and ``sa`` is None; otherwise the full array
    is assembled and returned.  ``mesh`` routes each super-chunk sort of
    ``p * chunk_rows`` rows through ``dsort`` so every device still only
    ever holds ``chunk_rows`` rows.
    """
    codes = np.asarray(codes, dtype=np.int32)
    n = int(len(codes))
    t0 = time.perf_counter()
    if chunk_rows is None:
        chunk_rows = chunk_rows_for_budget(max_device_bytes)
    chunk_rows = max(MIN_CHUNK_ROWS, int(chunk_rows))
    if shard_rows is None:
        shard_rows = chunk_rows

    if n <= 1:
        sa = np.arange(n, dtype=np.int32)
        stats = BuildStats(n_bases=n, rounds=0, n_chunks=min(n, 1),
                           chunk_rows=chunk_rows,
                           elapsed_s=time.perf_counter() - t0)
        if emit_shard is not None:
            if n:
                emit_shard(0, sa)
            return None, stats
        return sa, stats

    p = mesh_axis_size(mesh, axis_name) if mesh is not None else 1
    if p > 1:
        from repro.core.dsa import make_superchunk_sorter
        mesh_sorter = make_superchunk_sorter(mesh, axis_name, method)
    sc_rows = chunk_rows * p                      # rows per device sort call
    n_chunks = -(-n // chunk_rows)
    n_super = -(-n // sc_rows)
    if num_steps is None:
        num_steps = max(1, int(np.ceil(np.log2(n))))

    store = SpillStore(spill_dir)
    stats = BuildStats(n_bases=n, n_chunks=n_chunks, chunk_rows=chunk_rows,
                       peak_device_bytes=chunk_rows * BYTES_PER_ROW)
    block_rows = max(MIN_CHUNK_ROWS, sc_rows // max(1, n_super))

    def sort_chunk(first, second, idx):
        cap = sc_rows
        real = len(first)
        if real < cap:
            pad = np.full((cap - real,), _I32_MAX, np.int32)
            first = np.concatenate([first, pad])
            second = np.concatenate([second, pad])
            idx = np.concatenate([idx, pad])
        if p > 1:
            f_s, s_s, i_s = mesh_sorter(first, second, idx)
        else:
            f_s, s_s, i_s = _sort_triple(first, second, idx)
        return (np.asarray(f_s)[:real], np.asarray(s_s)[:real],
                np.asarray(i_s)[:real])

    try:
        k = 0                                      # round 0 = densify
        zeros = np.zeros((sc_rows,), np.int32)
        for rnd in range(num_steps + 1):
            runs = []
            for s in range(n_super):
                lo, hi = s * sc_rows, min((s + 1) * sc_rows, n)
                if rnd == 0:
                    first = codes[lo:hi]
                    second = zeros[:hi - lo]
                else:
                    first = _read_rank_range(store, lo, hi, n, chunk_rows)
                    second = _read_rank_range(store, lo + k, hi + k, n,
                                              chunk_rows)
                idx = np.arange(lo, hi, dtype=np.int32)
                f_s, s_s, i_s = sort_chunk(first, second, idx)
                runs.append(store.put_run(s, _pack_keys(f_s, s_s, n), i_s))

            # flush threshold scales with the chunk so pending scatter
            # buffers stay a fraction of the device budget, not O(n)
            scat = ChunkScatter(store, n_chunks, chunk_rows,
                                flush_rows=max(1024, chunk_rows // 8))
            sa_out = _ChunkedWriter(store, "sa", chunk_rows)
            last_rank = np.int64(0)
            prev_key = None
            for key_blk, idx_blk in merge_sorted_runs(
                    runs, block_rows=block_rows):
                ch = np.empty((len(key_blk),), np.int64)
                ch[1:] = key_blk[1:] != key_blk[:-1]
                ch[0] = 0 if prev_key is None else key_blk[0] != prev_key
                ranks = last_rank + np.cumsum(ch)
                last_rank = ranks[-1]
                prev_key = key_blk[-1]
                sa_out.add(idx_blk)
                scat.add(idx_blk, ranks)
            sa_out.finish()
            store.drop_runs(runs)
            stats.rounds = rnd + 1
            saturated = int(last_rank) == n - 1
            if saturated or rnd == num_steps:
                scat.discard()                     # ranks no longer needed
                break
            scat.finish(n)
            k = 1 if k == 0 else k * 2

        # Emit the final SA ("sa" chunks hold the last round's order).
        stats.spill_bytes = store.spill_bytes
        stats.elapsed_s = time.perf_counter() - t0
        stats.bases_per_s = n / stats.elapsed_s if stats.elapsed_s else 0.0
        if emit_shard is None:
            sa = np.concatenate([store.get("sa", j)
                                 for j in range(n_chunks)])
            return sa, stats
        shard_i = 0
        buf: list = []
        have = 0
        for j in range(n_chunks):
            buf.append(store.get("sa", j))
            have += len(buf[-1])
            while have >= shard_rows:
                cat = np.concatenate(buf)
                emit_shard(shard_i, cat[:shard_rows])
                shard_i += 1
                buf = [cat[shard_rows:]]
                have = len(buf[0])
        if have:
            emit_shard(shard_i, np.concatenate(buf))
        return None, stats
    finally:
        store.close()

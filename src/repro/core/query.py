"""Pattern-match queries over a TabletStore (paper §V "scans").

A scan is a batched lower/upper-bound binary search over the sorted suffix
array.  The paper's "50 user threads" become the batch axis; each search
round gathers one suffix window per query and compares it against the
pattern in a single dense VMEM op (the Pallas ``pattern_scan`` kernel on
TPU; the jnp path below is the oracle and the CPU fallback).

Distributed mode mirrors an Accumulo scan fan-out: every tablet performs
the search on its local rows; because lower/upper bounds are ADDITIVE over
contiguous tablets, the global bound is a single ``psum`` — one scalar per
query crosses the wire, not rows (DESIGN.md §2).

Callers should not pick between ``query`` / ``query_sharded`` /
``query_routed`` directly: ``repro.core.planner.ScanPlanner`` selects the
execution mode, retries the routed path's sentinel counts (-1 dispatch
overflow, -2 saturated run — see ``query_routed``) to exact values, and
adds match enumeration + caching.  See docs/scan_planner.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compat import pcast_varying
from repro.core import codec
from repro.core.tablet import TabletStore

WORD = codec.BASES_PER_WORD


@partial(jax.tree_util.register_dataclass,
         data_fields=("found", "count", "first_rank", "first_pos"),
         meta_fields=())
@dataclasses.dataclass(frozen=True)
class MatchResult:
    """Outcome of one batch of scans (paper Table II columns)."""
    found: jnp.ndarray       # (B,)  bool    — paper's ``outcome``
    count: jnp.ndarray       # (B,)  int32   — number of occurrences
    first_rank: jnp.ndarray  # (B,)  int32   — row index in the real SA
    first_pos: jnp.ndarray   # (B,)  int32   — text position of first match


# ---------------------------------------------------------------------------
# Pattern encoding
# ---------------------------------------------------------------------------
def encode_patterns(patterns: list[str], max_len: int):
    """list of DNA strings -> (codes (B, max_len) int32 zero-padded,
    packed (B, W) uint32, lengths (B,) int32)."""
    B = len(patterns)
    lengths = np.array([len(p) for p in patterns], np.int32)
    assert lengths.max(initial=0) <= max_len, (
        f"pattern length {int(lengths.max(initial=0))} exceeds "
        f"max_len={max_len}")
    W = codec.packed_length(max_len)
    if B == 0:
        # empty batches occur naturally (e.g. a retry pass with nothing to
        # retry, or a fully cache-served planner batch) — np.stack([]) raises
        return (jnp.zeros((0, max_len), jnp.int32),
                jnp.zeros((0, W), jnp.uint32),
                jnp.zeros((0,), jnp.int32))
    codes = np.zeros((B, max_len), np.int32)
    for i, p in enumerate(patterns):
        codes[i, : len(p)] = codec.encode_dna(p)
    packed = codec.pack_2bit_batch(codes)
    return jnp.asarray(codes), jnp.asarray(packed[:, :W]), jnp.asarray(lengths)


def random_patterns(num: int, min_len: int = 1, max_len: int = 100,
                    seed: int = 0):
    """The paper's workload: random ACGT patterns, uniform length 1..100."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(min_len, max_len + 1, size=num)
    pats = ["".join(codec.DNA_ALPHABET[c]
                    for c in rng.integers(0, 4, size=int(L)))
            for L in lengths]
    return pats


# ---------------------------------------------------------------------------
# Packed compare (DNA fast path): suffix-vs-pattern at depth `plen`
# ---------------------------------------------------------------------------
def _word_masks(plen: jnp.ndarray, n_words: int) -> jnp.ndarray:
    """(B, n_words) uint32 masks keeping the first ``plen`` bases."""
    w = jnp.arange(n_words, dtype=jnp.int32)[None, :]
    r = jnp.clip(plen[:, None] - w * WORD, 0, WORD).astype(jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    partial_mask = jnp.where(
        r == 0, jnp.uint32(0),
        jnp.where(r == WORD, full, ~((jnp.uint32(1) << (32 - 2 * r)) - 1)))
    return partial_mask


def compare_windows_packed(window: jnp.ndarray, pos: jnp.ndarray,
                           n_real, patt_packed: jnp.ndarray,
                           plen: jnp.ndarray):
    """Returns (lt, eq) for pre-extracted packed ``window`` rows (B, W).
    ``n_real`` may be a scalar or a per-row vector — rows of a fused
    multi-store compare come from different texts."""
    n_words = patt_packed.shape[-1]
    mask = _word_masks(plen, n_words)
    a = window & mask
    b = patt_packed & mask
    eq_w = a == b
    prefix_eq = jnp.cumprod(eq_w.astype(jnp.int32), axis=-1)
    prefix_eq_shifted = jnp.concatenate(
        [jnp.ones_like(prefix_eq[:, :1]), prefix_eq[:, :-1]], axis=-1)
    first_diff = (~eq_w) & (prefix_eq_shifted == 1)
    lt_raw = jnp.any(first_diff & (a < b), axis=-1)
    eq_all = jnp.all(eq_w, axis=-1)
    truncated = pos + plen > n_real            # suffix shorter than pattern
    lt = lt_raw | (eq_all & truncated)
    eq = eq_all & ~truncated
    return lt, eq


def compare_packed(packed_text: jnp.ndarray, n_real: int,
                   pos: jnp.ndarray, patt_packed: jnp.ndarray,
                   plen: jnp.ndarray):
    """Returns (lt, eq): suffix(pos) < pattern, suffix starts-with pattern.
    All (B,) bool.  Handles text-boundary truncation exactly."""
    window = codec.extract_window(packed_text, pos, patt_packed.shape[-1])
    return compare_windows_packed(window, pos, n_real, patt_packed, plen)


def gather_suffix_codes(codes: jnp.ndarray, n_real, pos: jnp.ndarray,
                        length: int) -> jnp.ndarray:
    """(B, length) int32 suffix windows at ``pos``; reads past ``n_real``
    come back -1 (< any real code), which is what makes truncated
    suffixes sort first without an explicit fix-up."""
    offs = jnp.arange(length, dtype=jnp.int32)[None, :]
    idx = pos[:, None] + offs
    return jnp.where(idx < n_real,
                     jnp.take(codes, jnp.clip(idx, 0, codes.shape[0] - 1)),
                     -1)


def compare_suffix_codes(suf: jnp.ndarray, patt_codes: jnp.ndarray,
                         plen: jnp.ndarray):
    """(lt, eq) for pre-gathered token suffix windows (B, L)."""
    L = patt_codes.shape[-1]
    offs = jnp.arange(L, dtype=jnp.int32)[None, :]
    valid = offs < plen[:, None]
    eq_w = jnp.where(valid, suf == patt_codes, True)
    prefix_eq = jnp.cumprod(eq_w.astype(jnp.int32), axis=-1)
    prefix_eq_shifted = jnp.concatenate(
        [jnp.ones_like(prefix_eq[:, :1]), prefix_eq[:, :-1]], axis=-1)
    first_diff = (~eq_w) & (prefix_eq_shifted == 1)
    lt = jnp.any(first_diff & (suf < patt_codes), axis=-1)
    eq = jnp.all(eq_w, axis=-1)
    return lt, eq


def compare_codes(codes: jnp.ndarray, n_real: int,
                  pos: jnp.ndarray, patt_codes: jnp.ndarray,
                  plen: jnp.ndarray):
    """Generic token path (vocab-sized alphabets).  codes is the padded
    int32 text; out-of-range reads are -1 (< any real code)."""
    suf = gather_suffix_codes(codes, n_real, pos, patt_codes.shape[-1])
    return compare_suffix_codes(suf, patt_codes, plen)


def _compare(store: TabletStore, pos, patt, plen):
    if store.is_dna and patt.dtype == jnp.uint32:
        return compare_packed(store.text_packed, store.n_real, pos, patt, plen)
    return compare_codes(store.text_codes, store.n_real, pos, patt, plen)


# ---------------------------------------------------------------------------
# Batched binary search
# ---------------------------------------------------------------------------
def _bounded_search(sa: jnp.ndarray, pred_fn, batch: int, n_rows: int,
                    varying_axis=None):
    """Per-query first index in [0, n_rows] where pred(sa[idx]) is False.
    pred = 'suffix is still before the target'.  ``varying_axis``: when run
    inside shard_map with a device-varying ``sa``, the loop carry must be
    marked varying over that axis (VMA tracking)."""
    steps = max(1, int(np.ceil(np.log2(n_rows + 1))))

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        pos = jnp.take(sa, jnp.clip(mid, 0, n_rows - 1))
        pred = pred_fn(pos)
        active = lo < hi
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
        return lo, hi

    lo = jnp.zeros((batch,), jnp.int32)
    hi = jnp.full((batch,), n_rows, jnp.int32)
    if varying_axis is not None:
        lo = pcast_varying(lo, varying_axis)
        hi = pcast_varying(hi, varying_axis)
    lo, _ = lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def query(store: TabletStore, patt, plen) -> MatchResult:
    """Single-device scan batch.  ``patt`` is packed uint32 (B, W) for DNA or
    int32 codes (B, L) for token corpora; ``plen`` (B,) int32."""
    B = patt.shape[0]
    n = store.n_pad

    lb = _bounded_search(
        store.sa, lambda pos: _compare(store, pos, patt, plen)[0], B, n)
    ub = _bounded_search(
        store.sa,
        lambda pos: (lambda lt, eq: lt | eq)(*_compare(store, pos, patt, plen)),
        B, n)
    count = ub - lb
    found = count > 0
    first_pos = jnp.take(store.sa, jnp.clip(lb, 0, n - 1))
    first_pos = jnp.where(found, first_pos, -1)
    first_rank = jnp.where(found, lb - store.pad_count, -1)
    return MatchResult(found=found, count=count,
                       first_rank=first_rank, first_pos=first_pos)


# ---------------------------------------------------------------------------
# Distributed scan (inside shard_map): additive bounds + one psum
# ---------------------------------------------------------------------------
def query_sharded(sa_local: jnp.ndarray, store_meta: TabletStore,
                  patt, plen, axis_name) -> MatchResult:
    """Paper-faithful Accumulo fan-out: every tablet searches its local rows
    for every query.  ``sa_local`` is this device's tablet (m rows);
    ``store_meta`` carries the (replicated) text and static metadata — its
    ``sa`` field is ignored.  Returns replicated MatchResult."""
    m = sa_local.shape[0]
    p = lax.psum(1, axis_name)
    d = lax.axis_index(axis_name)
    B = patt.shape[0]

    local_lb = _bounded_search(
        sa_local, lambda pos: _compare(store_meta, pos, patt, plen)[0], B, m,
        varying_axis=axis_name)
    local_ub = _bounded_search(
        sa_local,
        lambda pos: (lambda lt, eq: lt | eq)(
            *_compare(store_meta, pos, patt, plen)), B, m,
        varying_axis=axis_name)

    lb = lax.psum(local_lb, axis_name)
    ub = lax.psum(local_ub, axis_name)
    count = ub - lb
    found = count > 0
    # tablet owning the global lower bound: lb in [d*m, (d+1)*m)
    owner_is_me = (lb >= d * m) & (lb < (d + 1) * m)
    local_idx = jnp.clip(lb - d * m, 0, m - 1)
    mine = jnp.where(owner_is_me, jnp.take(sa_local, local_idx), 0)
    first_pos = lax.psum(mine, axis_name)
    first_pos = jnp.where(found, first_pos, -1)
    pad_count = store_meta.n_pad - store_meta.n_real
    first_rank = jnp.where(found, lb - pad_count, -1)
    return MatchResult(found=found, count=count,
                       first_rank=first_rank, first_pos=first_pos)


# ---------------------------------------------------------------------------
# Oracle (naive scan, paper Algorithm 1) for tests
# ---------------------------------------------------------------------------
def brute_force_count(text_codes: np.ndarray, pattern_codes: np.ndarray):
    """BruteForceSearch of paper Algorithm 1, returning (count, first_pos)."""
    n, k = len(text_codes), len(pattern_codes)
    count, first = 0, -1
    for i in range(n - k + 1):
        if (text_codes[i:i + k] == pattern_codes).all():
            count += 1
            if first < 0:
                first = i
    return count, first


# ---------------------------------------------------------------------------
# Routed scan (beyond-paper): queries travel to their owner tablet instead
# of broadcasting to all tablets.  Per-device work drops from O(B log m) to
# O(B/p log m); the price is two fixed-capacity all_to_alls (the same
# capacity-factor pattern as MoE dispatch).  Overflowed queries (hot tablet)
# come back with count = -1 — callers retry via the broadcast path.
# ---------------------------------------------------------------------------
def query_routed(sa_local: jnp.ndarray, store_meta: TabletStore,
                 patt, plen, axis_name, capacity_factor: float = 2.0
                 ) -> MatchResult:
    """Inside shard_map: ``patt``/``plen`` are the LOCAL query shard
    (B_local, W)/(B_local,).  Returns local-shard MatchResult."""
    m = sa_local.shape[0]
    p = lax.psum(1, axis_name)
    d = lax.axis_index(axis_name)
    Bl = patt.shape[0]
    W = patt.shape[1]

    # --- split keys: first suffix window of every tablet (replicated)
    first_pos = sa_local[:1]
    my_key = codec.extract_window(store_meta.text_packed, first_pos, W)
    split_keys = lax.all_gather(my_key[0], axis_name)          # (p, W)
    split_pos = lax.all_gather(first_pos[0], axis_name)        # (p,)

    # --- owner tablet per query: the tablet holding the global lower
    # bound.  a = #{tablets whose FIRST suffix < P} (strict); the lb row
    # lives in tablet a-1 (or its successor when lb sits exactly on the
    # boundary — the spill-correction pass below covers that case).
    def lt_count(q_patt, q_len):
        lt, _eq = compare_packed(store_meta.text_packed, store_meta.n_real,
                                 split_pos, jnp.broadcast_to(q_patt, (p, W)),
                                 jnp.broadcast_to(q_len, (p,)))
        return jnp.sum(lt.astype(jnp.int32))

    a = jax.vmap(lt_count)(patt, plen)                         # (Bl,)
    owner = jnp.clip(a - 1, 0, p - 1)

    # --- fixed-capacity dispatch to owners
    cap = max(4, int(np.ceil(Bl / p * capacity_factor)))
    order = jnp.argsort(owner, stable=True)
    o_s = owner[order]
    start = jnp.searchsorted(o_s, jnp.arange(p, dtype=jnp.int32))
    slot_in = jnp.arange(Bl, dtype=jnp.int32) - start[o_s]
    ok = slot_in < cap
    slot = jnp.where(ok, o_s * cap + slot_in, p * cap)

    def scatter(x, fill):
        buf = jnp.full((p * cap,) + x.shape[1:], fill, x.dtype)
        return buf.at[slot].set(jnp.where(
            ok.reshape((-1,) + (1,) * (x.ndim - 1)), x[order], fill),
            mode="drop")

    send_patt = scatter(patt, jnp.uint32(0)).reshape(p, cap, W)
    send_len = scatter(plen, jnp.int32(-1)).reshape(p, cap)
    recv_patt = lax.all_to_all(send_patt, axis_name, 0, 0).reshape(-1, W)
    recv_len = lax.all_to_all(send_len, axis_name, 0, 0).reshape(-1)

    # --- local search on my tablet only (lower bound clamps to my range)
    valid = recv_len >= 0
    rl = jnp.where(valid, recv_len, 1)
    local_lb = _bounded_search(
        sa_local, lambda pos: _compare(store_meta, pos, recv_patt, rl)[0],
        p * cap, m, varying_axis=axis_name)
    local_ub = _bounded_search(
        sa_local,
        lambda pos: (lambda lt, eq: lt | eq)(
            *_compare(store_meta, pos, recv_patt, rl)), p * cap, m,
        varying_axis=axis_name)
    # matches may spill into later tablets; count here covers the owner
    # tablet; spill is detected when ub hits the tablet end and the last
    # row still prefix-matches -> handled by one psum'd correction pass
    # against the NEXT tablet only (suffix order bounds the spill for
    # patterns shorter than the tablet span; exactness verified in tests).
    cnt = local_ub - local_lb
    fpos = jnp.where(cnt > 0,
                     jnp.take(sa_local, jnp.clip(local_lb, 0, m - 1)), -1)
    frank = jnp.where(cnt > 0, d * m + local_lb
                      - (store_meta.n_pad - store_meta.n_real), -1)

    # spill correction: ask the RIGHT neighbour how many of its rows
    # continue the match (ub == m means the run may continue).  Tablet d
    # evaluates the queries OWNED BY d-1, so patterns travel right
    # (r -> r+1) and results travel back left (r -> r-1).
    # (no spill past the last tablet — the ppermute ring wraps to tablet 0,
    # whose rows are the globally smallest suffixes, not a continuation)
    spill_possible = (cnt >= 0) & (local_ub == m) & valid & (d < p - 1)
    perm_right = [(r, (r + 1) % p) for r in range(p)]
    perm_left = [(r, (r - 1) % p) for r in range(p)]
    nb_patt = lax.ppermute(recv_patt, axis_name, perm_right)
    nb_len = lax.ppermute(rl, axis_name, perm_right)
    nb_lb = _bounded_search(
        sa_local, lambda pos: _compare(store_meta, pos, nb_patt, nb_len)[0],
        p * cap, m, varying_axis=axis_name)
    nb_ub = _bounded_search(
        sa_local,
        lambda pos: (lambda lt, eq: lt | eq)(
            *_compare(store_meta, pos, nb_patt, nb_len)), p * cap, m,
        varying_axis=axis_name)
    nb_cnt = nb_ub - nb_lb                       # neighbour's matching run
    spill_cnt = lax.ppermute(nb_cnt, axis_name, perm_left)
    spill_sat = lax.ppermute(nb_ub == m, axis_name, perm_left)
    spill_first = lax.ppermute(
        jnp.where(nb_cnt > 0, jnp.take(sa_local,
                                       jnp.clip(nb_lb, 0, m - 1)), -1),
        axis_name, perm_left)
    # global SA row of the neighbour's run start (for first_rank when the
    # whole run lives in the neighbour: a match starting exactly at the
    # tablet boundary leaves the owner's local run empty)
    spill_rank = lax.ppermute(
        jnp.where(nb_cnt > 0,
                  d * m + nb_lb - (store_meta.n_pad - store_meta.n_real),
                  -1), axis_name, perm_left)
    cnt = jnp.where(spill_possible, cnt + spill_cnt, cnt)
    fpos = jnp.where((cnt > 0) & (fpos < 0), spill_first, fpos)
    frank = jnp.where((cnt > 0) & (frank < 0), spill_rank, frank)
    # match run crosses >2 tablets (very short pattern): exact count needs
    # the broadcast path — flag with -2 (found stays exact: run nonempty)
    saturated = spill_possible & spill_sat
    cnt = jnp.where(saturated, -2, cnt)

    # --- route results back
    back_cnt = lax.all_to_all(cnt.reshape(p, cap), axis_name, 0, 0
                              ).reshape(-1)
    back_pos = lax.all_to_all(fpos.reshape(p, cap), axis_name, 0, 0
                              ).reshape(-1)
    back_rank = lax.all_to_all(frank.reshape(p, cap), axis_name, 0, 0
                               ).reshape(-1)
    # un-permute into original query order
    out_cnt = jnp.full((Bl,), -1, jnp.int32)    # -1 => overflow, retry
    take_slot = jnp.where(ok, slot, p * cap)
    gathered = jnp.where(ok, back_cnt[jnp.clip(take_slot, 0, p * cap - 1)],
                         -1)
    out_cnt = out_cnt.at[order].set(gathered, mode="drop")
    g_pos = jnp.where(ok, back_pos[jnp.clip(take_slot, 0, p * cap - 1)], -1)
    g_rank = jnp.where(ok, back_rank[jnp.clip(take_slot, 0, p * cap - 1)],
                       -1)
    out_pos = jnp.zeros((Bl,), jnp.int32).at[order].set(g_pos, mode="drop")
    out_rank = jnp.zeros((Bl,), jnp.int32).at[order].set(g_rank,
                                                         mode="drop")
    # count: >0 exact | 0 no match | -1 dispatch overflow (retry)
    #        | -2 saturated run (found=True, exact count via broadcast)
    found = (out_cnt > 0) | (out_cnt == -2)
    return MatchResult(found=found, count=out_cnt,
                       first_rank=jnp.where(found, out_rank, -1),
                       first_pos=jnp.where(found, out_pos, -1))

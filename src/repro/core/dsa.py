"""Distributed suffix-array construction (paper §IV pre-processing phase).

Prefix doubling where every sort is a distributed sort over the mesh axis
(``dsort``): each device ever holds only n/p rows — this is the Accumulo
tablet-ingest analogue.  The text is padded to p*m with a virtual minimal
symbol (initial rank -1, smaller than every real code), which (a) keeps
blocks equal-size for the collectives and (b) makes suffix order of real
positions identical to the unpadded text (a run of minimal symbols is the
standard ``$`` terminator generalized).  Pad suffixes occupy the first
``pad_count`` rows of the sorted order; queries are unaffected because all
real patterns compare greater than the pad symbol.

All functions here run INSIDE shard_map over ``axis_name``.
``build_suffix_array_distributed`` is the host-side convenience wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.dsort import (bitonic_sort_sharded, sample_sort_sharded,
                              sort_sharded_auto)
from repro.distributed.sharding import mesh_axis_size


def _axis_size(axis_name) -> int:
    return lax.psum(1, axis_name)


def _sort(operands, num_keys, axis_name, method):
    if method == "sample":
        return sort_sharded_auto(operands, num_keys=num_keys,
                                 axis_name=axis_name)
    if method == "sample_unsafe":  # dry-run/roofline: pure sample-sort HLO
        out, _ = sample_sort_sharded(operands, num_keys=num_keys,
                                     axis_name=axis_name)
        return out
    return bitonic_sort_sharded(operands, num_keys=num_keys,
                                axis_name=axis_name)


def _shift_ranks(rank, k: int, n_pad: int, axis_name):
    """nxt[i] = rank[gpos_i + k] in text-order sharding, -1 past the end.
    k is a static Python int; the source spans <= 2 neighbour blocks."""
    p = _axis_size(axis_name)
    m = rank.shape[0]
    d = lax.axis_index(axis_name)
    s0 = (k // m) % p
    perm0 = [(r, (r - s0) % p) for r in range(p)]
    perm1 = [(r, (r - s0 - 1) % p) for r in range(p)]
    from0 = lax.ppermute(rank, axis_name, perm0) if s0 else rank
    from1 = lax.ppermute(rank, axis_name, perm1)
    combined = jnp.concatenate([from0, from1])
    r = k % m
    nxt = lax.slice(combined, (r,), (r + m,))
    gpos = d * m + jnp.arange(m, dtype=jnp.int32)
    return jnp.where(gpos + k < n_pad, nxt, -1).astype(jnp.int32)


def _relabel_sharded(rank_s, nxt_s, axis_name):
    """Dense new ranks for globally sorted (rank, nxt) rows."""
    p = _axis_size(axis_name)
    d = lax.axis_index(axis_name)
    # previous row's key (from left neighbour's last row)
    perm = [(r, (r + 1) % p) for r in range(p)]
    prev_rank = lax.ppermute(rank_s[-1:], axis_name, perm)
    prev_nxt = lax.ppermute(nxt_s[-1:], axis_name, perm)
    pr = jnp.concatenate([prev_rank, rank_s[:-1]])
    pn = jnp.concatenate([prev_nxt, nxt_s[:-1]])
    changed = ((rank_s != pr) | (nxt_s != pn)).astype(jnp.int32)
    # global row 0 is never "changed" (rank 0 by definition)
    changed = changed.at[0].set(jnp.where(d == 0, 0, changed[0]))
    local_cum = jnp.cumsum(changed)
    totals = lax.all_gather(local_cum[-1], axis_name)            # (p,)
    offset = jnp.sum(jnp.where(jnp.arange(p) < d, totals, 0))
    return (offset + local_cum).astype(jnp.int32)


def build_suffix_array_sharded(codes_local, *, n_real: int, axis_name,
                               method: str = "bitonic",
                               num_steps: int | None = None):
    """Inside shard_map: codes_local is this device's text block (m,), already
    padded globally to p*m (pad values ignored — ranks forced to -1).
    Returns (sa_local, rank_local): device d holds sorted rows
    [d*m, (d+1)*m) of the padded suffix array and text-order ranks."""
    p = _axis_size(axis_name)
    m = codes_local.shape[0]
    n_pad = p * m
    d = lax.axis_index(axis_name)
    gpos = d * m + jnp.arange(m, dtype=jnp.int32)

    rank = jnp.where(gpos < n_real, codes_local.astype(jnp.int32), -1)
    if num_steps is None:
        num_steps = max(1, int(np.ceil(np.log2(n_pad))))

    # densify initial ranks: sort by (rank,), relabel, scatter back by gpos
    r_s, g_s = _sort((rank, gpos), 1, axis_name, method)
    new_r = _relabel_sharded(r_s, r_s, axis_name)
    g_back, rank = _sort((g_s, new_r), 1, axis_name, method)
    sa = gpos

    k = 1
    for _ in range(num_steps):
        nxt = _shift_ranks(rank, k, n_pad, axis_name)
        r_s, n_s, sa = _sort((rank, nxt, gpos), 2, axis_name, method)
        new_r = _relabel_sharded(r_s, n_s, axis_name)
        _, rank = _sort((sa, new_r), 1, axis_name, method)
        k *= 2
    return sa, rank


def make_superchunk_sorter(mesh, axis_name: str, method: str = "sample"):
    """Jitted mesh sort of one (key, nxt, idx) super-chunk for the staged
    build (``repro.core.build_pipeline``).  All three operands are int32
    of equal length divisible by the axis size; rows sort ascending by the
    full triple (idx last forces deterministic ties, so the result matches
    a stable 2-key sort of text-ordered rows bit-for-bit)."""
    spec = P(axis_name)

    @jax.jit
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(spec,) * 3,
                       out_specs=(spec,) * 3)
    def run(key, nxt, idx):
        return _sort((key, nxt, idx), 3, axis_name, method)

    return run


def build_suffix_array_distributed(codes: np.ndarray, mesh, axis_name: str,
                                   method: str = "bitonic"):
    """Host-side wrapper: pads, shard_maps, returns (sa_padded, pad_count).
    Real suffix array = sa_padded[pad_count:]."""
    p = mesh_axis_size(mesh, axis_name)
    n_real = int(len(codes))
    m = int(np.ceil(n_real / p))
    n_pad = m * p
    padded = np.zeros((n_pad,), dtype=np.int32)
    padded[:n_real] = np.asarray(codes, dtype=np.int32)

    spec = P(axis_name)
    fn = functools.partial(build_suffix_array_sharded, n_real=n_real,
                           axis_name=axis_name, method=method)

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(spec,),
                       out_specs=(spec, spec))
    def run(c):
        return fn(c)

    sa, rank = jax.jit(run)(padded)
    return sa, n_pad - n_real

"""Corpus dedup & contamination search — the LM-pipeline face of TabletSA.

The operation the paper performs on DNA (exact-substring lookup over a
sorted suffix store) is exactly what LM data pipelines need for
(a) exact-duplicate span detection (suffix-array dedup a la Lee et al.),
(b) eval-set contamination queries, and (c) exact-match retrieval.
This module wires the core engine into ``repro.data`` (DESIGN.md §3).

Every function accepts either a bare :class:`TabletStore` (pre-table
shim) or a :class:`repro.api.SuffixTable`.  LCP-based span detection runs
over the table's BASE index (``compact()`` first to cover appends);
``contamination_check`` on a table goes through the merged read path, so
appended-but-uncompacted training text is already searched.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.suffix_array import adjacent_lcp
from repro.core.tablet import TabletStore


def _base_store(store) -> TabletStore:
    """Unwrap a SuffixTable to its base TabletStore; pass stores through."""
    if isinstance(store, TabletStore):
        return store
    return store.store


def duplicate_span_mask(store, min_len: int) -> jnp.ndarray:
    """Boolean mask over text positions: True where a substring of length
    >= min_len starting there occurs at least twice in the corpus.

    Adjacent rows of the suffix array with LCP >= min_len are exactly the
    pairs of duplicated spans; both members get marked."""
    store = _base_store(store)
    text = store.text_codes
    sa = store.sa
    lcp = adjacent_lcp(text, sa, min_len)           # (n_pad-1,)
    dup = lcp >= min_len                            # pair (i, i+1) duplicated
    n = store.n_pad
    mask_sorted = jnp.zeros((n,), bool)
    mask_sorted = mask_sorted.at[:-1].set(dup)
    mask_sorted = mask_sorted.at[1:].max(dup)
    # scatter back to text positions; drop pad rows
    mask_text = jnp.zeros((n,), bool).at[sa].set(mask_sorted)
    return mask_text[: store.n_real]


def duplicate_fraction(store, min_len: int) -> jnp.ndarray:
    """Fraction of corpus positions inside >=min_len duplicated spans."""
    m = duplicate_span_mask(store, min_len)
    return jnp.mean(m.astype(jnp.float32))


def doc_dup_scores(store, doc_ids: np.ndarray,
                   min_len: int) -> np.ndarray:
    """Per-document duplicated-position fraction.  ``doc_ids`` maps each
    text position to its document (int, length n_real)."""
    mask = np.asarray(duplicate_span_mask(store, min_len))
    doc_ids = np.asarray(doc_ids)
    num_docs = int(doc_ids.max()) + 1 if doc_ids.size else 0
    tot = np.bincount(doc_ids, minlength=num_docs).astype(np.float64)
    dup = np.bincount(doc_ids, weights=mask.astype(np.float64),
                      minlength=num_docs)
    return dup / np.maximum(tot, 1)


def filter_duplicate_docs(store, doc_ids: np.ndarray,
                          min_len: int, threshold: float = 0.5) -> np.ndarray:
    """Returns the boolean keep-mask over documents (True = keep)."""
    return doc_dup_scores(store, doc_ids, min_len) < threshold


def contamination_check(store, eval_token_windows: np.ndarray
                        ) -> np.ndarray:
    """True per eval window if it appears verbatim in the training corpus.
    ``eval_token_windows``: (B, L) int32 token n-grams.  Given a
    SuffixTable, the merged read path also searches un-compacted appends."""
    w = jnp.asarray(eval_token_windows, jnp.int32)
    plen = jnp.full((w.shape[0],), w.shape[1], jnp.int32)
    if isinstance(store, TabletStore):
        res = Q.query(store, w, plen)
    else:
        res = store.scan_encoded(w, plen)
    return np.asarray(res.found)

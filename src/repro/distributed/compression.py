"""Gradient compression for cross-pod reduction (DESIGN.md §5).

int8 block-quantized gradient exchange with error feedback: the pod-
crossing hop is the slow link (DCN vs ICI), so gradients are quantized to
int8 with a per-block fp32 scale and exchanged via ``all_gather`` (int8 on
the wire — visible as an s8 collective in the dry-run HLO, which is how the
roofline parser credits the 4x byte saving), then dequantized and averaged
locally.  The quantization residual is carried in an error-feedback buffer
so the bias vanishes over steps (Karimireddy et al. 2019); tests verify
convergence parity.

Used by the explicit-DP trainer (shard_map over 'pod'); inside plain GSPMD
jit the collective is compiler-inserted and can't be intercepted, which is
why the pod-axis trainer is shard_map'd.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

BLOCK = 256


def _quantize(x):
    """fp32 (n,) -> (int8 blocks (nb, BLOCK), scales (nb,), pad)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xb = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xb / jnp.maximum(scale, 1e-20)), -127, 127
                 ).astype(jnp.int8)
    return q, scale[:, 0], pad


def compressed_pmean(x: jnp.ndarray, axis_name, err: jnp.ndarray):
    """Mean-reduce ``x`` over ``axis_name``: int8 payload on the wire.
    Returns (mean, new_err).  ``err`` matches x's shape (error feedback)."""
    shape = x.shape
    flat = (x.astype(jnp.float32) + err.astype(jnp.float32)).reshape(-1)
    n = flat.shape[0]
    q, scale, pad = _quantize(flat)
    sent = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    new_err = (flat - sent).reshape(shape)

    q_all = lax.all_gather(q, axis_name)              # (p, nb, BLOCK) int8
    s_all = lax.all_gather(scale, axis_name)          # (p, nb) fp32
    p = q_all.shape[0]
    deq = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None], axis=0) / p
    mean = deq.reshape(-1)[:n].reshape(shape)
    return mean.astype(x.dtype), new_err.astype(x.dtype)


def compressed_pmean_tree(tree, axis_name, err_tree):
    outs = jax.tree.map(
        lambda x, e: compressed_pmean(x, axis_name, e), tree, err_tree)
    mean = jax.tree.map(lambda o: o[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], outs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return mean, err


def zeros_like_tree(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)


def wire_bytes(tree) -> int:
    """Bytes on the slow link per exchange: int8 payload + fp32 scales."""
    total = 0
    for x in jax.tree.leaves(tree):
        n = int(np.prod(x.shape))
        total += n + 4 * ((n + BLOCK - 1) // BLOCK)
    return total

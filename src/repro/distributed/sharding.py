"""Sharding rules: logical names -> PartitionSpec for params/activations.

Strategy (DESIGN.md §5): 2-D FSDP x TP.
  * `model` axis: TP — attention heads, FFN hidden, experts (EP), vocab.
  * `data`  axis (+ `pod` when present): DP for the batch, FSDP for the
    non-TP dim of every large weight, ZeRO-1 for optimizer state (it
    inherits the param specs).
Param specs come from an explicit name-based table (the last path segment
plus enclosing module), applied to the trailing dims — stacked (scanned)
tensors carry a leading n_periods dim that is never sharded.  Activations
are constrained via the ``shard`` callback.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh):
    """All DP-capable axes present in the mesh ('pod' folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_axis_size(mesh: Optional[Mesh], axis_name=None) -> int:
    """Device count along ``axis_name`` (a name, a tuple of names, or
    ``None`` for every axis).  ``mesh=None`` means single-device (1).

    The one shared spelling of the "how many shards live on this axis"
    computation that the tablet store, scan planner, and staged build
    pipeline all need (previously each re-derived it inline from
    ``mesh.shape``)."""
    if mesh is None:
        return 1
    if axis_name is None:
        axes = tuple(mesh.axis_names)
    elif isinstance(axis_name, tuple):
        axes = axis_name
    else:
        axes = (axis_name,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


# Role tables: trailing-dims spec templates.  'M' = model axis, 'D' = data
# (FSDP) axes, None = replicated.  Matched on (enclosing, leaf-name).
_RULES: list[tuple[str, str, tuple]] = [
    # (enclosing-regex, leaf-regex, trailing spec)
    (r"moe", r"^(wi|wg|wo)$",      ("M", "D", None)),   # (E, d, f)/(E, f, d)
    (r"moe", r"^router$",          (None, None)),
    (r"shared", r"^(wi|wg)$",      ("D", "M")),         # (d, f)
    (r"shared", r"^wo$",           ("M", "D")),         # (f, d)
    (r"(attn|mtp)", r"^(wq|wk|wv)$", ("D", "M", None)), # (d, H, dh)
    (r"(attn|mtp)", r"^(wq_b|wk_b|wv_b)$", ("D", "M", None)),  # (r, H, dh)
    (r"(attn|mtp)", r"^(wq_a|wkv_a)$",     ("D", "M")),        # (d, r)
    (r"(attn|mtp)", r"^wo$",       ("M", None, "D")),   # (H, dh, d)
    (r"(attn|mtp)", r"^(bq|bk|bv)$", ("M", None)),      # (H, dh)
    (r"ssm", r"^in_proj$",         ("D", "M")),         # (d, 2di+2N+H)
    (r"ssm", r"^out_proj$",        ("M", "D")),         # (di, d)
    (r"", r"^(wi|wg)$",            ("D", "M")),         # dense mlp
    (r"", r"^wo$",                 ("M", "D")),
    (r"", r"^embed$",              ("M", "D")),         # (V, d)
    (r"", r"^unembed$",            ("D", "M")),         # (d, V)
    (r"", r"^proj$",               ("D", "M")),         # mtp proj (2d, d)
]


def _leaf_name(path: str) -> tuple[str, str]:
    keys = re.findall(r"\['([^']+)'\]", path)
    leaf = keys[-1] if keys else path
    enclosing = "/".join(keys[:-1])
    return enclosing, leaf


def param_spec(path: str, shape: tuple, mesh: Mesh,
               fsdp: bool = True) -> P:
    d_axes = data_axes(mesh) if fsdp else ()
    model_size = mesh.shape.get("model", 1)
    d_size = int(np.prod([mesh.shape[a] for a in d_axes])) if d_axes else 1
    enclosing, leaf = _leaf_name(path)

    for enc_re, leaf_re, template in _RULES:
        if re.search(enc_re, enclosing) and re.match(leaf_re, leaf):
            n_tail = len(template)
            if len(shape) < n_tail:
                return P()
            lead = len(shape) - n_tail
            spec: list = [None] * len(shape)
            for i, role in enumerate(template):
                dim = lead + i
                if role == "M" and shape[dim] % model_size == 0 \
                        and shape[dim] >= model_size:
                    spec[dim] = "model"
                elif role == "D" and d_axes and shape[dim] % d_size == 0 \
                        and shape[dim] >= d_size:
                    spec[dim] = d_axes
            return P(*spec)
    return P()          # norms, biases, scalars: replicated


def param_specs(params, mesh: Mesh, fsdp: bool = True):
    """Tree of PartitionSpecs matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        specs.append(param_spec(pstr, leaf.shape, mesh, fsdp))
    return jax.tree.unflatten(treedef, specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_shard_fn(mesh: Mesh, seq_shard: bool = False):
    """Activation constraint callback for model code.

    Logical names:
      act       (B, S, d)  batch over data axes (+ optionally seq/model)
      tokens2d  (T, d)     flat tokens over data axes
      moe_ecd   (E, C, *)  experts over model (EP), capacity over data
    """
    d_axes = data_axes(mesh)
    d_size = max(int(np.prod([mesh.shape[a] for a in d_axes])), 1)
    m_size = mesh.shape.get("model", 1)

    def shard(x, name):
        spec = [None] * x.ndim
        if name == "act" and x.ndim >= 2:
            if x.shape[0] % d_size == 0 and x.shape[0] >= d_size:
                spec[0] = d_axes
            if seq_shard and x.ndim >= 3 and x.shape[1] % m_size == 0:
                spec[1] = "model"
        elif name == "tokens2d" and x.ndim == 2:
            if x.shape[0] % d_size == 0 and x.shape[0] >= d_size:
                spec[0] = d_axes
        elif name == "moe_ecd" and x.ndim == 3:
            if x.shape[0] % m_size == 0 and x.shape[0] >= m_size:
                spec[0] = "model"
            if x.shape[1] % d_size == 0 and x.shape[1] >= d_size:
                spec[1] = d_axes
        elif name == "ssd_h2" and x.ndim >= 3:
            # (b, nc, h, ...): batch over data, SSD heads over model
            if x.shape[0] % d_size == 0 and x.shape[0] >= d_size:
                spec[0] = d_axes
            if x.shape[2] % m_size == 0 and x.shape[2] >= m_size:
                spec[2] = "model"
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return shard


def batch_spec_tree(batch, mesh: Mesh):
    """Input batch: shard leading (batch) dim over all data axes when it
    divides; otherwise replicate (long_500k has batch 1)."""
    d_axes = data_axes(mesh)
    d_size = int(np.prod([mesh.shape[a] for a in d_axes])) if d_axes else 1

    def spec_for(v):
        nd = len(v.shape)
        if v.shape[0] % d_size == 0 and v.shape[0] >= d_size:
            return P(d_axes, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree.map(spec_for, batch)


def opt_state_specs(opt_cfg, params, pspecs):
    """ZeRO-1: optimizer moments inherit the param spec.  AdamW m/v mirror
    params exactly; Adafactor's factored stats drop the reduced dim."""
    if opt_cfg.kind == "adamw":
        return {"m": pspecs, "v": pspecs}

    def one(p, spec):
        parts = list(spec)
        parts += [None] * (p.ndim - len(parts))
        st = {}
        if p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1:
            st["vr"] = P(*parts[:-1])
            st["vc"] = P(*(parts[:-2] + parts[-1:]))
        else:
            st["v"] = P(*parts)
        if opt_cfg.b1 > 0:
            st["m"] = P(*parts)
        return st

    return jax.tree.map(one, params, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_specs(caches, mesh: Mesh, batch_size: int):
    """PartitionSpecs for decode caches.  Batch shards over data axes when
    divisible; otherwise the (long) cache sequence dim takes the data axes
    (long_500k: batch=1, 512k-token KV).  Heads/channels shard over model
    when divisible.  Cache layouts (see models/transformer.py):
      k/v     (B, S, KV, dh)   [+ leading n_periods when stacked]
      ckv     (B, S, r) ; krope (B, S, dr)
      ssm     (B, H, P, N) ; conv (B, K-1, ch) ; length scalars/vectors
    """
    d_axes = data_axes(mesh)
    d_size = int(np.prod([mesh.shape[a] for a in d_axes])) if d_axes else 1
    m_size = mesh.shape.get("model", 1)
    batch_ok = batch_size % d_size == 0 and batch_size >= d_size

    def spec_for(path: str, leaf) -> P:
        _, name = _leaf_name(path)
        nd = leaf.ndim
        if name == "length" or nd == 0:
            return P()
        base: dict[int, Any] = {}
        if name in ("k", "v"):
            lead = nd - 4
            seq_axes = []
            if batch_ok:
                base[lead + 0] = d_axes
            else:
                seq_axes.extend(d_axes)
            if leaf.shape[lead + 2] % m_size == 0 \
                    and leaf.shape[lead + 2] >= m_size:
                base[lead + 2] = "model"       # TP over KV heads
            else:
                seq_axes.append("model")       # fall back: shard cache seq
            seq_sz = int(np.prod([mesh.shape[a] for a in seq_axes])) \
                if seq_axes else 1
            if seq_axes and leaf.shape[lead + 1] % seq_sz == 0 \
                    and leaf.shape[lead + 1] >= seq_sz:
                base[lead + 1] = tuple(seq_axes)
        elif name in ("ckv", "krope"):
            lead = nd - 3
            seq_axes = ["model"]               # latent has no head dim
            if batch_ok:
                base[lead + 0] = d_axes
            else:
                seq_axes = list(d_axes) + seq_axes
            seq_sz = int(np.prod([mesh.shape[a] for a in seq_axes]))
            if leaf.shape[lead + 1] % seq_sz == 0 \
                    and leaf.shape[lead + 1] >= seq_sz:
                base[lead + 1] = tuple(seq_axes)
        elif name == "ssm":
            lead = nd - 4
            if batch_ok:
                base[lead + 0] = d_axes
            if leaf.shape[lead + 1] % m_size == 0:
                base[lead + 1] = "model"
        elif name == "conv":
            lead = nd - 3
            if batch_ok:
                base[lead + 0] = d_axes
            if leaf.shape[lead + 2] % m_size == 0:
                base[lead + 2] = "model"
        spec = [base.get(i) for i in range(nd)]
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    specs = [spec_for(jax.tree_util.keystr(p), x) for p, x in flat]
    return jax.tree.unflatten(treedef, specs)

"""GPipe-style pipeline parallelism over a mesh axis (DESIGN.md §5).

The layer stack is split into ``p`` contiguous stages (one per device along
``axis_name``); microbatches stream through with ``ppermute`` hand-offs.
Forward runs p + n_micro - 1 ticks; backward falls out of jax.grad because
ppermute is differentiable (its transpose is the reverse permute), giving
the classic GPipe fill-drain schedule without hand-written backward.

This composes with the TP/FSDP axes: stage params live sharded over the
remaining axes; only the layer dimension moves to the pipeline axis.
Intended for the `pod` axis of the multi-pod mesh (2 stages) but generic.

All functions run INSIDE shard_map over ``axis_name``.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compat import pcast_varying


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, axis_name):
    """Run ``stage_fn(params, h) -> h`` over p pipeline stages.

    stage_params: this device's stage's params (layers for my stage).
    x_micro: (n_micro, mb, ...) microbatched input, REPLICATED across the
    pipeline axis (every stage sees the stream; only stage 0's injection
    matters).  Returns (n_micro, mb, ...) outputs valid on the LAST stage
    (replicated back via ppermute broadcast at the end).
    """
    p = lax.psum(1, axis_name)
    d = lax.axis_index(axis_name)
    n_micro = x_micro.shape[0]
    ticks = n_micro + p - 1
    mb_shape = x_micro.shape[1:]

    fwd_perm = [(r, (r + 1) % p) for r in range(p)]

    def tick(carry, t):
        recv, outs = carry
        # stage 0 injects microbatch t (if in range); others take recv
        mb_idx = jnp.clip(t, 0, n_micro - 1)
        inject = x_micro[mb_idx]
        h_in = jnp.where(d == 0, inject, recv)
        h_out = stage_fn(stage_params, h_in)
        # last stage writes its result for microbatch t - (p - 1)
        out_idx = t - (p - 1)
        do_write = (d == p - 1) & (out_idx >= 0)
        w_idx = (jnp.clip(out_idx, 0, n_micro - 1),) \
            + (0,) * len(mb_shape)
        old = lax.dynamic_slice(outs, w_idx, (1,) + mb_shape)
        new = jnp.where(do_write, h_out[None], old)
        outs = lax.dynamic_update_slice(outs, new, w_idx)
        recv_next = lax.ppermute(h_out, axis_name, fwd_perm)
        return (recv_next, outs), None

    outs0 = jnp.zeros((n_micro,) + mb_shape, x_micro.dtype)
    recv0 = jnp.zeros(mb_shape, x_micro.dtype)
    recv0 = pcast_varying(recv0, axis_name)
    outs0 = pcast_varying(outs0, axis_name)
    (_, outs), _ = lax.scan(tick, (recv0, outs0), jnp.arange(ticks))
    # broadcast final outputs from the last stage to all stages (masked
    # psum — ppermute can't fan out one source to many destinations)
    outs = lax.psum(jnp.where(d == p - 1, outs, 0), axis_name)
    return outs


def stage_slice(stacked_params, axis_name, n_layers_total: int):
    """Split a (L, ...) stacked param tree into this device's stage:
    (L/p, ...) via dynamic_slice on the layer dim."""
    p = lax.psum(1, axis_name)
    d = lax.axis_index(axis_name)
    per = n_layers_total // p

    def sl(x):
        start = (d * per,) + (0,) * (x.ndim - 1)
        return lax.dynamic_slice(x, start, (per,) + x.shape[1:])

    return jax.tree.map(sl, stacked_params)

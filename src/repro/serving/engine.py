"""Serving: LM prefill/decode entry points + the TabletSA scan service.

The scan service reproduces the paper's §V experiment shape (batched
random-pattern scans) and adds the production feature the paper's Table IV
is begging for: **hedged reads** over tablet replicas.  The paper measured
a max reply of 771 ms against a 5.3 ms mean — a 145x tail.  With replicas
and a backup request fired at the p95 deadline, the tail collapses to
~max(primary, backup-after-deadline); the service simulates per-replica
latency (lognormal body + pareto tail) around the measured TPU batch step
time and reports the same statistics as Tables III/IV.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.planner import ScanPlanner
from repro.core.tablet import TabletStore
from repro.models import decode_step, prefill
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# LM serving
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0         # 0 = greedy


def make_prefill_fn(cfg: ModelConfig, serve: ServeConfig, shard=None):
    shard_fn = shard if shard is not None else (lambda x, _n: x)

    @jax.jit
    def fn(params, batch):
        return prefill(cfg, params, batch, max_len=serve.max_len,
                       shard=shard_fn)

    return fn


def make_decode_fn(cfg: ModelConfig, shard=None):
    shard_fn = shard if shard is not None else (lambda x, _n: x)

    @jax.jit
    def fn(params, tokens, caches):
        return decode_step(cfg, params, tokens, caches, shard=shard_fn)

    return fn


def greedy_generate(cfg: ModelConfig, params, batch, num_steps: int,
                    serve: Optional[ServeConfig] = None):
    """Greedy generation loop (examples / integration tests)."""
    serve = serve or ServeConfig()
    logits, caches = prefill(cfg, params, batch, max_len=serve.max_len)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(num_steps - 1):
        logits, caches = decode_step(cfg, params, tok, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# TabletSA scan service with hedged reads (straggler mitigation)
# ---------------------------------------------------------------------------
def _safe_corr(a: np.ndarray, b: np.ndarray) -> float:
    """Pearson correlation, defined as 0.0 when either column has zero
    variance (hit rate exactly 0.0 or 1.0 made np.corrcoef emit NaN)."""
    if len(a) < 2 or float(a.std()) == 0.0 or float(b.std()) == 0.0:
        return 0.0
    return float(np.corrcoef(a, b)[0, 1])


@dataclasses.dataclass
class HedgedScanService:
    """A replica/hedging POLICY on top of the client frontend.

    Since the client API redesign this service owns no scan execution:
    every batch becomes a typed raw-codes :class:`repro.api.Query`
    dispatched through a :class:`repro.api.Database` handle, which
    routes by table name and coalesces with any other caller sharing
    the handle (pass ``database=`` to share one).  What remains here is
    the serving *policy* the paper's Table IV begs for — replicas,
    simulated per-replica latency, and hedged backup requests.

    ``table`` is the :class:`repro.api.SuffixTable` being served; reads
    go through the table's merged LSM path, so appended-but-uncompacted
    data is visible with exact counts.  A bare :class:`TabletStore` is
    still accepted (deprecation shim) and wrapped in an in-memory table.

    ``replicas`` tablet-store replicas serve every scan batch;
    per-request replica latency = base_ms * lognormal(sigma) with a
    pareto tail of probability tail_p and scale tail_scale (the paper's
    771 ms events).  A backup request fires after ``hedge_deadline_ms``;
    effective latency is min(primary, deadline + backup).  Scan RESULTS
    come from the real engine; only latency is simulated (no real
    multi-machine here) — UNLESS the served table is a
    :class:`~repro.serving.router.RemoteTable`: then the hedge is a real
    second RPC to a different worker process (the router's replica
    machinery — ``hedged=`` toggles it per call) and the reported
    latency is the real measured wall time of the routed batch, so the
    same Table III/IV statistics compare simulated and genuine hedging.
    """
    table: "object"                  # SuffixTable | TabletStore (shim)
    replicas: int = 2
    base_ms: float = 5.0
    sigma: float = 0.35
    tail_p: float = 0.002
    tail_scale_ms: float = 300.0
    hedge_deadline_ms: float = 15.0
    seed: int = 0
    planner: Optional[ScanPlanner] = None
    database: Optional["object"] = None      # repro.api.Database

    def __post_init__(self):
        from repro.api import Database
        from repro.api.table import SuffixTable
        self.is_remote = bool(getattr(self.table, "is_remote", False))
        if isinstance(self.table, TabletStore):
            self.table = SuffixTable.from_store(self.table,
                                                planner=self.planner)
        if self.planner is None and not self.is_remote:
            self.planner = self.table.planner
        if self.database is None:
            self.database = Database.in_memory()
        self.table_name = self.database.ensure_attached(self.table)
        # private generator (not a dataclass field): repeated workloads are
        # reproducible per service instance, and scan() no longer mutates
        # the dataclass's compare-by-value state (the old `self.seed += 1`)
        self._rng = np.random.default_rng(self.seed)

    @property
    def store(self) -> TabletStore:
        """The served table's base store (back-compat accessor)."""
        return self.table.store

    def _latency(self, rng, n) -> np.ndarray:
        lat = self.base_ms * rng.lognormal(0.0, self.sigma, size=n)
        tail = rng.random(n) < self.tail_p
        lat = lat + np.where(tail,
                             rng.pareto(1.5, size=n) * self.tail_scale_ms, 0)
        return lat

    def scan(self, patterns_packed, plen, hedged: bool = True):
        """Returns (QueryResult, latency_ms per query).  The batch rides
        a typed raw-codes Query through the client (bucket-padded jitted
        planner invocation, sentinel retry, merged LSM tiers)."""
        import time as _time

        from repro.api import Query
        q = Query(table=self.table_name, kind="scan",
                  codes=np.asarray(patterns_packed), lens=np.asarray(plen))
        n = int(np.asarray(plen).shape[0])
        if self.is_remote:
            # real plane: toggle the router's genuine hedging per call
            # and report measured wall latency (every query of the batch
            # experienced the same routed dispatch)
            router = self.table.router
            prev = router.hedge_enabled
            router.hedge_enabled = bool(hedged)
            try:
                t0 = _time.perf_counter()
                res = self.database.query(q)
                wall_ms = (_time.perf_counter() - t0) * 1e3
            finally:
                router.hedge_enabled = prev
            if not res.ok:
                raise RuntimeError(f"scan failed: {res.error}")
            return res, np.full(n, wall_ms)
        res = self.database.query(q)
        if not res.ok:
            raise RuntimeError(f"scan failed: {res.error}")
        rng = self._rng
        primary = self._latency(rng, n)
        if not hedged or self.replicas < 2:
            return res, primary
        backup = self._latency(rng, n)
        hedged_lat = np.minimum(primary,
                                self.hedge_deadline_ms + backup)
        return res, hedged_lat

    def run_workload(self, num_queries: int, batch: int = 1024,
                     min_len: int = 1, max_len: int = 100,
                     hedged: bool = True, seed: int = 0):
        """The paper's §V workload: random patterns, uniform length.
        Returns dict of Table III/IV statistics.

        ``max_len`` is validated against the served table's pattern cap
        up front — the planner rejects over-cap patterns per batch, so an
        invalid workload would otherwise crash midway with partial work
        done and an opaque traceback."""
        cap = int(self.planner.max_pattern_len if self.planner is not None
                  else self.table.max_query_len)
        if max_len > cap:
            raise ValueError(
                f"run_workload max_len={max_len} exceeds the table's "
                f"pattern cap {cap} (its max_query_len); clamp max_len "
                f"or rebuild the table with a larger max_query_len")
        if not 1 <= min_len <= max_len:
            raise ValueError(f"need 1 <= min_len <= max_len, got "
                             f"min_len={min_len} max_len={max_len}")
        lat_all, out_all, len_all = [], [], []
        done = 0
        b = 0
        while done < num_queries:
            take = min(batch, num_queries - done)
            # random_patterns takes an int seed; derive a distinct stream
            # per batch instead of passing an ad-hoc tuple
            pats = Q.random_patterns(take, min_len, max_len,
                                     seed=seed * 100_003 + b)
            _, pp, pl = Q.encode_patterns(
                pats, ((max_len + 15) // 16) * 16)
            res, lat = self.scan(pp, pl, hedged=hedged)
            lat_all.append(lat)
            out_all.append(np.asarray(res.found))
            len_all.append(np.asarray(pl))
            done += take
            b += 1
        if not lat_all:            # num_queries == 0: well-defined zeros
            z = 0.0
            return {"n": 0, "mean_ms": z, "sd_ms": z, "min_ms": z,
                    "max_ms": z, "p99_ms": z, "hit_rate": z, "mean_len": z,
                    "corr_len_time": z, "corr_len_outcome": z}
        lat = np.concatenate(lat_all)
        out = np.concatenate(out_all)
        ln = np.concatenate(len_all)
        return {
            "n": len(lat),
            "mean_ms": float(lat.mean()), "sd_ms": float(lat.std()),
            "min_ms": float(lat.min()), "max_ms": float(lat.max()),
            "p99_ms": float(np.percentile(lat, 99)),
            "hit_rate": float(out.mean()),
            "mean_len": float(ln.mean()),
            "corr_len_time": _safe_corr(ln, lat),
            "corr_len_outcome": _safe_corr(ln, out.astype(float)),
        }

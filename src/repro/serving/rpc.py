"""Length-prefixed framed RPC over local sockets — the serving plane's wire.

Bigtable tablet servers speak a thin RPC protocol to the client library;
this module is that layer scaled to one machine and zero new
dependencies: numpy arrays and JSON over ``AF_UNIX`` stream sockets.

Frame layout (little-endian)::

    u32 frame_len | u32 header_len | header JSON | buffer 0 | buffer 1 ...

The header is an ordinary JSON object; any top-level numpy-array value
of the message is lifted out of the JSON and shipped as a raw buffer,
described in the header's ``__arrays__`` list as ``[key, dtype, shape]``
in buffer order.  Decoding reverses the lift, so both ends see one flat
``dict`` with real ``np.ndarray`` values — no base64, no pickling, no
copy beyond the socket itself.

* :class:`RpcServer` — thread-per-connection server with a **bounded
  inflight gate**: at most ``max_inflight`` requests may be queued or
  executing; request number ``max_inflight + 1`` is answered immediately
  with ``{"status": "overloaded"}`` instead of queueing unboundedly
  (the worker half of the plane's admission control — the router half
  lives in ``repro.serving.router``).
* :class:`RpcClient` — thread-safe client with a small connection pool;
  concurrent calls each hold a pooled connection exclusively, so a
  hedged backup request never interleaves frames with the primary.

Everything here is numpy-only on purpose: tablet worker processes import
this without jax (see ``repro.serving.tablet_server``).
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
from typing import Callable, Optional

import numpy as np

_LEN = struct.Struct("<I")
# one frame must hold a whole coalesced batch of patterns (or a full
# locate enumeration); 256 MiB is orders of magnitude above either while
# still rejecting a corrupt length prefix before it allocates the moon
MAX_FRAME = 256 << 20


class RpcError(RuntimeError):
    """Transport-level failure: connect/send/recv on a dead endpoint."""


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------
def encode_message(msg: dict) -> bytes:
    """One frame.  Top-level ndarray values ride as raw buffers."""
    header: dict = {}
    arrays: list = []
    buffers: list[bytes] = []
    for key, value in msg.items():
        if isinstance(value, np.ndarray):
            arr = np.ascontiguousarray(value)
            arrays.append([key, arr.dtype.str, list(arr.shape)])
            buffers.append(arr.tobytes())
        else:
            header[key] = value
    header["__arrays__"] = arrays
    hdr = json.dumps(header, separators=(",", ":")).encode("utf-8")
    body = b"".join([_LEN.pack(len(hdr)), hdr] + buffers)
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame of {len(body)} bytes exceeds MAX_FRAME")
    return _LEN.pack(len(body)) + body


def decode_message(body: bytes) -> dict:
    """Inverse of :func:`encode_message`."""
    (hdr_len,) = _LEN.unpack_from(body, 0)
    off = _LEN.size
    header = json.loads(body[off:off + hdr_len].decode("utf-8"))
    off += hdr_len
    msg = {k: v for k, v in header.items() if k != "__arrays__"}
    for key, dtype, shape in header.get("__arrays__", []):
        dt = np.dtype(dtype)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = n * dt.itemsize
        arr = np.frombuffer(body[off:off + nbytes], dtype=dt)
        msg[key] = arr.reshape(shape).copy()
        off += nbytes
    return msg


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise RpcError("connection closed mid-frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_message(sock: socket.socket, msg: dict) -> None:
    try:
        sock.sendall(encode_message(msg))
    except OSError as e:
        raise RpcError(f"send failed: {e}") from e


def recv_message(sock: socket.socket) -> dict:
    try:
        (frame_len,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
        if frame_len > MAX_FRAME:
            raise RpcError(f"frame length {frame_len} exceeds MAX_FRAME")
        return decode_message(_recv_exact(sock, frame_len))
    except OSError as e:
        raise RpcError(f"recv failed: {e}") from e


def overloaded_response(queue_depth: int) -> dict:
    """The typed shed result (docs/serving_plane.md, admission control)."""
    return {"status": "overloaded", "queue_depth": int(queue_depth)}


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------
class RpcServer:
    """Unix-socket server: one thread per connection, bounded inflight.

    ``handler(msg) -> dict`` runs every admitted request; a request
    arriving while ``max_inflight`` others are queued or executing is
    shed with :func:`overloaded_response` WITHOUT running the handler —
    the bounded per-worker queue the plane's backpressure contract
    promises.  ``stats_hook`` (optional) observes ``(op, service_ms,
    shed)`` per request for the worker's metrics feed.
    """

    def __init__(self, path: str, handler: Callable[[dict], dict], *,
                 max_inflight: int = 8,
                 stats_hook: Optional[Callable] = None):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {max_inflight}")
        self.path = path
        self.handler = handler
        self.max_inflight = int(max_inflight)
        self.stats_hook = stats_hook
        self._inflight = 0
        self._shed = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        if os.path.exists(path):
            os.unlink(path)
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(path)
        self._listener.listen(64)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="rpc-accept", daemon=True)
        self._accept_thread.start()

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return self._inflight

    @property
    def shed_count(self) -> int:
        with self._lock:
            return self._shed

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return                       # listener closed by stop()
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="rpc-conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        import time
        try:
            while not self._stop.is_set():
                try:
                    msg = recv_message(conn)
                except RpcError:
                    return                   # client went away
                with self._lock:
                    if self._inflight >= self.max_inflight:
                        self._shed += 1
                        depth = self._inflight
                        admitted = False
                    else:
                        self._inflight += 1
                        admitted = True
                if not admitted:
                    if self.stats_hook is not None:
                        self.stats_hook(msg.get("op", "?"), 0.0, True)
                    send_message(conn, overloaded_response(depth))
                    continue
                t0 = time.perf_counter()
                try:
                    try:
                        reply = self.handler(msg)
                    except Exception as e:  # noqa: BLE001 — reply, don't die
                        reply = {"status": "error",
                                 "error": f"{type(e).__name__}: {e}"}
                finally:
                    with self._lock:
                        self._inflight -= 1
                if self.stats_hook is not None:
                    self.stats_hook(msg.get("op", "?"),
                                    (time.perf_counter() - t0) * 1e3, False)
                send_message(conn, reply)
        finally:
            conn.close()

    def stop(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        if os.path.exists(self.path):
            try:
                os.unlink(self.path)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------
class RpcClient:
    """Thread-safe client for one endpoint, with connection pooling.

    Each :meth:`call` holds one pooled connection exclusively for its
    whole request/response exchange, so concurrent callers (the router's
    fan-out threads, a hedged backup) never interleave frames.  A failed
    exchange closes its connection; the next call dials fresh.
    """

    def __init__(self, path: str, *, timeout: float = 30.0,
                 pool_size: int = 8):
        self.path = path
        self.timeout = float(timeout)
        self.pool_size = int(pool_size)
        self._pool: list[socket.socket] = []
        self._lock = threading.Lock()
        self._closed = False

    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._closed:
                raise RpcError(f"client for {self.path} is closed")
            if self._pool:
                return self._pool.pop()
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(self.timeout)
        try:
            sock.connect(self.path)
        except OSError as e:
            sock.close()
            raise RpcError(f"connect to {self.path} failed: {e}") from e
        return sock

    def _checkin(self, sock: socket.socket) -> None:
        with self._lock:
            if not self._closed and len(self._pool) < self.pool_size:
                self._pool.append(sock)
                return
        sock.close()

    def call(self, msg: dict, *, timeout: Optional[float] = None) -> dict:
        """One request/response exchange; raises :class:`RpcError` on
        any transport failure (the router treats that as a dead replica
        and fails over)."""
        sock = self._checkout()
        try:
            if timeout is not None:
                sock.settimeout(timeout)
            send_message(sock, msg)
            reply = recv_message(sock)
        except (RpcError, OSError) as e:
            sock.close()
            if isinstance(e, RpcError):
                raise
            raise RpcError(f"call to {self.path} failed: {e}") from e
        if timeout is not None:
            sock.settimeout(self.timeout)
        self._checkin(sock)
        return reply

    def ping(self, *, timeout: float = 1.0) -> bool:
        try:
            return self.call({"op": "ping"},
                             timeout=timeout).get("status") == "ok"
        except RpcError:
            return False

    def close(self) -> None:
        with self._lock:
            self._closed = True
            pool, self._pool = self._pool, []
        for sock in pool:
            sock.close()

"""Tablet router — the client half of the serving plane.

Bigtable clients cache the METADATA tablet map, send each read straight
to the tablet server owning its row range, and merge.  This module is
that client: :class:`TabletRouter` routes every pattern to the tablets
whose rank-key range can contain it (docs/serving_plane.md has the
range math), fans the per-tablet RPCs out concurrently, and merges the
replies into exactly the result a single-process ``SuffixTable`` would
return.  :class:`RemoteTable` wraps a router in the ``SuffixTable`` scan
surface (``scan`` / ``scan_batch`` / ``locate_range``), so the existing
``Database`` / ``QueryScheduler`` / ``ReadSession`` frontend drives a
multi-process deployment unchanged.

Reliability semantics, in router order:

* **admission** — per-tenant :class:`TokenBucket` quotas are charged
  BEFORE any RPC leaves the process (``admit``); an over-quota tenant is
  shed locally with the typed ``OVERLOADED`` result, costing the plane
  nothing;
* **hedging** — with ``hedge_enabled`` and a replica available, a
  request still unanswered after ``hedge_deadline_ms`` fires a backup
  RPC to a different process; first success wins, the loser's reply is
  discarded (each call holds its own pooled connection, so a late loser
  can never corrupt a later exchange);
* **failover** — a dead or shedding replica (``RpcError`` / worker
  ``overloaded``) falls through to the next replica; only when every
  replica of some needed tablet sheds does the caller see
  :class:`OverloadedError`.

Numpy-only on purpose (no jax import): bench client processes and tests
route without paying the accelerator runtime startup.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.serving import rpc
from repro.serving.metrics import LatencyWindow, MetricsEmitter
from repro.serving.tablet_server import encode_pattern_rows
from repro.serving.trace import Tracer


class OverloadedError(RuntimeError):
    """Every replica of a needed tablet shed the request (or the tenant
    is over quota).  The message starts with ``OVERLOADED`` so the typed
    marker survives the trip through a ``QueryResult.error`` string."""

    def __init__(self, detail: str):
        super().__init__(f"OVERLOADED: {detail}")


class TokenBucket:
    """Per-tenant admission quota: ``rate_per_s`` sustained, ``burst``
    peak.  ``try_acquire(n)`` charges n patterns and answers whether the
    tenant is inside its quota — it never blocks (shedding beats
    queueing; the caller turns False into an ``OVERLOADED`` result)."""

    def __init__(self, rate_per_s: float, burst: float):
        if rate_per_s <= 0 or burst <= 0:
            raise ValueError("rate_per_s and burst must be > 0")
        self.rate = float(rate_per_s)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._t_last = time.monotonic()
        self._lock = threading.Lock()

    def try_acquire(self, n: float = 1.0) -> bool:
        with self._lock:
            now = time.monotonic()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t_last) * self.rate)
            self._t_last = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


def _unpack_2bit(words: np.ndarray) -> np.ndarray:
    """(B, W) packed uint32 DNA words -> (B, 16 W) int32 code rows —
    the numpy mirror of ``codec.unpack_2bit_batch`` (same big-endian
    layout: base i of a word at bit 30−2i), kept here so the router
    never imports the jax-backed codec module."""
    words = np.asarray(words, dtype=np.uint32)
    shifts = (30 - 2 * np.arange(16)).astype(np.uint32)
    lanes = (words[:, :, None] >> shifts[None, None, :]) & np.uint32(3)
    return lanes.reshape(words.shape[0], -1).astype(np.int32)


class _Overloaded(Exception):
    """Internal: one replica shed; the router may still fail over."""


class TabletRouter:
    """Routes pattern batches across tablet workers and merges replies.

    ``manifest`` is the table's ``tablets/manifest.json`` dict;
    ``endpoints`` is ``[[sock, sock, ...], ...]`` — one socket list per
    tablet, replica 0 first (the ``tablets/serving.json`` layout
    :func:`repro.serving.plane.ServingPlane` writes).
    """

    def __init__(self, manifest: dict, endpoints: Sequence[Sequence[str]], *,
                 hedge_deadline_ms: float = 50.0, hedge_enabled: bool = True,
                 rpc_timeout_s: float = 30.0,
                 metrics_path: Optional[str] = None,
                 metrics_interval_s: float = 0.0):
        if len(endpoints) != manifest["n_tablets"]:
            raise ValueError(
                f"manifest has {manifest['n_tablets']} tablets but "
                f"{len(endpoints)} endpoint lists were given")
        self.manifest = manifest
        self.n_tablets = int(manifest["n_tablets"])
        self.owner = self.n_tablets - 1      # delta-owner tablet
        # split keys: tablet i serves suffixes in [key_i, key_{i+1});
        # key_0 is implicitly -inf, key_{n} +inf
        self._keys = [np.asarray(t["key"], np.int32)
                      for t in manifest["tablets"]]
        self._clients = [[rpc.RpcClient(p, timeout=rpc_timeout_s)
                          for p in reps] for reps in endpoints]
        self.hedge_deadline_ms = float(hedge_deadline_ms)
        self.hedge_enabled = bool(hedge_enabled)
        # separate pools: fan-out tasks block on hedge futures, so they
        # must never compete for the same worker slots (deadlock)
        self._fanout = cf.ThreadPoolExecutor(
            max_workers=max(8, 2 * self.n_tablets),
            thread_name_prefix="router-fanout")
        max_reps = max(len(r) for r in endpoints)
        self._hedge = cf.ThreadPoolExecutor(
            max_workers=max(8, 4 * self.n_tablets * max_reps),
            thread_name_prefix="router-hedge")
        self._stats_lock = threading.Lock()
        self.hedge_fired = 0
        self.hedge_wins = 0
        self.failovers = 0
        self.quota_shed = 0
        self.rpcs = 0
        self._latency = LatencyWindow()
        # span histograms (stats()["latency"]): dispatch_remote (one
        # logical tablet read: hedge + failover walk) and hedge_wait
        # (hedge fired -> first success) — docs/observability.md
        self.tracer = Tracer()
        self._quotas: dict[str, TokenBucket] = {}
        self.emitter = None
        if metrics_path is not None:
            self.emitter = MetricsEmitter(metrics_path, self.stats,
                                          interval_s=metrics_interval_s)

    # -- admission (the quota half; the worker holds the queue half) ---------
    def set_quota(self, tenant: str, rate_per_s: float,
                  burst: Optional[float] = None) -> None:
        """Cap ``tenant`` at ``rate_per_s`` patterns/s (peak ``burst``,
        default 2x the rate).  Tenants without a quota are unmetered."""
        self._quotas[str(tenant)] = TokenBucket(
            rate_per_s, burst if burst is not None else 2.0 * rate_per_s)

    def admit(self, tenant: Optional[str], n_patterns: int) -> bool:
        """Charge ``tenant`` for ``n_patterns``; False = shed locally."""
        if tenant is None:
            return True
        bucket = self._quotas.get(str(tenant))
        if bucket is None or bucket.try_acquire(n_patterns):
            return True
        with self._stats_lock:
            self.quota_shed += n_patterns
        return False

    # -- tablet RPC with hedging + failover ----------------------------------
    def _try_replica(self, tid: int, rep: int, msg: dict) -> dict:
        reply = self._clients[tid][rep].call(msg)
        status = reply.get("status")
        if status == "overloaded":
            raise _Overloaded(
                f"tablet {tid} replica {rep} queue at "
                f"{reply.get('queue_depth')}")
        if status != "ok":
            raise rpc.RpcError(
                f"tablet {tid} replica {rep}: {reply.get('error')}")
        return reply

    def _call_tablet(self, tid: int, msg: dict) -> dict:
        """One logical tablet read: hedge across replicas, fail over on
        transport errors and worker sheds, raise only when every replica
        is gone (RpcError) or shedding (OverloadedError).  The whole
        walk is one ``dispatch_remote`` span (recorded on error too)."""
        with self.tracer.span("dispatch_remote"):
            return self._call_tablet_inner(tid, msg)

    def _call_tablet_inner(self, tid: int, msg: dict) -> dict:
        with self._stats_lock:
            self.rpcs += 1
        clients = self._clients[tid]
        if self.hedge_enabled and len(clients) > 1:
            reply = self._call_hedged(tid, msg)
            if reply is not None:
                return reply
        # serial failover walk (also the hedged path's last resort)
        overloads, last_err = 0, None
        for rep in range(len(clients)):
            try:
                reply = self._try_replica(tid, rep, msg)
                if rep > 0:
                    with self._stats_lock:
                        self.failovers += 1
                return reply
            except _Overloaded as e:
                overloads += 1
                last_err = e
            except rpc.RpcError as e:
                last_err = e
        if overloads:
            raise OverloadedError(f"all {len(clients)} replicas of tablet "
                                  f"{tid} shed ({last_err})")
        raise rpc.RpcError(f"every replica of tablet {tid} failed: "
                           f"{last_err}")

    def _call_hedged(self, tid: int, msg: dict) -> Optional[dict]:
        """Primary + (after ``hedge_deadline_ms``) one backup on a
        different replica; first success wins.  ``None`` means both
        attempts died and the caller should walk the failover path."""
        primary = self._hedge.submit(self._try_replica, tid, 0, msg)
        try:
            return primary.result(timeout=self.hedge_deadline_ms / 1e3)
        except cf.TimeoutError:
            pass
        except (_Overloaded, rpc.RpcError):
            return None                    # fast failure: no hedge needed
        with self._stats_lock:
            self.hedge_fired += 1
        with self.tracer.span("hedge_wait"):
            backup = self._hedge.submit(self._try_replica, tid, 1, msg)
            pending = {primary, backup}
            while pending:
                done, pending = cf.wait(pending,
                                        return_when=cf.FIRST_COMPLETED)
                for fut in done:
                    try:
                        reply = fut.result()
                    except (_Overloaded, rpc.RpcError):
                        continue
                    if fut is backup:
                        with self._stats_lock:
                            self.hedge_wins += 1
                    return reply           # loser's reply is discarded
            return None

    # -- routing -------------------------------------------------------------
    def _prefix_cmp(self, row: np.ndarray, length: int,
                    key: np.ndarray) -> int:
        """Compare pattern prefix to a split key over their common
        depth: −1 / +1 on the first differing symbol, 0 when one is a
        prefix of the other (ambiguous — the pattern's rank range may
        straddle this boundary, so the caller must include both sides)."""
        m = min(int(length), int(key.shape[0]))
        a, b = row[:m], key[:m]
        neq = np.flatnonzero(a != b)
        if neq.size == 0:
            return 0
        j = int(neq[0])
        return -1 if int(a[j]) < int(b[j]) else 1

    def candidates(self, row: np.ndarray, length: int) -> list[int]:
        """Tablets whose rank range can hold suffixes starting with this
        pattern.  Sound by construction: a tablet is EXCLUDED only when
        the whole pattern range provably sorts outside its key range
        (strict prefix compare), so no occurrence can be missed — an
        over-included tablet just answers zero."""
        out = []
        for tid in range(self.n_tablets):
            if tid > 0 and self._prefix_cmp(row, length,
                                            self._keys[tid]) < 0:
                continue               # every p-suffix sorts before tablet
            if tid + 1 < self.n_tablets and \
                    self._prefix_cmp(row, length, self._keys[tid + 1]) > 0:
                continue               # every p-suffix sorts after tablet
            out.append(tid)
        return out

    # -- the merged scan ------------------------------------------------------
    def scan_rows(self, rows: np.ndarray, lens: np.ndarray,
                  top_k: int = 0) -> dict:
        """Scan a decoded (B, L) int32 batch across the plane and merge
        to single-process semantics: count = Σ per-tablet counts (+ the
        owner's delta count), first_pos = min, positions = ascending
        top-k of the union (docs/serving_plane.md proves each)."""
        t0 = time.perf_counter()
        rows = np.ascontiguousarray(rows).astype(np.int32)
        lens = np.asarray(lens).astype(np.int64)
        B = rows.shape[0]
        per_tablet: dict[int, list[int]] = {}
        for i in range(B):
            for tid in self.candidates(rows[i], int(lens[i])):
                per_tablet.setdefault(tid, []).append(i)
        futures = {}
        for tid in range(self.n_tablets):
            idx = per_tablet.get(tid, [])
            if not idx and tid != self.owner:
                continue
            msg: dict = {"op": "scan", "top_k": int(top_k)}
            if idx:
                sub = np.asarray(idx, np.int64)
                msg["rows"] = rows[sub]
                msg["lens"] = lens[sub]
            if tid == self.owner:
                # the delta tier is unpartitioned: its owner always sees
                # the full batch (delta-empty planes short-circuit it)
                msg["drows"] = rows
                msg["dlens"] = lens
            futures[tid] = (self._fanout.submit(self._call_tablet, tid,
                                                msg),
                            per_tablet.get(tid, []))
        count = np.zeros(B, np.int64)
        first = np.full(B, -1, np.int64)
        parts: list[list[np.ndarray]] = [[] for _ in range(B)]
        for tid, (fut, idx) in futures.items():
            reply = fut.result()
            if idx:
                sub = np.asarray(idx, np.int64)
                self._merge_rows(count, first, parts, sub,
                                 reply["count"], reply["first_pos"],
                                 reply.get("positions"), top_k)
            if tid == self.owner and "dcount" in reply:
                all_rows = np.arange(B, dtype=np.int64)
                self._merge_rows(count, first, parts, all_rows,
                                 reply["dcount"], reply["dfirst_pos"],
                                 reply.get("dpositions"), top_k)
        positions = None
        if top_k:
            positions = np.full((B, top_k), -1, np.int64)
            for i in range(B):
                if parts[i]:
                    cand = np.concatenate(parts[i])
                    cand = cand[cand >= 0]
                    if cand.shape[0] > top_k:
                        cand = np.partition(cand, top_k - 1)[:top_k]
                    cand.sort()
                    positions[i, :cand.shape[0]] = cand
        self._latency.record((time.perf_counter() - t0) * 1e3)
        return {"found": count > 0, "count": count, "first_pos": first,
                "positions": positions}

    @staticmethod
    def _merge_rows(count, first, parts, idx, sub_count, sub_first,
                    sub_pos, top_k) -> None:
        count[idx] += np.asarray(sub_count, np.int64)
        sf = np.asarray(sub_first, np.int64)
        cur = first[idx]
        first[idx] = np.where(cur < 0, sf,
                              np.where(sf < 0, cur, np.minimum(cur, sf)))
        if top_k and sub_pos is not None:
            for j, i in enumerate(np.asarray(idx)):
                parts[int(i)].append(np.asarray(sub_pos[j], np.int64))

    def locate_rows(self, row: np.ndarray, length: int, *,
                    after: int = -1,
                    limit: Optional[int] = None) -> np.ndarray:
        """Merged paged enumeration of one decoded pattern row: each
        tablet returns its ascending positions ``> after`` capped at
        ``limit``; keeping the smallest ``limit`` of the union is exact
        because every tablet stream is individually complete-from-
        ``after``."""
        row = np.ascontiguousarray(row).astype(np.int32)
        msg_limit = -1 if limit is None else int(limit)
        # the owner joins even when it is not a base candidate: it may
        # still hold delta-tier occurrences of the pattern
        tablets = set(self.candidates(row, length)) | {self.owner}
        msg = {"op": "locate_range", "row": row, "len": int(length),
               "after": int(after), "limit": msg_limit}
        futures = [self._fanout.submit(self._call_tablet, tid, dict(msg))
                   for tid in sorted(tablets)]
        cands = [np.asarray(fut.result()["positions"], np.int64)
                 for fut in futures]
        cand = (np.concatenate(cands) if cands
                else np.zeros((0,), np.int64))
        cand.sort()
        if limit is not None and cand.shape[0] > limit:
            cand = cand[:limit]
        return cand

    # -- observability / lifecycle -------------------------------------------
    def stats(self) -> dict:
        with self._stats_lock:
            st = {"role": "router", "pid": os.getpid(),
                  "n_tablets": self.n_tablets, "rpcs": self.rpcs,
                  "hedge_fired": self.hedge_fired,
                  "hedge_wins": self.hedge_wins,
                  "failovers": self.failovers,
                  "quota_shed": self.quota_shed,
                  "hedge_enabled": self.hedge_enabled}
        st.update(self._latency.quantiles())
        st["latency"] = self.tracer.snapshot()
        return st

    def ping_all(self, *, timeout: float = 1.0) -> list[list[bool]]:
        return [[c.ping(timeout=timeout) for c in reps]
                for reps in self._clients]

    def close(self) -> None:
        if self.emitter is not None:
            self.emitter.stop()
        self._fanout.shutdown(wait=False)
        self._hedge.shutdown(wait=False)
        for reps in self._clients:
            for c in reps:
                c.close()


# ---------------------------------------------------------------------------
# the SuffixTable-shaped facade
# ---------------------------------------------------------------------------
class _RemoteOutcome:
    """Duck-typed ``ScanOutcome`` (found/count/first_pos/positions) —
    defined here so the router stack never imports the jax-backed
    planner module."""

    __slots__ = ("found", "count", "first_pos", "positions")

    def __init__(self, found, count, first_pos, positions):
        self.found = found
        self.count = count
        self.first_pos = first_pos
        self.positions = positions


class RemoteTable:
    """A ``SuffixTable``-shaped handle served by the tablet plane.

    Attach one to a :class:`repro.api.client.Database` (or let
    ``Database.connect_plane`` do it) and the whole typed frontend —
    ``Query`` kinds, coalescing, ``ReadSession`` paging — runs against
    the multi-process deployment unchanged.  Read-only: the plane serves
    a frozen snapshot + WAL tail, so there is no append path and
    ``write_generation`` is constant.

    ``supports_concurrent_scans`` tells the ``QueryScheduler`` NOT to
    serialize dispatches to this table: concurrency here IS the point
    (each dispatch fans out to different worker processes), and the
    single-table lock that protects an in-process table's tier view
    would re-serialize the plane back to one-worker throughput.
    """

    is_remote = True
    supports_concurrent_scans = True
    write_generation = 0

    def __init__(self, router: TabletRouter, *, name: str, is_dna: bool,
                 max_query_len: int):
        self.router = router
        self.name = name
        self.is_dna = bool(is_dna)
        self.max_query_len = int(max_query_len)

    @classmethod
    def from_manifest(cls, router: TabletRouter) -> "RemoteTable":
        m = router.manifest
        return cls(router, name=m["table"], is_dna=bool(m["is_dna"]),
                   max_query_len=int(m["max_query_len"]))

    # -- admission hook consulted by the QueryScheduler ----------------------
    def admit(self, tenant: Optional[str], n_patterns: int) -> bool:
        return self.router.admit(tenant, n_patterns)

    # -- the scan surface ----------------------------------------------------
    def _check_lens(self, lens: np.ndarray) -> None:
        if lens.size and int(lens.max()) > self.max_query_len:
            raise ValueError(
                f"pattern of length {int(lens.max())} exceeds "
                f"max_query_len={self.max_query_len}; compares are "
                f"depth-capped, so it would be silently truncated")

    def scan(self, patterns: list[str], top_k: int = 0) -> _RemoteOutcome:
        rows, lens = encode_pattern_rows(list(patterns))
        self._check_lens(lens)
        out = self.router.scan_rows(rows, lens, top_k=top_k)
        return _RemoteOutcome(out["found"], out["count"],
                              out["first_pos"], out["positions"])

    def scan_batch(self, patt, plen, top_k: int = 0) -> _RemoteOutcome:
        """Encoded-batch scan: packed uint32 DNA words (the planner's
        DNA encoding) are unpacked host-side; int32 code rows pass
        through."""
        patt = np.asarray(patt)
        lens = np.asarray(plen).astype(np.int64)
        self._check_lens(lens)
        rows = (_unpack_2bit(patt) if patt.dtype == np.uint32
                else patt.astype(np.int32))
        out = self.router.scan_rows(rows, lens, top_k=top_k)
        return _RemoteOutcome(out["found"], out["count"],
                              out["first_pos"], out["positions"])

    def locate_range(self, pattern: str, *, after: int = -1,
                     limit: Optional[int] = 256) -> np.ndarray:
        if limit is not None and limit <= 0:
            raise ValueError(f"limit must be positive, got {limit}")
        rows, lens = encode_pattern_rows([pattern])
        self._check_lens(lens)
        return self.router.locate_rows(rows[0], int(lens[0]),
                                       after=after, limit=limit)

    def count(self, patterns: list[str]) -> np.ndarray:
        return self.scan(list(patterns)).count

    def contains(self, patterns: list[str]) -> np.ndarray:
        return self.scan(list(patterns)).found

    def locate(self, patterns: list[str], top_k: int = 8) -> np.ndarray:
        return self.scan(list(patterns), top_k=top_k).positions

    def stats(self) -> dict:
        return {"name": self.name, "remote": True,
                "is_dna": self.is_dna,
                "max_query_len": self.max_query_len,
                "router": self.router.stats()}

    def close(self) -> None:
        self.router.close()


def connect(root: str, name: str, **router_kw) -> RemoteTable:
    """Open a served table by root/name: reads the ``tablets/`` manifest
    (METADATA) and ``serving.json`` (live endpoints) and returns a
    routed handle.  Use from any process — e.g. a second client process
    against a plane another process launched."""
    tdir = os.path.join(root, name, "tablets")
    with open(os.path.join(tdir, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(tdir, "serving.json")) as f:
        serving = json.load(f)
    router = TabletRouter(manifest, serving["endpoints"], **router_kw)
    return RemoteTable.from_manifest(router)

"""Deployment of the serving plane: tablet split + worker supervision.

``split_table`` is the Bigtable master's tablet-assignment step scaled
to one table: it cuts the table's latest published snapshot into
``n_tablets`` contiguous suffix-rank ranges, derives each boundary's
**split key** (the first ``key_len`` symbols of the boundary suffix —
what the router needs to route a pattern without consulting the SA),
and records the layout in ``root/<name>/tablets/manifest.json`` — the
METADATA tablet map, living inside the same catalog directory scheme
the ``Catalog`` already manages.

:class:`ServingPlane` is the process supervisor: it spawns one
``python -m repro.serving.tablet_server`` per (tablet, replica) —
numpy-only workers, millisecond startup — publishes the live socket
endpoints in ``tablets/serving.json``, health-checks them, and supports
kill / restart (the failover test's kill -9 path) and clean shutdown.
Sockets live in a fresh ``/tmp`` directory because ``AF_UNIX`` paths
cap at ~108 bytes — a pytest ``tmp_path`` would overflow it.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time
from typing import Optional

import numpy as np

from repro.serving.router import RemoteTable, TabletRouter
from repro.serving.tablet_server import SnapshotReader


def _latest_step(table_dir: str) -> int:
    steps = [int(d[len("step_"):]) for d in os.listdir(table_dir)
             if d.startswith("step_")
             and os.path.isdir(os.path.join(table_dir, d))]
    if not steps:
        raise FileNotFoundError(f"no published snapshot under {table_dir}")
    return max(steps)


def split_table(root: str, name: str, n_tablets: int, *,
                key_len: int = 32) -> dict:
    """Cut the table's latest snapshot into ``n_tablets`` rank ranges
    and write the ``tablets/manifest.json`` METADATA map.

    Boundary ``i`` sits at rank ``round(i * n / T)``; its split key is
    the first ``key_len`` symbols of the suffix at that rank, so the
    router can bound any pattern's rank range by prefix-comparing
    against the keys alone.  Raises on a frozen table (the FM tier has
    no suffix array to partition — split before ``freeze()``).
    """
    if n_tablets < 1:
        raise ValueError(f"n_tablets must be >= 1, got {n_tablets}")
    table_dir = os.path.join(root, name)
    step = _latest_step(table_dir)
    snap = SnapshotReader(table_dir, step)
    extra = snap.extra
    if extra.get("frozen"):
        raise RuntimeError(
            f"table {name!r} is frozen onto the FM-index: no suffix "
            f"array to range-partition — split before freeze()")
    sa = np.asarray(snap.load("sa_real")).astype(np.int64)
    codes = np.asarray(snap.load("codes"))
    n = int(sa.shape[0])
    if n_tablets > max(n, 1):
        raise ValueError(f"cannot cut {n} suffixes into {n_tablets} "
                         f"tablets")
    bounds = [round(i * n / n_tablets) for i in range(n_tablets + 1)]
    tablets = []
    for i in range(n_tablets):
        lo, hi = bounds[i], bounds[i + 1]
        g = int(sa[lo]) if lo < n else n
        key = codes[g:g + key_len].astype(int).tolist()
        tablets.append({"id": i, "rank_lo": lo, "rank_hi": hi,
                        "key": key})
    manifest = {
        "table": name,
        "step": step,
        "table_version": int(extra["version"]),
        "is_dna": bool(extra["is_dna"]),
        "max_query_len": int(extra["max_query_len"]),
        "n_base": n,
        "key_len": int(key_len),
        "n_tablets": int(n_tablets),
        "tablets": tablets,
    }
    tdir = os.path.join(table_dir, "tablets")
    os.makedirs(tdir, exist_ok=True)
    path = os.path.join(tdir, "manifest.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, path)           # readers see old map or new, never half
    return manifest


class ServingPlane:
    """Supervisor for one table's worker fleet.

    ``replicas`` is processes PER TABLET (1 = no replication).  Worker
    knobs (``max_inflight``, ``device_floor_ms``, slow-injection) are
    passed straight through to ``tablet_server`` argv.  Use as a
    context manager or call :meth:`stop`.
    """

    def __init__(self, root: str, name: str, *, replicas: int = 1,
                 max_inflight: int = 8, metrics_interval_s: float = 2.0,
                 device_floor_ms: float = 0.0,
                 inject_slow_ms: float = 0.0, inject_slow_p: float = 0.0,
                 inject_slow_replica: Optional[int] = None,
                 python: Optional[str] = None):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.root = os.path.abspath(root)
        self.name = name
        self.replicas = int(replicas)
        self.max_inflight = int(max_inflight)
        self.metrics_interval_s = float(metrics_interval_s)
        self.device_floor_ms = float(device_floor_ms)
        self.inject_slow_ms = float(inject_slow_ms)
        self.inject_slow_p = float(inject_slow_p)
        # None = every worker injects; an int restricts injection to that
        # replica index (a designated straggler victim, so fault-injection
        # benches measure the hedge path deterministically)
        self.inject_slow_replica = (None if inject_slow_replica is None
                                    else int(inject_slow_replica))
        self.python = python or sys.executable
        self.tablets_dir = os.path.join(self.root, name, "tablets")
        self.manifest_path = os.path.join(self.tablets_dir,
                                          "manifest.json")
        with open(self.manifest_path) as f:
            self.manifest = json.load(f)
        self.n_tablets = int(self.manifest["n_tablets"])
        # AF_UNIX socket paths are capped (~108 bytes): keep them short
        # and in /tmp, never under a deep pytest tmp_path
        self._sock_dir = tempfile.mkdtemp(prefix="saplane-")
        self._procs: dict[tuple[int, int], subprocess.Popen] = {}
        self._logs: list = []

    @classmethod
    def deploy(cls, root: str, name: str, n_tablets: int, *,
               key_len: int = 32, start: bool = True,
               wait: bool = True, **kw) -> "ServingPlane":
        """split + construct (+ start) in one call — the common path."""
        split_table(root, name, n_tablets, key_len=key_len)
        plane = cls(root, name, **kw)
        if start:
            plane.start(wait=wait)
        return plane

    # -- process management --------------------------------------------------
    def _sock_path(self, tablet: int, replica: int) -> str:
        return os.path.join(self._sock_dir, f"t{tablet}r{replica}.sock")

    def _spawn(self, tablet: int, replica: int) -> subprocess.Popen:
        slow_p = self.inject_slow_p
        if (self.inject_slow_replica is not None
                and replica != self.inject_slow_replica):
            slow_p = 0.0
        argv = [
            self.python, "-m", "repro.serving.tablet_server",
            "--manifest", self.manifest_path,
            "--tablet", str(tablet), "--replica", str(replica),
            "--sock", self._sock_path(tablet, replica),
            "--max-inflight", str(self.max_inflight),
            "--metrics-path", os.path.join(self.root, self.name,
                                           "metrics.jsonl"),
            "--metrics-interval", str(self.metrics_interval_s),
            "--device-floor-ms", str(self.device_floor_ms),
            "--inject-slow-ms", str(self.inject_slow_ms),
            "--inject-slow-p", str(slow_p),
            "--seed", str(1 + tablet * self.replicas + replica),
        ]
        env = dict(os.environ)
        # repro is a namespace package (no __file__); anchor on a real
        # module of it to find the src dir the workers must import from
        import repro.serving as _pkg
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(_pkg.__file__))))
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        log = open(os.path.join(
            self.tablets_dir, f"worker_t{tablet}_r{replica}.log"), "ab")
        self._logs.append(log)
        proc = subprocess.Popen(argv, stdout=log, stderr=log, env=env)
        self._procs[(tablet, replica)] = proc
        return proc

    def start(self, *, wait: bool = True,
              timeout_s: float = 30.0) -> None:
        for t in range(self.n_tablets):
            for r in range(self.replicas):
                self._spawn(t, r)
        self._write_serving()
        if wait:
            self.wait_ready(timeout_s=timeout_s)

    def _write_serving(self) -> None:
        endpoints = [[self._sock_path(t, r) for r in range(self.replicas)]
                     for t in range(self.n_tablets)]
        pids = [[self._procs[(t, r)].pid for r in range(self.replicas)]
                for t in range(self.n_tablets)]
        path = os.path.join(self.tablets_dir, "serving.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"endpoints": endpoints, "pids": pids}, f, indent=1)
        os.replace(tmp, path)

    def wait_ready(self, *, timeout_s: float = 30.0) -> None:
        from repro.serving.rpc import RpcClient
        deadline = time.monotonic() + timeout_s
        for (t, r), proc in sorted(self._procs.items()):
            client = RpcClient(self._sock_path(t, r), timeout=2.0)
            try:
                while not client.ping(timeout=1.0):
                    if proc.poll() is not None:
                        raise RuntimeError(
                            f"tablet worker t{t}r{r} exited with "
                            f"{proc.returncode} before becoming ready "
                            f"(see worker_t{t}_r{r}.log)")
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"tablet worker t{t}r{r} not ready after "
                            f"{timeout_s}s")
                    time.sleep(0.05)
            finally:
                client.close()

    def alive(self, tablet: int, replica: int = 0) -> bool:
        proc = self._procs.get((tablet, replica))
        return proc is not None and proc.poll() is None

    def pid(self, tablet: int, replica: int = 0) -> int:
        return self._procs[(tablet, replica)].pid

    def kill(self, tablet: int, replica: int = 0, *,
             sig: int = signal.SIGKILL) -> None:
        """Hard-kill one worker (the failover test's crash injection)."""
        proc = self._procs[(tablet, replica)]
        if proc.poll() is None:
            proc.send_signal(sig)
            proc.wait(timeout=10)

    def restart(self, tablet: int, replica: int = 0, *,
                wait: bool = True, timeout_s: float = 30.0) -> None:
        """Respawn one worker on its old socket path (it unlinks the
        stale socket on bind); pooled router connections to the dead
        process fail once and redial."""
        self.kill(tablet, replica, sig=signal.SIGKILL)
        self._spawn(tablet, replica)
        self._write_serving()
        if wait:
            from repro.serving.rpc import RpcClient
            client = RpcClient(self._sock_path(tablet, replica),
                               timeout=2.0)
            deadline = time.monotonic() + timeout_s
            try:
                while not client.ping(timeout=1.0):
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"restarted worker t{tablet}r{replica} not "
                            f"ready after {timeout_s}s")
                    time.sleep(0.05)
            finally:
                client.close()

    # -- client handles ------------------------------------------------------
    def endpoints(self) -> list[list[str]]:
        return [[self._sock_path(t, r) for r in range(self.replicas)]
                for t in range(self.n_tablets)]

    def router(self, **kw) -> TabletRouter:
        kw.setdefault("metrics_path",
                      os.path.join(self.root, self.name, "metrics.jsonl"))
        return TabletRouter(self.manifest, self.endpoints(), **kw)

    def remote_table(self, **kw) -> RemoteTable:
        return RemoteTable.from_manifest(self.router(**kw))

    # -- lifecycle -----------------------------------------------------------
    def stop(self, *, grace_s: float = 5.0) -> None:
        for proc in self._procs.values():
            if proc.poll() is None:
                proc.terminate()
        deadline = time.monotonic() + grace_s
        for proc in self._procs.values():
            left = max(0.0, deadline - time.monotonic())
            try:
                proc.wait(timeout=left)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=5)
        for log in self._logs:
            try:
                log.close()
            except OSError:
                pass
        self._logs = []
        shutil.rmtree(self._sock_dir, ignore_errors=True)

    def __enter__(self) -> "ServingPlane":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

"""Periodic JSON-lines metrics feed for the serving plane (numpy-free).

Every tablet worker appends one JSON line per interval to the served
table's ``root/<name>/metrics.jsonl`` — p50/p95/p99 service latency,
queue depth, shed count, WAL replay/fsync state — and the router
appends its own lines (hedge wins, failovers, per-tenant shed).
In-process tables join the same feed through
``SuffixTable.start_metrics`` (rows built by :func:`table_record`, the
full ``stats()`` tree under ``"stats"``), so one schema covers
single-process, scheduled, and plane serving.  ``serve.py
--dump-stats`` aggregates the file into a ``/varz``-style snapshot:
the latest line per emitter plus fleet-wide totals
(docs/observability.md).

Appends are single ``os.write`` calls on an ``O_APPEND`` fd, so
concurrent workers interleave whole lines, never fragments (each line
stays far under ``PIPE_BUF``).
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Callable, Optional


class LatencyWindow:
    """Rolling window of service latencies with p50/p95 quantiles."""

    def __init__(self, size: int = 512):
        self._window: deque = deque(maxlen=size)
        self._lock = threading.Lock()
        self.total = 0

    def record(self, ms: float) -> None:
        with self._lock:
            self._window.append(float(ms))
            self.total += 1

    def quantiles(self) -> dict:
        with self._lock:
            data = sorted(self._window)
        if not data:
            return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0, "n": 0}

        def q(frac: float) -> float:
            return data[min(len(data) - 1, int(frac * len(data)))]

        return {"p50_ms": round(q(0.50), 4), "p95_ms": round(q(0.95), 4),
                "p99_ms": round(q(0.99), 4), "n": len(data)}


def append_line(path: str, record: dict) -> None:
    """Append one metrics line atomically (O_APPEND, single write)."""
    line = json.dumps(record, separators=(",", ":")) + "\n"
    fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        os.write(fd, line.encode("utf-8"))
    finally:
        os.close(fd)


class MetricsEmitter:
    """Background thread appending ``provider()`` to ``path`` every
    ``interval_s`` (plus one final line on :meth:`stop`, so short-lived
    workers still leave a record).  ``interval_s <= 0`` disables the
    periodic thread but keeps the final line."""

    def __init__(self, path: str, provider: Callable[[], dict], *,
                 interval_s: float = 10.0):
        self.path = path
        self.provider = provider
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if self.interval_s > 0:
            self._thread = threading.Thread(target=self._loop,
                                            name="metrics-emitter",
                                            daemon=True)
            self._thread.start()

    def emit(self) -> None:
        record = dict(self.provider())
        record["ts"] = round(time.time(), 3)
        try:
            append_line(self.path, record)
        except OSError:
            pass                   # metrics must never take serving down

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.emit()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self.emit()                # final line: the worker's last word


def table_record(name: Optional[str], stats: dict) -> dict:
    """One feed row for an in-process table — the SAME schema plane
    workers emit: ``role`` + identity + top-level ``queries`` /
    ``p50_ms`` / ``p95_ms`` / ``p99_ms`` scalars the aggregator sums,
    with the full ``SuffixTable.stats()`` tree (tiers/cache/planner/
    build/wal/latency) riding under ``"stats"`` for drill-down.  The
    latency scalars come from the ``"total"`` span histogram (end-to-end
    ``scan_batch`` time); docs/observability.md documents the row."""
    latency = stats.get("latency") or {}
    total = latency.get("total") or {}
    return {
        "role": "table",
        "table": name,
        "pid": os.getpid(),
        "queries": int((stats.get("planner") or {}).get("queries") or 0),
        "p50_ms": float(total.get("p50_ms") or 0.0),
        "p95_ms": float(total.get("p95_ms") or 0.0),
        "p99_ms": float(total.get("p99_ms") or 0.0),
        "stats": stats,
    }


def read_lines(path: str) -> list[dict]:
    """Every parseable metrics line (torn/corrupt lines are skipped —
    the feed is observability, not a source of truth)."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except ValueError:
                continue
    return out


def aggregate_metrics(path: str) -> dict:
    """The ``/varz`` snapshot ``serve.py --dump-stats`` prints.

    Groups lines by emitter (``role``/``tablet``/``replica``/``pid``,
    plus ``table`` for in-process ``role: "table"`` rows), keeps each
    emitter's LATEST line, and sums the countable fields across
    emitters: queries served, RPCs, sheds, hedge wins, failovers, WAL
    records replayed.  Latencies aggregate as the worst (max) p95 and
    the median of p50s over every query-serving emitter (workers AND
    in-process tables) — a fleet summary, not a merged histogram.
    """
    lines = read_lines(path)
    latest: dict[tuple, dict] = {}
    for rec in lines:
        key = (rec.get("role", "worker"), rec.get("tablet"),
               rec.get("replica"), rec.get("pid"), rec.get("table"))
        cur = latest.get(key)
        if cur is None or rec.get("ts", 0) >= cur.get("ts", 0):
            latest[key] = rec
    workers = [r for r in latest.values()
               if r.get("role", "worker") == "worker"]
    routers = [r for r in latest.values() if r.get("role") == "router"]
    tables = [r for r in latest.values() if r.get("role") == "table"]
    serving = workers + tables     # everything that answers queries

    def total(records: list[dict], field: str) -> int:
        return int(sum(r.get(field) or 0 for r in records))

    p50s = sorted(r.get("p50_ms", 0.0) for r in serving)
    summary = {
        "emitters": len(latest),
        "workers": len(workers),
        "tables": len(tables),
        "tablets": len({r.get("tablet") for r in workers}),
        "queries": total(serving, "queries"),
        "rpcs": total(workers, "rpcs"),
        "shed_worker": total(workers, "shed"),
        "shed_quota": total(routers, "quota_shed"),
        "hedge_fired": total(routers, "hedge_fired"),
        "hedge_wins": total(routers, "hedge_wins"),
        "failovers": total(routers, "failovers"),
        "wal_records_replayed": total(workers, "wal_records_replayed"),
        "queue_depth": total(workers, "queue_depth"),
        "p50_ms_median": (p50s[len(p50s) // 2] if p50s else 0.0),
        "p95_ms_max": max((r.get("p95_ms", 0.0) for r in serving),
                          default=0.0),
    }
    return {"summary": summary,
            "latest": sorted(latest.values(),
                             key=lambda r: (str(r.get("role", "worker")),
                                            str(r.get("table") or ""),
                                            r.get("tablet") or 0,
                                            r.get("replica") or 0))}

"""Tablet worker process — serves one rank-range tablet of one table.

One worker owns one **tablet**: a contiguous suffix-rank slice
``[rank_lo, rank_hi)`` of a table's base suffix array, cut by
``repro.serving.plane.split_table`` and recorded in the table's
``tablets/manifest.json`` (the METADATA entry).  The worker opens the
manifest's frozen snapshot READ-ONLY with numpy alone — no jax import,
so a replica starts in milliseconds — loading:

* the full base text (``codes``; every tablet needs it to compare
  suffixes) but only the **suffix-array rows of its own rank slice**:
  when the snapshot was shard-streamed (``ShardedSave``), only the
  ``shard_sa_real_*.npy`` files overlapping the slice are even opened;
* for the **delta-owner** tablet (the last one) the delta tier too:
  sealed run codes + snapshot memtable codes + the WAL **tail replayed
  read-only** (records with seq beyond the snapshot's ``wal_seq``,
  exactly the records ``SuffixTable.open`` would replay — so a worker
  restarted after a kill -9 serves the same bit-identical view, which
  ``tests/test_plane.py`` asserts via the text CRC).

The read algorithms mirror the store's semantics exactly
(docs/serving_plane.md, "bit-identical by construction"):

* base counts/positions come from a **batched binary search** over the
  rank slice with depth-capped lexicographic compare (a suffix shorter
  than the pattern compares less via a −1 sentinel) — per-tablet counts
  over disjoint rank slices sum to the single-process count;
* delta occurrences (those ending past ``n_base``) are matched over the
  overlap window + delta text with the memtable's two-sided rule
  ``n_base < g + plen <= n_base + delta_len``.

Execution is serialized per worker behind a **device lock** — the
process model is one logical accelerator per tablet server, like a
jitted planner dispatch — with an optional per-pattern service floor
(``--device-floor-ms``) so ``benchmarks/plane_bench.py`` measures the
plane's horizontal scaling rather than a single host core's arithmetic.
Admission is bounded by ``--max-inflight`` (requests beyond it get the
typed OVERLOADED shed, see ``repro.serving.rpc``), and every worker
appends a periodic metrics line to the table's ``metrics.jsonl``.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import threading
import time
import zlib
from typing import Optional

import numpy as np

from repro.api.wal import read_segment
from repro.serving import rpc
from repro.serving.metrics import LatencyWindow, MetricsEmitter
from repro.serving.trace import Tracer

_DNA = {c: i for i, c in enumerate("ACGT")}


def encode_pattern_rows(patterns: list) -> tuple:
    """Strings -> (B, Lmax) int32 rows + (B,) int64 lens.  A numpy-only
    mirror of ``repro.core.query.encode_patterns`` (which sits behind a
    jax import): string patterns are DNA-encoded for every store kind,
    zero-padded to the batch width.  ``tests/test_plane.py`` asserts
    parity with the planner's encoding."""
    lens = np.array([len(p) for p in patterns], np.int64)
    lmax = max(1, int(lens.max()) if lens.size else 1)
    rows = np.zeros((len(patterns), lmax), np.int32)
    for i, p in enumerate(patterns):
        try:
            row = [_DNA[c.upper()] for c in p]
        except KeyError as e:
            raise ValueError(f"non-DNA symbol {e} in pattern") from e
        rows[i, :len(row)] = row
    return rows, lens


# ---------------------------------------------------------------------------
# snapshot slice loading (numpy-only)
# ---------------------------------------------------------------------------
def _array_name(path: str) -> str:
    """``"['codes']"`` -> ``"codes"`` (CheckpointManager path strings)."""
    return path.replace("['", "").replace("']", "").strip("'[]")


class SnapshotReader:
    """Read-only view of one published ``step_*`` snapshot dir."""

    def __init__(self, table_dir: str, step: int):
        self.dir = os.path.join(table_dir, f"step_{int(step):010d}")
        with open(os.path.join(self.dir, "meta.json")) as f:
            self.meta = json.load(f)
        self.extra = self.meta.get("extra", {})
        self._npz = np.load(os.path.join(self.dir, "arrays.npz"))
        self._index = {_array_name(p): f"a{i}"
                       for i, p in enumerate(self.meta["paths"])}

    def has(self, name: str) -> bool:
        return name in self._index or name in self.meta.get("shards", {})

    def load(self, name: str) -> np.ndarray:
        """Full array ``name`` (npz member or stitched shards)."""
        if name in self._index:
            return self._npz[self._index[name]]
        ent = self.meta["shards"][name]
        parts = [np.load(os.path.join(self.dir,
                                      f"shard_{name}_{i:06d}.npy"))
                 for i in range(ent["count"])]
        if not parts:
            return np.zeros((0,), np.dtype(ent["dtype"] or "int32"))
        return np.concatenate(parts)

    def load_slice(self, name: str, lo: int, hi: int) -> np.ndarray:
        """Rows ``[lo, hi)`` of 1-D array ``name`` — for a shard-streamed
        array only the overlapping shard files are opened (memory-mapped,
        then sliced), so a tablet's footprint is its slice, not the SA."""
        shards = self.meta.get("shards", {})
        if name not in shards:
            return np.asarray(self.load(name)[lo:hi])
        parts = []
        offset = 0
        for i in range(shards[name]["count"]):
            path = os.path.join(self.dir, f"shard_{name}_{i:06d}.npy")
            mm = np.load(path, mmap_mode="r")
            n = int(mm.shape[0])
            a, b = max(lo, offset), min(hi, offset + n)
            if a < b:
                parts.append(np.asarray(mm[a - offset:b - offset]))
            offset += n
        if not parts:
            dt = np.dtype(shards[name]["dtype"] or "int32")
            return np.zeros((0,), dt)
        return np.concatenate(parts)


def load_tablet(manifest_path: str, tablet_id: int) -> "TabletIndex":
    """Open the manifest's snapshot and build this tablet's index."""
    with open(manifest_path) as f:
        manifest = json.load(f)
    tablets_dir = os.path.dirname(os.path.abspath(manifest_path))
    table_dir = os.path.dirname(tablets_dir)
    spec = manifest["tablets"][tablet_id]
    if spec["id"] != tablet_id:
        raise ValueError(f"manifest tablet order broken at {tablet_id}")
    snap = SnapshotReader(table_dir, manifest["step"])
    extra = snap.extra
    if extra.get("frozen"):
        raise RuntimeError(
            "tablet workers serve the SA base tier; this snapshot is "
            "frozen onto the FM-index — split before freeze()")
    if int(extra["version"]) != int(manifest["table_version"]):
        raise RuntimeError(
            f"manifest was cut at table version "
            f"{manifest['table_version']} but the snapshot holds "
            f"v{extra['version']} — redeploy the plane (split_table)")
    codes = np.asarray(snap.load("codes"))
    n_base = int(codes.shape[0])
    rank_lo, rank_hi = int(spec["rank_lo"]), int(spec["rank_hi"])
    sa_slice = snap.load_slice("sa_real", rank_lo, rank_hi)
    mql = int(extra["max_query_len"])

    serves_delta = tablet_id == manifest["n_tablets"] - 1
    delta_parts: list[np.ndarray] = []
    wal_replayed = 0
    if serves_delta:
        for i, _meta in enumerate(extra.get("runs", [])):
            delta_parts.append(np.asarray(snap.load(f"run{i}_codes")))
        if snap.has("mem_codes"):
            mem = np.asarray(snap.load("mem_codes"))
            if mem.size:
                delta_parts.append(mem)
        wal_path = os.path.join(table_dir, "wal", "wal.log")
        if os.path.exists(wal_path):
            # read-only tail replay: never touches the live segment
            # (SuffixTable.open would truncate/attach it — workers must
            # not, the primary owns the log)
            _start, records, _summary = read_segment(wal_path)
            wal_seq = int(extra.get("wal_seq", 0))
            for seq, rec_codes, _end in records:
                if seq > wal_seq:
                    delta_parts.append(np.asarray(rec_codes))
                    wal_replayed += 1
    delta = (np.concatenate(delta_parts).astype(codes.dtype)
             if delta_parts else np.zeros((0,), codes.dtype))
    return TabletIndex(
        codes=codes, sa_slice=sa_slice, rank_lo=rank_lo, rank_hi=rank_hi,
        delta_codes=delta, max_query_len=mql,
        is_dna=bool(extra["is_dna"]), serves_delta=serves_delta,
        wal_records_replayed=wal_replayed, manifest=manifest,
        tablet_id=tablet_id)


# ---------------------------------------------------------------------------
# the tablet index
# ---------------------------------------------------------------------------
class TabletIndex:
    """Rank-slice suffix search + (for the owner) delta matching."""

    def __init__(self, *, codes: np.ndarray, sa_slice: np.ndarray,
                 rank_lo: int, rank_hi: int, delta_codes: np.ndarray,
                 max_query_len: int, is_dna: bool, serves_delta: bool,
                 wal_records_replayed: int = 0,
                 manifest: Optional[dict] = None, tablet_id: int = 0):
        self.n_base = int(codes.shape[0])
        self.rank_lo, self.rank_hi = int(rank_lo), int(rank_hi)
        self.max_query_len = int(max_query_len)
        self.is_dna = bool(is_dna)
        self.serves_delta = bool(serves_delta)
        self.wal_records_replayed = int(wal_records_replayed)
        self.manifest = manifest
        self.tablet_id = int(tablet_id)
        self._sa = np.ascontiguousarray(sa_slice).astype(np.int64)
        if self._sa.shape[0] != self.rank_hi - self.rank_lo:
            raise ValueError(
                f"SA slice holds {self._sa.shape[0]} rows for rank range "
                f"[{rank_lo}, {rank_hi}) — snapshot/manifest mismatch")
        codes32 = np.ascontiguousarray(codes).astype(np.int32)
        # −1 sentinel pad: a suffix running out of text inside the
        # compare depth reads −1 < every real code, i.e. shorter-is-less
        self._pad = np.concatenate(
            [codes32, np.full(self.max_query_len, -1, np.int32)])
        self.delta_len = int(delta_codes.shape[0])
        self.overlap = min(self.max_query_len - 1, self.n_base)
        if self.serves_delta and self.delta_len:
            self._window = np.concatenate([
                codes32[self.n_base - self.overlap:self.n_base],
                np.asarray(delta_codes).astype(np.int32)])
        else:
            self._window = np.zeros((0,), np.int32)
        # identity of the served view: crc over base + delta code bytes
        crc = zlib.crc32(np.ascontiguousarray(codes).tobytes())
        self.text_crc = zlib.crc32(
            np.asarray(delta_codes).astype(codes.dtype).tobytes(), crc)

    @property
    def n_slice(self) -> int:
        return int(self._sa.shape[0])

    # -- base tier: batched rank-slice binary search -------------------------
    def _cmp_rows(self, g: np.ndarray, rows: np.ndarray,
                  mask: np.ndarray, rowsel: np.ndarray) -> np.ndarray:
        """sign(suffix(g) - pattern) per row, compared to pattern depth."""
        idx = g[:, None] + np.arange(rows.shape[1], dtype=np.int64)[None, :]
        w = self._pad[np.minimum(idx, self._pad.shape[0] - 1)]
        diff = (w != rows) & mask
        has = diff.any(axis=1)
        first = np.where(has, diff.argmax(axis=1), 0)
        delta = (w[rowsel, first].astype(np.int64)
                 - rows[rowsel, first].astype(np.int64))
        return np.where(has, np.sign(delta), 0)

    def _bound(self, rows: np.ndarray, mask: np.ndarray,
               upper: bool) -> np.ndarray:
        B = rows.shape[0]
        rowsel = np.arange(B)
        lo = np.zeros(B, np.int64)
        hi = np.full(B, self.n_slice, np.int64)
        while True:
            act = lo < hi
            if not act.any():
                return lo
            mid = (lo + hi) >> 1
            g = self._sa[np.minimum(mid, max(self.n_slice - 1, 0))]
            c = self._cmp_rows(g, rows, mask, rowsel)
            go_right = (c <= 0) if upper else (c < 0)
            lo = np.where(act & go_right, mid + 1, lo)
            hi = np.where(act & ~go_right, mid, hi)

    def base_bounds(self, rows: np.ndarray,
                    lens: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(lb, ub) local rank bounds per pattern; count = ub - lb."""
        rows = np.ascontiguousarray(rows).astype(np.int32)
        lens = np.asarray(lens).astype(np.int64)
        if np.any(lens < 1) or np.any(lens > self.max_query_len):
            raise ValueError(
                f"pattern lengths must be in [1, {self.max_query_len}]")
        if self.n_slice == 0:
            z = np.zeros(rows.shape[0], np.int64)
            return z, z.copy()
        mask = (np.arange(rows.shape[1], dtype=np.int64)[None, :]
                < lens[:, None])
        return (self._bound(rows, mask, upper=False),
                self._bound(rows, mask, upper=True))

    def base_scan(self, rows: np.ndarray, lens: np.ndarray,
                  top_k: int = 0) -> dict:
        lb, ub = self.base_bounds(rows, lens)
        B = lb.shape[0]
        count = ub - lb
        first = np.full(B, -1, np.int64)
        positions = (np.full((B, top_k), -1, np.int64) if top_k else None)
        for i in np.flatnonzero(count > 0):
            seg = self._sa[lb[i]:ub[i]]
            first[i] = int(seg.min())
            if top_k:
                c = (np.partition(seg, top_k - 1)[:top_k]
                     if seg.shape[0] > top_k else seg.copy())
                c.sort()
                positions[i, :c.shape[0]] = c
        out = {"count": count, "first_pos": first}
        if top_k:
            out["positions"] = positions
        return out

    def base_positions(self, row: np.ndarray, length: int) -> np.ndarray:
        """All base occurrences of one pattern inside this slice."""
        lb, ub = self.base_bounds(row[None, :], np.array([length]))
        return np.sort(self._sa[int(lb[0]):int(ub[0])])

    # -- delta tier (owner only) ---------------------------------------------
    def delta_positions_one(self, row: np.ndarray,
                            length: int) -> np.ndarray:
        """Global start positions of delta-owned occurrences of one
        pattern (``n_base < g + L <= n_base + delta_len``), ascending."""
        L = int(length)
        win = self._window
        if not self.serves_delta or win.shape[0] < L:
            return np.zeros((0,), np.int64)
        sl = np.lib.stride_tricks.sliding_window_view(win, L)
        hit = np.flatnonzero((sl == row[:L]).all(axis=1))
        g = hit.astype(np.int64) + (self.n_base - self.overlap)
        return g[g + L > self.n_base]

    def delta_scan(self, rows: np.ndarray, lens: np.ndarray,
                   top_k: int = 0) -> dict:
        rows = np.ascontiguousarray(rows).astype(np.int32)
        lens = np.asarray(lens).astype(np.int64)
        B = rows.shape[0]
        count = np.zeros(B, np.int64)
        first = np.full(B, -1, np.int64)
        positions = (np.full((B, top_k), -1, np.int64) if top_k else None)
        if self.delta_len:
            for i in range(B):
                g = self.delta_positions_one(rows[i], int(lens[i]))
                if g.size:
                    count[i] = g.shape[0]
                    first[i] = int(g[0])
                    if top_k:
                        positions[i, :min(top_k, g.shape[0])] = g[:top_k]
        out = {"count": count, "first_pos": first}
        if top_k:
            out["positions"] = positions
        return out

    def locate_range(self, row: np.ndarray, length: int, after: int,
                     limit: Optional[int]) -> np.ndarray:
        """This tablet's contribution to a paged enumeration: ascending
        positions strictly greater than ``after``, capped at ``limit``
        (per-tablet caps are safe — the router keeps the globally
        smallest ``limit`` of the merged streams)."""
        base = self.base_positions(row, length)
        parts = [base[base > after]]
        if self.serves_delta and self.delta_len:
            g = self.delta_positions_one(row, length)
            parts.append(g[g > after])
        cand = np.concatenate(parts)
        cand.sort()
        if limit is not None and cand.shape[0] > limit:
            cand = cand[:limit]
        return cand.astype(np.int64)

    def stats(self) -> dict:
        return {"tablet": self.tablet_id, "rank_lo": self.rank_lo,
                "rank_hi": self.rank_hi, "n_base": self.n_base,
                "serves_delta": self.serves_delta,
                "delta_len": self.delta_len,
                "wal_records_replayed": self.wal_records_replayed,
                "text_crc": self.text_crc}


# ---------------------------------------------------------------------------
# the worker process
# ---------------------------------------------------------------------------
class TabletWorker:
    """One serving process: index + RPC server + metrics feed."""

    def __init__(self, index: TabletIndex, sock_path: str, *,
                 replica: int = 0, max_inflight: int = 8,
                 metrics_path: Optional[str] = None,
                 metrics_interval_s: float = 10.0,
                 device_floor_ms: float = 0.0,
                 inject_slow_ms: float = 0.0, inject_slow_p: float = 0.0,
                 seed: int = 0):
        self.index = index
        self.replica = int(replica)
        self.device_floor_ms = float(device_floor_ms)
        self.inject_slow_ms = float(inject_slow_ms)
        self.inject_slow_p = float(inject_slow_p)
        self._rng = np.random.default_rng(
            seed * 1000003 + index.tablet_id * 101 + replica)
        # one logical device per worker: scan execution is serialized,
        # like a single-accelerator planner dispatch queue
        self._device_lock = threading.Lock()
        self._latency = LatencyWindow()
        # per-op span histograms (stats()["latency"]): scan / locate /
        # stats service time, same snapshot schema as every other tier
        self.tracer = Tracer()
        self._queries = 0
        self._rpcs = 0
        self._t0 = time.time()
        self.stop_event = threading.Event()
        self.server = rpc.RpcServer(sock_path, self.handle,
                                    max_inflight=max_inflight,
                                    stats_hook=self._observe)
        self.emitter = None
        if metrics_path is not None:
            self.emitter = MetricsEmitter(metrics_path, self.stats,
                                          interval_s=metrics_interval_s)

    def _observe(self, op: str, service_ms: float, shed: bool) -> None:
        if not shed:
            self._latency.record(service_ms)
            self.tracer.record(str(op), service_ms)

    def _device_execute(self, n_patterns: int):
        """The device model: serialized execution, optional per-pattern
        service floor, optional injected straggler (for the hedged-read
        bench — a replica that sometimes stalls like the paper's 771 ms
        outlier)."""
        with self._device_lock:
            dt = self.device_floor_ms * n_patterns / 1e3
            if self.inject_slow_p > 0 and \
                    self._rng.random() < self.inject_slow_p:
                dt += self.inject_slow_ms / 1e3
            if dt > 0:
                time.sleep(dt)

    def stats(self) -> dict:
        st = self.index.stats()
        st.update(self._latency.quantiles())
        st.update({"role": "worker", "replica": self.replica,
                   "pid": os.getpid(), "queries": self._queries,
                   "rpcs": self._rpcs,
                   "shed": self.server.shed_count,
                   "queue_depth": self.server.queue_depth,
                   "max_inflight": self.server.max_inflight,
                   "uptime_s": round(time.time() - self._t0, 1)})
        st["latency"] = self.tracer.snapshot()
        return st

    # -- request handling -----------------------------------------------------
    def handle(self, msg: dict) -> dict:
        op = msg.get("op")
        if op == "ping":
            return {"status": "ok", "pid": os.getpid(),
                    "tablet": self.index.tablet_id,
                    "replica": self.replica}
        if op == "stats":
            return {"status": "ok", "stats": self.stats()}
        if op == "shutdown":
            self.stop_event.set()
            return {"status": "ok"}
        if op == "scan":
            return self._handle_scan(msg)
        if op == "locate_range":
            return self._handle_locate(msg)
        return {"status": "error", "error": f"unknown op {op!r}"}

    def _handle_scan(self, msg: dict) -> dict:
        self._rpcs += 1
        reply: dict = {"status": "ok"}
        n_device = 0
        rows = msg.get("rows")
        if rows is not None and rows.shape[0]:
            n_device += int(rows.shape[0])
        drows = msg.get("drows")
        has_delta = (self.index.serves_delta and self.index.delta_len > 0)
        if drows is not None and drows.shape[0] and has_delta:
            n_device += int(drows.shape[0])
        self._device_execute(n_device)
        top_k = int(msg.get("top_k", 0))
        if rows is not None and rows.shape[0]:
            self._queries += int(rows.shape[0])
            reply.update(self.index.base_scan(rows, msg["lens"], top_k))
        if drows is not None and drows.shape[0]:
            d = self.index.delta_scan(drows, msg["dlens"], top_k)
            reply["dcount"] = d["count"]
            reply["dfirst_pos"] = d["first_pos"]
            if top_k:
                reply["dpositions"] = d["positions"]
        return reply

    def _handle_locate(self, msg: dict) -> dict:
        self._rpcs += 1
        self._queries += 1
        self._device_execute(1)
        limit = msg.get("limit")
        out = self.index.locate_range(
            np.asarray(msg["row"]), int(msg["len"]),
            int(msg.get("after", -1)),
            None if limit is None or limit < 0 else int(limit))
        return {"status": "ok", "positions": out}

    def run_forever(self) -> None:
        try:
            while not self.stop_event.wait(0.25):
                pass
        finally:
            self.shutdown()

    def shutdown(self) -> None:
        if self.emitter is not None:
            self.emitter.stop()
        self.server.stop()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serve one tablet of a suffix table (numpy-only)")
    ap.add_argument("--manifest", required=True)
    ap.add_argument("--tablet", type=int, required=True)
    ap.add_argument("--replica", type=int, default=0)
    ap.add_argument("--sock", required=True)
    ap.add_argument("--max-inflight", type=int, default=8)
    ap.add_argument("--metrics-path", default=None)
    ap.add_argument("--metrics-interval", type=float, default=10.0)
    ap.add_argument("--device-floor-ms", type=float, default=0.0)
    ap.add_argument("--inject-slow-ms", type=float, default=0.0)
    ap.add_argument("--inject-slow-p", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    index = load_tablet(args.manifest, args.tablet)
    worker = TabletWorker(
        index, args.sock, replica=args.replica,
        max_inflight=args.max_inflight, metrics_path=args.metrics_path,
        metrics_interval_s=args.metrics_interval,
        device_floor_ms=args.device_floor_ms,
        inject_slow_ms=args.inject_slow_ms,
        inject_slow_p=args.inject_slow_p, seed=args.seed)
    signal.signal(signal.SIGTERM,
                  lambda *_: worker.stop_event.set())
    print(f"[tablet-worker] tablet={args.tablet} replica={args.replica} "
          f"ranks=[{index.rank_lo},{index.rank_hi}) "
          f"delta={index.delta_len} pid={os.getpid()}", flush=True)
    worker.run_forever()
    return 0


if __name__ == "__main__":
    sys.exit(main())

from repro.core.planner import ScanPlanner
from repro.serving.engine import (HedgedScanService, ServeConfig,
                                  greedy_generate, make_decode_fn,
                                  make_prefill_fn)

__all__ = ["HedgedScanService", "ScanPlanner", "ServeConfig",
           "greedy_generate", "make_decode_fn", "make_prefill_fn"]

"""repro.serving — scan serving: in-process engine + the multi-process
serving plane (docs/serving_plane.md).

Exports resolve lazily (PEP 562) so that the plane's numpy-only modules
(``rpc``, ``metrics``, ``tablet_server``) can be imported by worker
processes without paying the jax import the engine needs.
"""
import importlib

_EXPORTS = {
    "HedgedScanService": "repro.serving.engine",
    "ServeConfig": "repro.serving.engine",
    "greedy_generate": "repro.serving.engine",
    "make_decode_fn": "repro.serving.engine",
    "make_prefill_fn": "repro.serving.engine",
    "ScanPlanner": "repro.core.planner",
    "ServingPlane": "repro.serving.plane",
    "split_table": "repro.serving.plane",
    "TabletRouter": "repro.serving.router",
    "RemoteTable": "repro.serving.router",
    "OverloadedError": "repro.serving.router",
    "RpcClient": "repro.serving.rpc",
    "RpcServer": "repro.serving.rpc",
    "RpcError": "repro.serving.rpc",
    "aggregate_metrics": "repro.serving.metrics",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

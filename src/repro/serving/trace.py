"""Lightweight per-query tracing shared by every serving mode.

Each stage of the read path wraps itself in a named *span*
(``with tracer.span("dispatch"): ...``); the measured wall time lands
in a bounded ring buffer per span name, and ``snapshot()`` reduces the
rings to rolling p50/p95/p99 histograms.  The snapshot is what
``stats()["latency"]`` returns everywhere — ``SuffixTable``,
``QueryScheduler``, ``TabletRouter`` — and what the ``metrics.jsonl``
feed exports, so one schema describes in-process, scheduled, and
multi-process serving alike (docs/observability.md).

Design constraints (the read path is the hot path):

* Recording a span is two ``time.monotonic_ns()`` calls, one float
  subtraction, one ring-slot store, and one integer increment — no
  locks, no allocation beyond the span object itself.  Slot writes and
  the index bump are each atomic under the GIL; a concurrent recorder
  can at worst overwrite one sample or under-count by one, which a
  rolling histogram tolerates by construction.
* ``Tracer(enabled=False)`` (or ``tracer.enabled = False`` at runtime)
  swaps ``span()`` for a shared no-op context, so a disabled tracer
  costs one attribute check per call site.
* Buffers are preallocated numpy float64 rings (default 2048 samples
  per span) — memory is bounded no matter how long the process serves.

Span names are dotted free-form; the conventional set produced by the
repo's own call sites is documented in docs/observability.md.
"""

from __future__ import annotations

import time

import numpy as np

__all__ = ["SpanHistogram", "Tracer"]

_DEFAULT_RING = 2048
# quantiles exported by every histogram snapshot, in feed order
_QUANTILES = (("p50_ms", 0.50), ("p95_ms", 0.95), ("p99_ms", 0.99))


class SpanHistogram:
    """Bounded ring of span durations (ms) reduced to rolling quantiles.

    The ring keeps the most recent ``size`` samples; ``total`` counts
    every sample ever recorded (so feeds can rate-convert) and
    ``sum_ms`` accumulates total time for mean/utilisation math.
    """

    __slots__ = ("_buf", "_size", "_n", "_sum_ms")

    def __init__(self, size: int = _DEFAULT_RING):
        if size <= 0:
            raise ValueError(f"ring size must be positive, got {size}")
        self._size = int(size)
        self._buf = np.zeros(self._size, np.float64)
        self._n = 0
        self._sum_ms = 0.0

    def record(self, ms: float) -> None:
        # lock-free: a slot store + int bump, each atomic under the GIL
        self._buf[self._n % self._size] = ms
        self._n += 1
        self._sum_ms += ms

    @property
    def count(self) -> int:
        return self._n

    def quantiles(self) -> dict:
        """Rolling p50/p95/p99 over the ring window (same empirical
        quantile rule as ``metrics.LatencyWindow``: the sorted sample
        at index ``int(frac * n)``, clamped)."""
        n = min(self._n, self._size)
        if n == 0:
            out = {name: 0.0 for name, _ in _QUANTILES}
            out.update(n=0, total=0, sum_ms=0.0)
            return out
        data = np.sort(self._buf[:n])
        out = {name: round(float(data[min(n - 1, int(frac * n))]), 4)
               for name, frac in _QUANTILES}
        out.update(n=int(n), total=int(self._n),
                   sum_ms=round(float(self._sum_ms), 4))
        return out


class _Span:
    """One timed region.  Deliberately not ``@contextmanager`` — a tiny
    __enter__/__exit__ class is several times cheaper per call."""

    __slots__ = ("_tracer", "_name", "_t0")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self._name = name

    def __enter__(self) -> "_Span":
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tracer.record(self._name,
                            (time.monotonic_ns() - self._t0) / 1e6)
        return False


class _NullSpan:
    """Shared no-op context for disabled tracers."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Named span histograms for one component (table, scheduler,
    router).  ``span(name)`` times a region; ``record(name, ms)`` logs
    an externally measured duration (e.g. a queue wait computed from a
    stored submit timestamp); ``snapshot()`` is the ``stats()
    ["latency"]`` payload."""

    def __init__(self, *, ring_size: int = _DEFAULT_RING,
                 enabled: bool = True):
        self.enabled = bool(enabled)
        self._ring_size = int(ring_size)
        self._spans: dict[str, SpanHistogram] = {}

    def span(self, name: str):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name)

    def record(self, name: str, ms: float) -> None:
        if not self.enabled:
            return
        hist = self._spans.get(name)
        if hist is None:
            # setdefault: two racing first-recorders converge on one ring
            hist = self._spans.setdefault(name,
                                          SpanHistogram(self._ring_size))
        hist.record(float(ms))

    def snapshot(self) -> dict:
        """``{span_name: {p50_ms, p95_ms, p99_ms, n, total, sum_ms}}``,
        name-sorted so feed rows diff cleanly."""
        return {name: self._spans[name].quantiles()
                for name in sorted(self._spans)}

    def reset(self) -> None:
        self._spans.clear()

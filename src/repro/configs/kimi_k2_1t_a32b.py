"""kimi-k2-1t-a32b [moe] — trillion-param MoE [arXiv:2501.kimi2].
Pool spec: 61L d_model=7168 64H (GQA kv=8... pool annotation; the released
K2 uses MLA — we follow the pool table's MLA-style low-rank attention with
64 heads) d_ff(expert)=2048 vocab=163840, MoE 384e top-8, 1 shared."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,               # 7168 / 64
    d_ff=18432,
    vocab_size=163840,
    attn_type="gqa",            # pool table: GQA kv=8
    num_experts=384,
    num_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_dense_layers=1,
    rope_theta=50000.0,
)

"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; attention at layer i%8==4; MoE on every 2nd layer."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=65536,
    num_experts=16, experts_per_token=2, moe_d_ff=14336, moe_every=2,
    attn_every=8, attn_offset=4,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    rope_theta=10000.0,
)

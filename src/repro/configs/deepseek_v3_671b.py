"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].  61L d_model=7168 128H d_ff(expert)=2048
vocab=129280; first 3 layers dense (d_ff 18432 folded into prefix MoE-free
layers via d_ff), q LoRA 1536 / kv LoRA 512, nope 128 + rope 64, v 128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,                 # dense layers (first 3)
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    mtp_depth=1,
    rope_theta=10000.0,
)

"""The paper's own workload config: tablet-sharded suffix array over a
human-chromosome-scale DNA string, serving random-pattern scans
(Giacomelli 2020 §IV-V)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SuffixArrayConfig:
    name: str = "dna-suffix"
    text_len: int = 250_000_000      # ~chromosome 1 (bases)
    max_query_len: int = 112         # paper workload <= 100, word-aligned
    query_batch: int = 1024          # concurrent scans per step
    tablets_per_device: int = 1
    sort_method: str = "bitonic"     # construction sort (or "sample")


CONFIG = SuffixArrayConfig()

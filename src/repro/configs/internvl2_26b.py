"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].
Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
Frontend STUB: input_specs provides precomputed patch embeddings
(B, num_patches, d) prepended to the text sequence."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm", num_layers=48, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=16384, vocab_size=92553,
    frontend="vlm_stub", num_patches=256, rope_theta=1000000.0,
)

"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060].
48L d_model=1536 attn-free, d_ff=0, vocab=50280, ssm_state=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    attn_type="none", ssm_state=128, ssm_headdim=64, ssm_expand=2,
    ssm_conv=4, ssm_chunk=256, tie_embeddings=True,
)

"""Architecture registry: one module per assigned arch (+ the paper's own
DNA suffix-array engine config).  ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_v3_671b",
    "kimi_k2_1t_a32b",
    "yi_6b",
    "qwen15_110b",
    "qwen3_0_6b",
    "phi3_mini_3_8b",
    "jamba_v01_52b",
    "mamba2_780m",
    "musicgen_medium",
    "internvl2_26b",
]

_ALIAS = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "yi-6b": "yi_6b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen3-0.6b": "qwen3_0_6b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "mamba2-780m": "mamba2_780m",
    "musicgen-medium": "musicgen_medium",
    "internvl2-26b": "internvl2_26b",
    "dna-suffix": "dna_suffix",
}


def get_config(name: str):
    mod_name = _ALIAS.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs():
    return list(_ALIAS.keys())[:-1]

"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048.  Frontend STUB: input_specs provides precomputed frame
embeddings (B, S, d); the EnCodec encoder itself is out of scope."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", num_layers=48, d_model=1536,
    num_heads=24, num_kv_heads=24, d_ff=6144, vocab_size=2048,
    mlp_act="gelu", frontend="audio_stub", rope_theta=10000.0,
)

"""Pallas TPU kernels for the scan-path hot spots the paper optimizes:

  pack2bit      — 2-bit DNA ingest packing (paper §IV pre-processing)
  pattern_scan  — masked packed suffix-vs-pattern compare (one search round)
  tablet_scan   — blocked range-scan: BQ patterns x BR sorted rows in VMEM

Each kernel: <name>.py (pl.pallas_call + BlockSpec), validated in
interpret mode against ref.py oracles across shape/dtype sweeps
(tests/test_kernels.py); ops.py holds the jit'd public wrappers."""
from repro.kernels import ops, ref
from repro.kernels.ops import pack2bit, pattern_compare, tablet_scan

__all__ = ["ops", "pack2bit", "pattern_compare", "ref", "tablet_scan"]

"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute via ``interpret=True``; on TPU
they compile to Mosaic.  Wrappers handle padding to kernel block multiples
and layout transposition so callers keep natural (B, W) shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.kernels import pack2bit as _pk
from repro.kernels import pattern_scan as _ps
from repro.kernels import tablet_scan as _ts


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis, fill=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill), n


def pack2bit(codes) -> jnp.ndarray:
    """uint8 codes {0..3} -> packed uint32 words (kernel-backed)."""
    codes = jnp.asarray(codes)
    n = codes.shape[0]
    n_words = codec.packed_length(n)
    n_words_pad = int(np.ceil(n_words / _pk.BLOCK_WORDS)) * _pk.BLOCK_WORDS
    flat = jnp.zeros((n_words_pad * 16,), jnp.uint32).at[:n].set(
        codes.astype(jnp.uint32))
    lanes = flat.reshape(n_words_pad, 16).T          # slot-major (16, words)
    packed = _pk.pack2bit_pallas(lanes, interpret=_interpret())
    return packed[:n_words]


def pattern_compare(windows, patterns, plen, pos, *, n_real: int):
    """(B, W) windows/patterns, (B,) plen/pos -> (lt, le, eq) bool (B,)."""
    wt, B = _pad_to(windows.T.astype(jnp.uint32), _ps.BLOCK_B, 1)
    pt, _ = _pad_to(patterns.T.astype(jnp.uint32), _ps.BLOCK_B, 1)
    pl_, _ = _pad_to(plen.astype(jnp.int32), _ps.BLOCK_B, 0)
    po_, _ = _pad_to(pos.astype(jnp.int32), _ps.BLOCK_B, 0)
    lt, le, eq = _ps.pattern_compare_pallas(
        wt, pt, pl_, po_, n_real=n_real, interpret=_interpret())
    return (lt[:B].astype(bool), le[:B].astype(bool), eq[:B].astype(bool))


def tablet_scan(patterns, plen, windows, pos, *, n_real: int):
    """Linear scan of BR sorted-row windows by BQ patterns.
    patterns (BQ, W), plen (BQ,), windows (BR, W), pos (BR,).
    Returns (count, less, first_row) int32 (BQ,); first_row == 2**30 when
    no match.  Row padding uses pos=n_real & window=~0 so padded rows never
    match and never count as 'less'."""
    pt, BQ = _pad_to(patterns.T.astype(jnp.uint32), _ts.BLOCK_Q, 1)
    pl_, _ = _pad_to(plen.astype(jnp.int32), _ts.BLOCK_Q, 0, fill=1)
    wt, BR = _pad_to(windows.T.astype(jnp.uint32), _ts.BLOCK_R, 1)
    po_, _ = _pad_to(pos.astype(jnp.int32), _ts.BLOCK_R, 0, fill=n_real)
    count, less, first = _ts.tablet_scan_pallas(
        pt, pl_, wt, po_, n_real=n_real, n_rows=BR, interpret=_interpret())
    return count[:BQ], less[:BQ], first[:BQ]

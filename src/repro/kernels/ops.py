"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute via ``interpret=True``; on TPU
they compile to Mosaic.  Wrappers handle padding to kernel block multiples
and layout transposition so callers keep natural (B, W) shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core import query as _q
from repro.kernels import fm_scan as _fm
from repro.kernels import pack2bit as _pk
from repro.kernels import pattern_scan as _ps
from repro.kernels import tablet_scan as _ts
from repro.kernels import tier_scan as _tier


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis, fill=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill), n


def pack2bit(codes) -> jnp.ndarray:
    """uint8 codes {0..3} -> packed uint32 words (kernel-backed)."""
    codes = jnp.asarray(codes)
    n = codes.shape[0]
    n_words = codec.packed_length(n)
    n_words_pad = int(np.ceil(n_words / _pk.BLOCK_WORDS)) * _pk.BLOCK_WORDS
    flat = jnp.zeros((n_words_pad * 16,), jnp.uint32).at[:n].set(
        codes.astype(jnp.uint32))
    lanes = flat.reshape(n_words_pad, 16).T          # slot-major (16, words)
    packed = _pk.pack2bit_pallas(lanes, interpret=_interpret())
    return packed[:n_words]


def pattern_compare(windows, patterns, plen, pos, *, n_real: int):
    """(B, W) windows/patterns, (B,) plen/pos -> (lt, le, eq) bool (B,)."""
    wt, B = _pad_to(windows.T.astype(jnp.uint32), _ps.BLOCK_B, 1)
    pt, _ = _pad_to(patterns.T.astype(jnp.uint32), _ps.BLOCK_B, 1)
    pl_, _ = _pad_to(plen.astype(jnp.int32), _ps.BLOCK_B, 0)
    po_, _ = _pad_to(pos.astype(jnp.int32), _ps.BLOCK_B, 0)
    lt, le, eq = _ps.pattern_compare_pallas(
        wt, pt, pl_, po_, n_real=n_real, interpret=_interpret())
    return (lt[:B].astype(bool), le[:B].astype(bool), eq[:B].astype(bool))


def tablet_scan(patterns, plen, windows, pos, *, n_real: int):
    """Linear scan of BR sorted-row windows by BQ patterns.
    patterns (BQ, W), plen (BQ,), windows (BR, W), pos (BR,).
    Returns (count, less, first_row) int32 (BQ,); first_row == 2**30 when
    no match.  Row padding uses pos=n_real & window=~0 so padded rows never
    match and never count as 'less'."""
    pt, BQ = _pad_to(patterns.T.astype(jnp.uint32), _ts.BLOCK_Q, 1)
    pl_, _ = _pad_to(plen.astype(jnp.int32), _ts.BLOCK_Q, 0, fill=1)
    wt, BR = _pad_to(windows.T.astype(jnp.uint32), _ts.BLOCK_R, 1)
    po_, _ = _pad_to(pos.astype(jnp.int32), _ts.BLOCK_R, 0, fill=n_real)
    count, less, first = _ts.tablet_scan_pallas(
        pt, pl_, wt, po_, n_real=n_real, n_rows=BR, interpret=_interpret())
    return count[:BQ], less[:BQ], first[:BQ]


def tier_scan(stack, patterns, plen):
    """Kernel-backed fused tier scan (DNA-packed tables only).
    patterns (B, W) uint32, plen (B,) int32; returns (count, less,
    matches, first_g) int32 (T, B) — same contract as
    ``tier_scan.fused_tier_scan``."""
    T = stack.num_tiers
    R = stack.rows
    W = patterns.shape[1]
    windows = jax.vmap(
        lambda pk, sa_t: codec.extract_window(pk, sa_t, W))(
            stack.text_packed, stack.sa)                    # (T, R, W)
    wt, _ = _pad_to(jnp.transpose(windows, (0, 2, 1)).astype(jnp.uint32),
                    _tier.BLOCK_R, 2)
    sa_p, _ = _pad_to(stack.sa.astype(jnp.int32), _tier.BLOCK_R, 1)
    pt, B = _pad_to(patterns.T.astype(jnp.uint32), _tier.BLOCK_Q, 1)
    pl_, _ = _pad_to(plen.astype(jnp.int32), _tier.BLOCK_Q, 0, fill=1)
    meta = jnp.zeros((T, 8), jnp.int32)
    meta = meta.at[:, 0].set(stack.n_real.astype(jnp.int32))
    meta = meta.at[:, 1].set(stack.n_rows.astype(jnp.int32))
    meta = meta.at[:, 2].set(stack.offset.astype(jnp.int32))
    meta = meta.at[:, 3].set(stack.lo.astype(jnp.int32))
    meta = meta.at[:, 4].set(stack.hi.astype(jnp.int32))
    count, less, matches, first = _tier.tier_scan_pallas(
        pt, pl_, wt, sa_p, meta, interpret=_interpret())
    return count[:, :B], less[:, :B], matches[:, :B], first[:, :B]


def tier_scan_auto(stack, patterns, plen):
    """Pick the Pallas tier kernel on TPU for packed-DNA batches; the jnp
    binary-search path everywhere else (it is also the oracle)."""
    if (not _interpret()) and stack.is_dna and patterns.dtype == jnp.uint32:
        return tier_scan(stack, patterns, plen)
    return _tier.fused_tier_scan(stack, patterns, plen)


@jax.jit
def fused_tiers(stack, patterns, plen):
    """One launch over all delta tiers: (count, less, matches, first_g),
    each (T, B) int32.  Used by mesh tables, where the base scan already
    runs inside its own shard_map dispatch."""
    return tier_scan_auto(stack, patterns, plen)


@jax.jit
def fused_single(store, stack, patterns, plen):
    """THE single-device merged read: base binary search + all delta
    tiers + the merge, one jitted launch end to end.  Returns
    (merged MatchResult, base MatchResult, (count, less, matches,
    first_g)).

    On the jnp path the base and tier searches share ONE fori_loop
    (``tier_scan.fused_table_scan``), so a merged read pays the serial
    depth of the deepest store, not the sum; with the Pallas kernel the
    dense tier scan rides its own launch next to the base search."""
    if (not _interpret()) and stack.is_dna and patterns.dtype == jnp.uint32:
        base = _q.query(store, patterns, plen)
        tiers = tier_scan(stack, patterns, plen)
    else:
        base, tiers = _tier.fused_table_scan(store, stack, patterns, plen)
    merged = _tier.merge_tier_results(base, tiers[0], tiers[3])
    return merged, base, tiers


@jax.jit
def fm_search(arrays, patterns, plen):
    """Frozen-tier base read: FM backward search + one LF walk for
    ``first_pos``, a single jitted launch.  Same MatchResult contract as
    ``query`` with one widening: ``first_rank`` is the real-SA lower
    bound for EVERY query (found or not) — ``merge_tier_results`` only
    reads it through a ``count > 0`` guard, so the paths stay
    bit-identical where it matters.  Packed-DNA batches take the Pallas
    kernel on TPU; everything else runs the jnp oracle."""
    if arrays.is_dna and patterns.dtype == jnp.uint32:
        syms = _fm.syms_from_packed(patterns, plen, patterns.shape[1] * 16)
    else:
        syms = _fm.syms_from_codes(patterns, plen, patterns.shape[1])
    if (not _interpret()) and arrays.is_dna \
            and patterns.dtype == jnp.uint32:
        padded, B = _pad_to(syms, _fm.BLOCK_Q, 1, fill=-1)
        lo, hi = _fm.fm_scan_pallas(padded, arrays.bwt, arrays.occ,
                                    _fm.pallas_meta(arrays),
                                    interpret=False)
        lo, hi = lo[:B], hi[:B]
    else:
        lo, hi = _fm.search_syms(arrays, syms)
    found, count, first_rank, first_pos = _fm.finish_match(arrays, lo, hi)
    return _q.MatchResult(found=found, count=count,
                          first_rank=first_rank, first_pos=first_pos)

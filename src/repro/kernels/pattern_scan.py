"""Pallas TPU kernel: masked packed suffix-vs-pattern compare (query hot-spot).

One binary-search round compares B suffix windows against B patterns at
per-query depth.  Layout is word-major: (W, B) — W (<=8) packed words on the
sublane axis, queries on the 128-aligned lane axis.  The first-difference
scan over words is an unrolled W-loop carrying a prefix-equality mask —
the idiom the VPU wants instead of a horizontal cumprod.

Outputs: lt  (suffix < pattern at depth plen)  — drives lower_bound;
         le  (lt | prefix-equal)                — drives upper_bound;
         eq  (suffix starts with pattern)       — match flag.
Truncation at the text boundary (suffix shorter than pattern) is folded in
exactly as core.query.compare_packed does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 512  # queries per grid step (lane axis)


def _compare_kernel(win_ref, patt_ref, plen_ref, pos_ref,
                    lt_ref, le_ref, eq_ref, *, n_real: int, n_words: int):
    plen = plen_ref[...].astype(jnp.int32)          # (1, B)
    pos = pos_ref[...].astype(jnp.int32)            # (1, B)
    shape = plen.shape

    pe = jnp.ones(shape, jnp.bool_)                 # prefix equal so far
    lt = jnp.zeros(shape, jnp.bool_)
    for w in range(n_words):
        a = win_ref[w, :][None, :]                  # suffix word   (1, B)
        b = patt_ref[w, :][None, :]                 # pattern word  (1, B)
        r = jnp.clip(plen - w * 16, 0, 16).astype(jnp.uint32)
        full = jnp.uint32(0xFFFFFFFF)
        mask = jnp.where(r == 0, jnp.uint32(0),
                         jnp.where(r == 16, full,
                                   ~((jnp.uint32(1) << (32 - 2 * r)) - 1)))
        am = a & mask
        bm = b & mask
        lt = lt | (pe & (am < bm))
        pe = pe & (am == bm)
    truncated = pos + plen > n_real
    eq = pe & ~truncated
    lt = lt | (pe & truncated)
    lt_ref[...] = lt.astype(jnp.int8)
    le_ref[...] = (lt | eq).astype(jnp.int8)
    eq_ref[...] = eq.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("n_real", "interpret"))
def pattern_compare_pallas(windows_t: jnp.ndarray, patterns_t: jnp.ndarray,
                           plen: jnp.ndarray, pos: jnp.ndarray,
                           *, n_real: int, interpret: bool = False):
    """windows_t/patterns_t: (W, B) uint32; plen/pos: (B,) int32.
    B must be a multiple of BLOCK_B (caller pads).  Returns (lt, le, eq)
    int8 (B,)."""
    W, B = windows_t.shape
    assert patterns_t.shape == (W, B)
    assert B % BLOCK_B == 0
    grid = (B // BLOCK_B,)
    kernel = functools.partial(_compare_kernel, n_real=n_real, n_words=W)
    out_shape = [jax.ShapeDtypeStruct((1, B), jnp.int8)] * 3
    vec_spec = pl.BlockSpec((1, BLOCK_B), lambda i: (0, i))
    lt, le, eq = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((W, BLOCK_B), lambda i: (0, i)),
            pl.BlockSpec((W, BLOCK_B), lambda i: (0, i)),
            vec_spec, vec_spec,
        ],
        out_specs=[vec_spec] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(windows_t, patterns_t, plen[None, :], pos[None, :])
    return lt[0], le[0], eq[0]

"""Fused multi-tier scan: every LSM tier binary-searched in ONE launch.

A merged read over a ``SuffixTable`` used to dispatch once per tier —
base scan, then one jitted query per sealed run, then the memtable —
plus a per-query host loop to apply each tier's straddle-rule bounds.
With runs live that fan-out dominated read latency (~9x base-only,
BENCH_compaction.json).  This module is the fused replacement: the delta
tiers are stacked into one bucket-padded :class:`~repro.core.tablet.
TierStack` and scanned together, with the per-tier straddle masks
(``lo < g + plen <= hi``, docs/table_api.md) applied inside the same
trace.

Two implementations, cross-checked in tests/test_kernels.py:

* :func:`fused_tier_scan` — pure jnp: a vmapped batched binary search
  over the stacked tiers plus a masked in-range reduction.  This is the
  production CPU path and the oracle;
* :func:`tier_scan_pallas` — the Pallas TPU kernel (DNA-packed rows):
  a dense blocked scan in the ``tablet_scan`` style with a tier axis on
  the grid, so all tiers of a table ride one Mosaic launch.

Per query and per tier both return, over the tier's REAL rows only:

====== =====================================================================
field  meaning
====== =====================================================================
count    occurrences the tier OWNS (straddle bounds applied)
less     rows strictly before the pattern — the enumeration lower bound
matches  raw prefix-match run length (bounds NOT applied); the SA slice
         ``[less, less + matches)`` holds every candidate row, from which
         the host filters owned positions without re-searching
first_g  minimum owned GLOBAL start position (``BIG`` when count == 0)
====== =====================================================================
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from repro.core import codec
from repro.core import query as Q

BLOCK_Q = 128   # patterns per tile (sublane-major axis of the compare tile)
BLOCK_R = 256   # rows per tile (lane axis, 128-aligned)
BIG = 2**30     # "no match" sentinel for first_g


def _owned_tail(ov_rank_t, hi_rank_t, pad_cnt_t, rmq_t, offset_t, lo_t,
                hi_t, plen, lb, ub):
    """From one tier's search bounds [lb, ub) to its four outputs, in
    O(B * (max_query_len + log R)) instead of a dense (B, R) mask.

    A window row at local position p is OWNED iff
    ``overlap < p + plen <= tl`` (``overlap = lo - offset``,
    ``tl = hi - offset`` = the true text length; positions ``p >= tl``
    are the pow2 bucket padding of ``padded_segment_store``, real to the
    store but never owned).  The disowned rows split into three disjoint
    sets, each precomputed host-side (see
    :class:`~repro.core.tablet.TierStack`): overlap rows indexed by
    ``ov_rank``, end rows indexed by ``hi_rank``, and pad rows counted
    by the ``pad_cnt`` prefix sums.  ``first_g`` is the min over (a)
    owned overlap rows and (b) the sparse-table range-min of middle-row
    ``g`` over the window, guarded by the high bound — if the minimum
    position fails ``p <= tl - plen``, every middle row in the window
    does."""
    K, R = rmq_t.shape
    OV = ov_rank_t.shape[0]
    plen_i = plen.astype(jnp.int32)
    overlap = lo_t - offset_t
    tl = hi_t - offset_t
    L = (ub - lb).astype(jnp.int32)                                # (B,)
    p_idx = jnp.arange(OV, dtype=jnp.int32)[None, :]

    # low bound: overlap rows (p < overlap) present in the window
    in_lo = ((ov_rank_t[None, :] >= lb[:, None])
             & (ov_rank_t[None, :] < ub[:, None]))                 # (B, OV)
    stops_in = p_idx + plen_i[:, None] <= overlap  # match END inside prefix
    excl_lo = jnp.sum(in_lo & stops_in, axis=1).astype(jnp.int32)
    own_lo = in_lo & ~stops_in & (p_idx + plen_i[:, None] <= tl)
    c_ov = jnp.min(jnp.where(own_lo, p_idx + offset_t,
                             jnp.int32(BIG)), axis=1)

    # high bound: end rows (p = tl - 1 - q) with the match running past tl
    in_hi = ((hi_rank_t[None, :] >= lb[:, None])
             & (hi_rank_t[None, :] < ub[:, None]))                 # (B, OV)
    excl_hi = jnp.sum(in_hi & (p_idx <= plen_i[:, None] - 2),
                      axis=1).astype(jnp.int32)

    # bucket-pad rows (p >= tl): never owned, counted by prefix sums
    excl_pad = jnp.take(pad_cnt_t, ub) - jnp.take(pad_cnt_t, lb)

    count = L - excl_lo - excl_hi - excl_pad
    k = jnp.zeros_like(L)                          # floor(log2 L), L >= 1
    for j in range(1, K):
        k = k + (L >= (1 << j)).astype(L.dtype)
    h = jnp.left_shift(jnp.int32(1), k)
    flat = rmq_t.reshape(-1)
    m = jnp.minimum(
        jnp.take(flat, k * R + jnp.clip(lb, 0, R - 1)),
        jnp.take(flat, k * R + jnp.clip(ub - h, 0, R - 1)))
    ok = (L > 0) & (m - offset_t <= tl - plen_i)
    c_rmq = jnp.where(ok, m, jnp.int32(BIG))
    return count, lb, L, jnp.minimum(c_ov, c_rmq)


# ---------------------------------------------------------------------------
# pure-jnp fused path (production on CPU; oracle for the kernel)
# ---------------------------------------------------------------------------
def fused_tier_scan(stack, patt, plen):
    """Scan every tier of a :class:`~repro.core.tablet.TierStack` in one
    trace.  ``patt`` is the same encoded batch the base scan takes
    (packed uint32 (B, W) for DNA, int32 codes (B, L) otherwise); returns
    ``(count, less, matches, first_g)``, each (T, B) int32.

    The per-tier metadata (``n_real`` / ``n_rows`` / ``offset`` / ``lo``
    / ``hi``) is traced DATA, so appends that stay inside a text bucket
    reuse the compilation; only bucket growth or a tier-count change
    re-specializes."""
    R = stack.rows
    steps = max(1, int(np.ceil(np.log2(R + 1))))
    use_packed = stack.is_dna and patt.dtype == jnp.uint32
    text = stack.text_packed if use_packed else stack.text_codes
    cmp = Q.compare_packed if use_packed else Q.compare_codes
    B = patt.shape[0]

    # both bounds ride ONE loop: row 0 searches the lower bound
    # (pred = lt), row 1 the upper (pred = lt | eq), with the compare
    # batched over 2B stacked positions — half the loop trips of two
    # independent searches
    patt2 = jnp.concatenate([patt, patt], axis=0)
    plen2 = jnp.concatenate([plen, plen], axis=0)
    is_ub = jnp.array([[False], [True]])                   # (2, 1)

    def one_tier(sa_t, text_t, n_real_t, n_rows_t, offset_t, lo_t, hi_t,
                 ov_rank_t, hi_rank_t, pad_cnt_t, rmq_t):
        def body(_, lohi):
            lo, hi = lohi                                  # (2, B)
            mid = (lo + hi) // 2
            pos = jnp.take(sa_t, jnp.clip(mid.reshape(-1), 0, R - 1))
            lt, eq = cmp(text_t, n_real_t, pos, patt2, plen2)
            pred = lt.reshape(2, B) | (eq.reshape(2, B) & is_ub)
            active = lo < hi
            lo = jnp.where(active & pred, mid + 1, lo)
            hi = jnp.where(active & ~pred, mid, hi)
            return lo, hi

        lo = jnp.zeros((2, B), jnp.int32)
        hi = jnp.broadcast_to(n_rows_t.astype(jnp.int32), (2, B))
        lo, hi = lax.fori_loop(0, steps, body, (lo, hi))
        return _owned_tail(ov_rank_t, hi_rank_t, pad_cnt_t, rmq_t,
                           offset_t, lo_t, hi_t, plen, lo[0], lo[1])

    return jax.vmap(one_tier)(stack.sa, text, stack.n_real, stack.n_rows,
                              stack.offset, stack.lo, stack.hi,
                              stack.ov_rank, stack.hi_rank,
                              stack.pad_cnt, stack.rmq)


def fused_table_scan(store, stack, patt, plen):
    """THE single-device merged read search: the base store AND every
    delta tier binary-searched inside ONE ``fori_loop``.  Each step
    gathers one probe row per (store, bound, query), compares all of
    them in one chain (per-row ``n_real`` — the rows come from different
    texts), and advances all bounds together, so the serial step count
    is ``max(log2 n_base, log2 R)`` instead of their sum across separate
    base and tier dispatches.

    Returns ``(base MatchResult, (count, less, matches, first_g))`` with
    exactly the :func:`~repro.core.query.query` / :func:`fused_tier_scan`
    contracts — bit-identical, just one fused launch."""
    R = stack.rows
    T = stack.num_tiers
    n = store.n_pad
    steps = max(1, int(np.ceil(np.log2(max(n, R) + 1))))
    use_packed = stack.is_dna and patt.dtype == jnp.uint32
    btext = store.text_packed if use_packed else store.text_codes
    ttext = stack.text_packed if use_packed else stack.text_codes
    B, W = patt.shape

    # probe layout: group 0 is the base, groups 1..T the tiers; within a
    # group, row 0 searches the lower bound, row 1 the upper
    patt2 = jnp.concatenate([patt, patt], axis=0)              # (2B, W)
    plen2 = jnp.concatenate([plen, plen], axis=0)
    patt_rep = jnp.tile(patt2, (T + 1, 1))
    plen_rep = jnp.tile(plen2, (T + 1,))
    n_real_all = jnp.concatenate(
        [jnp.full((1,), store.n_real, jnp.int32),
         stack.n_real.astype(jnp.int32)])                      # (T+1,)
    n_real_rep = jnp.repeat(n_real_all, 2 * B)
    is_ub = jnp.array([[False], [True]])                       # (2, 1)

    def body(_, carry):
        blo, bhi, tlo, thi = carry                 # (2, B) / (T, 2, B)
        bmid = (blo + bhi) // 2
        tmid = (tlo + thi) // 2
        bpos = jnp.take(store.sa,
                        jnp.clip(bmid.reshape(-1), 0, n - 1))  # (2B,)
        tpos = jax.vmap(
            lambda sa_t, m: jnp.take(sa_t, jnp.clip(m, 0, R - 1)))(
                stack.sa, tmid.reshape(T, 2 * B))              # (T, 2B)
        pos_all = jnp.concatenate(
            [bpos.reshape(1, -1).astype(jnp.int32),
             tpos.astype(jnp.int32)]).reshape(-1)
        if use_packed:
            wb = codec.extract_window(btext, bpos, W)
            wt = jax.vmap(
                lambda tx, p: codec.extract_window(tx, p, W))(ttext, tpos)
            win = jnp.concatenate([wb[None], wt]).reshape(-1, W)
            lt, eq = Q.compare_windows_packed(win, pos_all, n_real_rep,
                                              patt_rep, plen_rep)
        else:
            sb = Q.gather_suffix_codes(btext, store.n_real, bpos, W)
            st = jax.vmap(
                lambda tx, nr, p: Q.gather_suffix_codes(tx, nr, p, W))(
                    ttext, stack.n_real, tpos)
            suf = jnp.concatenate([sb[None], st]).reshape(-1, W)
            lt, eq = Q.compare_suffix_codes(suf, patt_rep, plen_rep)
        pred = (lt.reshape(T + 1, 2, B)
                | (eq.reshape(T + 1, 2, B) & is_ub[None]))
        bactive = blo < bhi
        blo = jnp.where(bactive & pred[0], bmid + 1, blo)
        bhi = jnp.where(bactive & ~pred[0], bmid, bhi)
        tactive = tlo < thi
        tlo = jnp.where(tactive & pred[1:], tmid + 1, tlo)
        thi = jnp.where(tactive & ~pred[1:], tmid, thi)
        return blo, bhi, tlo, thi

    blo = jnp.zeros((2, B), jnp.int32)
    bhi = jnp.full((2, B), n, jnp.int32)
    tlo = jnp.zeros((T, 2, B), jnp.int32)
    thi = jnp.broadcast_to(
        stack.n_rows.astype(jnp.int32)[:, None, None], (T, 2, B))
    blo, _, tlo, _ = lax.fori_loop(0, steps, body, (blo, bhi, tlo, thi))

    lb, ub = blo[0], blo[1]                        # base, Q.query contract
    count = ub - lb
    found = count > 0
    first_pos = jnp.take(store.sa, jnp.clip(lb, 0, n - 1))
    first_pos = jnp.where(found, first_pos, -1)
    first_rank = jnp.where(found, lb - store.pad_count, -1)
    base = Q.MatchResult(found=found, count=count,
                         first_rank=first_rank, first_pos=first_pos)

    tiers = jax.vmap(
        lambda ovr, hir, pcn, rmq_t, offset_t, lo_t, hi_t, lb_t, ub_t:
        _owned_tail(ovr, hir, pcn, rmq_t, offset_t, lo_t, hi_t, plen,
                    lb_t, ub_t))(
            stack.ov_rank, stack.hi_rank, stack.pad_cnt, stack.rmq,
            stack.offset, stack.lo, stack.hi, tlo[:, 0, :], tlo[:, 1, :])
    return base, tiers


def merge_tier_results(base, tier_count, tier_first):
    """Merge a base :class:`~repro.core.query.MatchResult` with fused
    tier outputs, in-trace (jnp) or on host (numpy): merged ``count`` is
    the sum over owners, ``first_pos`` the minimum over the base's
    reported position and every tier's first owned position, and
    ``first_rank`` keeps its base-only meaning (−1 when only delta tiers
    match — docs/table_api.md)."""
    total = base.count + jnp.sum(tier_count, axis=0).astype(base.count.dtype)
    dmin = jnp.min(tier_first, axis=0)          # BIG when a tier owns none
    cand = jnp.where(base.count > 0, base.first_pos, jnp.int32(BIG))
    first_pos = jnp.minimum(cand.astype(jnp.int32), dmin)
    found = total > 0
    first_pos = jnp.where(found & (first_pos < BIG), first_pos, -1)
    return Q.MatchResult(found=found, count=total,
                         first_rank=base.first_rank, first_pos=first_pos)


# ---------------------------------------------------------------------------
# Pallas kernel: dense blocked scan with a tier grid axis (DNA-packed)
# ---------------------------------------------------------------------------
def _tier_kernel(patt_ref, plen_ref, win_ref, sa_ref, meta_ref,
                 count_ref, less_ref, match_ref, first_ref,
                 *, n_words: int):
    plen = plen_ref[...].reshape(-1, 1).astype(jnp.int32)   # (BQ, 1)
    salocal = sa_ref[0, 0, :].reshape(1, -1)                # (1, BR)
    n_real = meta_ref[0, 0]
    n_rows = meta_ref[0, 1]
    offset = meta_ref[0, 2]
    lo_b = meta_ref[0, 3]
    hi_b = meta_ref[0, 4]

    bq = plen.shape[0]
    br = salocal.shape[1]
    pe = jnp.ones((bq, br), jnp.bool_)
    lt = jnp.zeros((bq, br), jnp.bool_)
    for w in range(n_words):
        a = win_ref[0, w, :][None, :]                       # row word (1,BR)
        b = patt_ref[w, :][:, None]                         # pattern  (BQ,1)
        r = jnp.clip(plen - w * 16, 0, 16).astype(jnp.uint32)
        full = jnp.uint32(0xFFFFFFFF)
        mask = jnp.where(r == 0, jnp.uint32(0),
                         jnp.where(r == 16, full,
                                   ~((jnp.uint32(1) << (32 - 2 * r)) - 1)))
        am = a & mask                                       # (BQ, BR)
        bm = b & mask
        lt = lt | (pe & (am < bm))
        pe = pe & (am == bm)
    truncated = salocal + plen > n_real                     # (BQ, BR)
    eq = pe & ~truncated
    lt = lt | (pe & truncated)

    row0 = pl.program_id(2) * br
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, br), 1)
    valid = rows < n_rows                                   # stack padding
    eq = eq & valid
    lt = lt & valid
    g = salocal + offset                                    # global starts
    e = g + plen
    owned = eq & (e > lo_b) & (e <= hi_b)                   # straddle rule
    first = jnp.min(jnp.where(owned, g, jnp.int32(BIG)), axis=1)   # (BQ,)
    cnt = jnp.sum(owned.astype(jnp.int32), axis=1)
    mat = jnp.sum(eq.astype(jnp.int32), axis=1)
    less = jnp.sum(lt.astype(jnp.int32), axis=1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        count_ref[...] = cnt[None, :]
        less_ref[...] = less[None, :]
        match_ref[...] = mat[None, :]
        first_ref[...] = first[None, :]

    @pl.when(pl.program_id(2) != 0)
    def _acc():
        count_ref[...] += cnt[None, :]
        less_ref[...] += less[None, :]
        match_ref[...] += mat[None, :]
        first_ref[...] = jnp.minimum(first_ref[...], first[None, :])


@functools.partial(jax.jit, static_argnames=("interpret",))
def tier_scan_pallas(patterns_t: jnp.ndarray, plen: jnp.ndarray,
                     windows_t: jnp.ndarray, sa: jnp.ndarray,
                     meta: jnp.ndarray, *, interpret: bool = False):
    """patterns_t: (W, BQtot) uint32; plen: (BQtot,) int32; windows_t:
    (T, W, BRtot) uint32 — packed windows of every tier's stacked sorted
    rows; sa: (T, BRtot) int32 LOCAL text positions of those rows; meta:
    (T, 8) int32 rows of ``[n_real, n_rows, offset, lo, hi, 0, 0, 0]``
    per tier.  BQtot % BLOCK_Q == 0 and BRtot % BLOCK_R == 0 (caller
    pads; rows past ``n_rows`` are masked).  Returns (count, less,
    matches, first_g) int32 (T, BQtot)."""
    T, W, BR = windows_t.shape
    BQ = patterns_t.shape[1]
    assert BQ % BLOCK_Q == 0 and BR % BLOCK_R == 0
    grid = (T, BQ // BLOCK_Q, BR // BLOCK_R)
    kernel = functools.partial(_tier_kernel, n_words=W)
    qvec = pl.BlockSpec((1, BLOCK_Q), lambda t, q, r: (t, q))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((W, BLOCK_Q), lambda t, q, r: (0, q)),
            pl.BlockSpec((1, BLOCK_Q), lambda t, q, r: (0, q)),
            pl.BlockSpec((1, W, BLOCK_R), lambda t, q, r: (t, 0, r)),
            pl.BlockSpec((1, 1, BLOCK_R), lambda t, q, r: (t, 0, r)),
            pl.BlockSpec((1, 8), lambda t, q, r: (t, 0)),
        ],
        out_specs=[qvec] * 4,
        out_shape=[jax.ShapeDtypeStruct((T, BQ), jnp.int32)] * 4,
        interpret=interpret,
    )(patterns_t, plen[None, :], windows_t, sa[:, None, :], meta)
    return out

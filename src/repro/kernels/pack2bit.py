"""Pallas TPU kernel: 2-bit DNA packing (ingest hot-spot, paper §IV).

Layout: the caller reshapes the code stream to (16, n_words) — 16 sublanes
(one per base slot of a word) x n_words lanes — so the shift/OR reduction
runs along the sublane axis and every lane op is 128-aligned.  Packing is
big-endian within the word (codec.pack_2bit convention): base s sits at
bit 30-2s, so an unsigned word compare is a 16-base lexicographic compare.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 16          # bases per 32-bit word (sublane dim)
BLOCK_WORDS = 1024  # words per grid step (lane dim, 128-aligned)


def _pack_kernel(codes_ref, out_ref):
    c = codes_ref[...].astype(jnp.uint32)                    # (16, BW)
    s = jax.lax.broadcasted_iota(jnp.uint32, c.shape, 0)     # sublane index
    shifted = c << (30 - 2 * s)
    # bits are disjoint per sublane -> OR == sum; sum lowers everywhere
    out_ref[...] = jnp.sum(shifted, axis=0, dtype=jnp.uint32)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack2bit_pallas(codes_lanes: jnp.ndarray, *, interpret: bool = False):
    """codes_lanes: (16, n_words) uint8/uint32 codes in {0..3} (slot-major).
    Returns (n_words,) uint32 packed words."""
    lanes, n_words = codes_lanes.shape
    assert lanes == LANES
    assert n_words % BLOCK_WORDS == 0, "caller pads to BLOCK_WORDS"
    grid = (n_words // BLOCK_WORDS,)
    out = pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((LANES, BLOCK_WORDS), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, BLOCK_WORDS), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_words), jnp.uint32),
        interpret=interpret,
    )(codes_lanes.astype(jnp.uint32))
    return out[0]
